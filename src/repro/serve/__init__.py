"""Serving layer.

The family-dispatched cache/decode primitives live in ``repro.models``
(`cache_spec`, `init_cache`, `decode_step`, `forward(..., caches=)`) so each
architecture's cache layout sits next to its math; this package re-exports
them as the serving API and hosts the batched driver (`repro.launch.serve`).
Cache sharding (sequence-sharded KV with LSE-combine collectives, ring
buffers for local attention, O(1) recurrent states) is documented in
DESIGN.md §6.
"""
from ..models import cache_spec, init_cache, decode_step, forward

__all__ = ["cache_spec", "init_cache", "decode_step", "forward"]
