"""Distributed BSP coloring via shard_map — the Bozdag et al. [6] framework
(the paper's ITERATIVE ancestor) mapped onto a JAX device mesh.

Vertices are partitioned across all mesh devices (:func:`partition_graph`:
1D blocks, or 2D block-cyclic for skewed R-MAT degree distributions), and
every local vertex is classified at partition time as **interior** (no
cross-shard edge — its color never leaves the shard) or **boundary**. Each
BSP round:

  1. local speculative greedy over the device's pending vertices, against
     last round's exchanged snapshot. With local concurrency ``C=1``
     (default) each device colors its pending set *sequentially* — exactly
     the distributed-memory algorithm — realized as the chaotic fixpoint of
     the local offset-precedence dataflow equations via the shared
     :func:`repro.core.engine.fixpoint_sweep` (converges in local-DAG-depth
     sweeps, no communication inside); cross-device pending neighbors are
     speculated against (not forbidden). The first-fit inner loop is the
     pluggable mex backend (``engine=``), bound to the local vertex slab;
  2. the wire — a three-tier exchange of ``(color, pending)`` state, each
     tier bit-identical to the others (DESIGN.md §Distributed):

     * **boundary wire** (the default): only *boundary* colors + pending
       flags cross the wire, bit-packed into int32 words
       (:mod:`repro.parallel.compression`) and scattered through the static
       boundary->halo index map; the shard's own ``[Vl]`` snapshot slice is
       patched locally with no collective at all. Exact because every
       cross-shard read (phase-1 forbids and the conflict pass) targets
       either a local vertex or a remote *boundary* vertex — by definition;
     * **frontier-halo wire** (H-C3, layered on top): when a psum vote says
       every device's pending set fits its frontier slab, the exchange
       shrinks further, to the ``(gid, color)`` pairs of the per-device
       frontier slabs;
     * **full gather** (the spill path, ``wire="full"``): the legacy H-C1
       ``[Vp]`` packed-int16 gather — retained for plan envelopes whose
       halo capacity a served graph overflows, and as the parity oracle;
  3. conflict detection against the exchanged view: monochromatic
     same-round pairs — with C=1 these are exclusively *boundary*
     (cross-device) conflicts, as in [6]; the higher global index recolors;
  4. ``psum`` termination vote.

The whole multi-round algorithm is one ``lax.while_loop`` inside shard_map,
so it lowers/compiles as a single XLA program on the production meshes —
`launch/dryrun.py` exercises it via the rmat_coloring config, and the
``dist_scale`` benchmark family measures bytes-on-wire vs. shard count.
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..jax_compat import pvary, shard_map
from ..parallel.compression import halo_words, pack_halo, unpack_halo

from .engine import (EngineSpec, SweepSpec, edge_slots, fixpoint_sweep,
                     get_backend, lockstep_offsets)
from .frontier import (FrontierSlab, compact_frontier, frontier_counts,
                       frontier_sweep)
from .graph import Graph, ShardLayout

PARTITION_SCHEMES = ("1d", "2d")
WIRES = ("boundary", "full")


def slab_entry_bytes(verts_global: int, wire_colors: int) -> int:
    """Wire bytes one H-C3 frontier-slab entry costs: 4 when the
    ``(gid, color)`` pair packs into a single int32 word (gid needs
    ``bit_length(Vp)`` bits — ``Vp`` doubles as the drop sentinel — plus
    ``bit_length(wire_colors)`` for the color), else 8 via the two-gather
    path. The single packing rule shared by ``_bsp_local``'s trace-time
    decision, the ``dist_scale`` benchmark's accounting, and (re-derived
    independently) the SPMD verifier's WIRE cost model."""
    packed = (wire_colors > 0 and
              int(verts_global).bit_length()
              + int(wire_colors).bit_length() <= 32)
    return 4 if packed else 8


def _grid_shape(num_devices: int):
    """The ``Pr x Pc`` device grid of the 2D block-cyclic scheme: ``Pr`` the
    largest divisor of D at most ``sqrt(D)`` (a prime D degenerates to a
    1 x D grid, i.e. plain cyclic distribution)."""
    Pr = max(1, int(np.sqrt(num_devices)))
    while num_devices % Pr:
        Pr -= 1
    return Pr, num_devices // Pr


def partition_graph(graph: Graph, num_devices: int,
                    pad_edges_to: int = 0, *, scheme: str = "1d",
                    pad_boundary_to: int = 0) -> ShardLayout:
    """Host-side partitioning into the shard-local CSR + halo layout
    (:class:`repro.core.graph.ShardLayout`).

    Device d owns partition-space vertices [d*Vl, (d+1)*Vl); ``lsrc`` holds
    *local* ids (pad = Vl), ``ldst`` *global* ids (pad = Vl*D). Edges stay
    row-contiguous per device (src order), so local ELL slots are
    recoverable on device via :func:`repro.core.engine.edge_slots`.

    Every local vertex is classified: **boundary** iff it has any
    cross-shard edge (as src or dst — symmetric directed edge lists make
    these the same set), else **interior**. ``layout.bnd [D, Bl]`` is the
    static boundary->halo index map the boundary-only wire exchanges
    through; interior vertices never appear in it, nor in any other shard's
    ``ldst``, so their colors structurally cannot leave the shard.

    ``scheme`` picks vertex ownership: ``"1d"`` contiguous blocks of the
    original ids, or ``"2d"`` block-cyclic over a ``Pr x Pc`` device grid
    (ScaLAPACK-style: ``owner(v) = (v mod Pr)*Pc + (v div Pr) mod Pc``,
    local index ``v div D``). R-MAT generators concentrate high-degree
    vertices at low ids, so 1D blocks hand one shard both the widest edge
    slab and the densest boundary; the 2D map spreads each hub region
    across the whole grid, re-balancing El and Bl (the ``dist_scale``
    benchmark family measures both). A ``"2d"`` layout carries the
    original->partition ``perm``; colors come back through
    :meth:`ShardLayout.unpermute`. (This is vertex-grid distribution, not
    Bogle-Slota 2D *edge* partitioning — the local solve keeps every edge
    on its src's owner, so no row/column sub-collectives are needed.)

    ``pad_edges_to`` / ``pad_boundary_to`` pin the slab widths El / Bl to
    fixed capacities (the :class:`repro.core.api.ColoringPlan` path, where
    every served graph must produce identically-shaped slabs); a graph
    whose densest shard exceeds either is rejected rather than truncated.
    """
    if scheme not in PARTITION_SCHEMES:
        raise ValueError(f"unknown partition scheme {scheme!r}; choose "
                         f"from {PARTITION_SCHEMES}")
    D = num_devices
    V = graph.num_vertices
    Vl = -(-V // D)
    Vp = Vl * D
    src, dst = graph.directed_edges()  # src sorted
    perm = None
    if scheme == "2d":
        Pr, Pc = _grid_shape(D)
        ids = np.arange(V, dtype=np.int64)
        owner_of = (ids % Pr) * Pc + (ids // Pr) % Pc
        perm = (owner_of * Vl + ids // D).astype(np.int32)
        src, dst = perm[src], perm[dst]
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
    owner = src // Vl
    counts = np.bincount(owner, minlength=D)
    El = max(1, int(counts.max()))
    if pad_edges_to:
        if El > pad_edges_to:
            raise ValueError(
                f"densest partition holds {El} directed edges, above the "
                f"requested slab capacity pad_edges_to={pad_edges_to}")
        El = int(pad_edges_to)
    lsrc = np.full((D, El), Vl, np.int32)
    ldst = np.full((D, El), Vp, np.int32)
    offsets = np.zeros(D + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    for d in range(D):
        sl = slice(offsets[d], offsets[d + 1])
        k = offsets[d + 1] - offsets[d]
        lsrc[d, :k] = src[sl] - d * Vl
        ldst[d, :k] = dst[sl]
    # interior/boundary split: every endpoint of a cross-shard edge is
    # boundary. Marking dst as well as src keeps the classification exact
    # (= "some remote shard reads this vertex") even for an asymmetric
    # directed edge list; for the symmetric lists Graph produces the two
    # marks coincide.
    cross = owner != (dst // Vl)
    bmask = np.zeros(Vp, np.bool_)
    bmask[src[cross]] = True
    bmask[dst[cross]] = True
    bids = np.flatnonzero(bmask)
    bowner = bids // Vl
    bcounts = np.bincount(bowner, minlength=D)
    Bl = int(bcounts.max()) if bids.size else 0
    if pad_boundary_to:
        if Bl > pad_boundary_to:
            raise ValueError(
                f"densest shard holds {Bl} boundary vertices, above the "
                f"requested halo capacity pad_boundary_to={pad_boundary_to}")
        Bl = int(pad_boundary_to)
    bnd = np.full((D, Bl), Vl, np.int32)
    boffsets = np.zeros(D + 1, np.int64)
    np.cumsum(bcounts, out=boffsets[1:])
    rank = np.arange(bids.size, dtype=np.int64) - boffsets[bowner]
    bnd[bowner, rank] = (bids - bowner * Vl).astype(np.int32)
    return ShardLayout(lsrc=lsrc, ldst=ldst, bnd=bnd, verts_local=Vl,
                       num_vertices=V, num_devices=D, scheme=scheme,
                       perm=perm, boundary_counts=bcounts.astype(np.int64))


def _bsp_local(lsrc, ldst, bnd, *, axis_names: Tuple[str, ...],
               verts_local: int, num_devices: int, local_concurrency: int,
               max_rounds: int, max_sweeps: int, backend, max_colors: int,
               ell_width: int, frontier_cap_v: int = 0,
               frontier_cap_e: int = 0, wire: str = "boundary",
               wire_colors: int = 0):
    """Per-device body (runs under shard_map).

    The wire (DESIGN.md §Distributed / §Perf): the default **boundary
    wire** packs each shard's boundary ``(color, pending)`` entries into
    int32 words (``repro.parallel.compression.pack_halo``; entry width =
    ``bit_length(wire_colors) + 1`` bits, ``wire_colors`` the provable
    Delta+1 color bound) and all-gathers only those — the static
    boundary->halo id map ``bnd`` is gathered ONCE outside the round loop.
    The gathered payload patches the carried ``[Vp]`` snapshot/pending view
    at the (static) boundary ids; the shard's own ``[Vl]`` slice is patched
    locally with no collective. Exact for both the phase-1 forbids and the
    conflict pass: every cross-shard read lands on a remote *boundary*
    vertex by definition, and every local read on the locally-patched
    slice — so colors, rounds and conflict histories are bit-identical to
    the full gather. With ``wire="full"`` (the spill path) each round
    instead gathers the whole packed-int16 ``[Vp]`` vector (H-C1:
    ``color << 1 | pending``, colors below 2^14; one gather serves phase 1
    AND conflict detection, §Perf H-C2).

    Frontier rounds (§Frontier, ``frontier_cap_v > 0``): each device
    compacts its pending vertices + incident slab edges and solves over the
    compacted slab; when EVERY device's pending set fits its vertex slab
    (one psum vote), the wire shrinks further — to a (global id, color)
    gather of the per-device frontier slabs (H-C3), layered on top of the
    boundary tier: it patches the same carried snapshot the boundary wire
    maintains. Any overflow falls back to the full sweep / the configured
    round wire, so results are bit-identical in all regimes. Round 0
    always takes the configured round wire.

    The conflict pass stays fused with the wire decode rather than routing
    through engine.speculation_conflicts — the per-machine specialization
    this driver exists for.
    """
    Vl = verts_local
    Vp = Vl * num_devices
    C = local_concurrency
    lsrc = lsrc.reshape(-1)
    ldst = ldst.reshape(-1)
    bnd = bnd.reshape(-1)
    if wire not in WIRES:
        raise ValueError(f"unknown wire {wire!r}; choose from {WIRES}")
    use_boundary = wire == "boundary"
    Bl = int(bnd.shape[0])
    if use_boundary and Bl > 0 and wire_colors <= 0:
        raise ValueError("wire='boundary' needs wire_colors (the provable "
                         "Delta+1 color bound) to size the packed payload")
    didx = lax.axis_index(axis_names).astype(jnp.int32)
    base = didx * Vl
    gsrc = jnp.where(lsrc < Vl, lsrc + base, Vp)
    dst_local = (ldst >= base) & (ldst < base + Vl)
    dst_loc = jnp.where(dst_local, ldst - base, Vl)  # local id or pad
    lsrc_safe = jnp.minimum(lsrc, Vl)
    slots = edge_slots(lsrc, Vl) if backend.needs_ell else None
    # ell_width IS the true max degree here (color_distributed wires it so);
    # pass it as max_degree too, so a color_bound cap can't mask truncation
    mex = backend.bind(num_vertices=Vl, max_colors=max_colors,
                       ell_slot=slots, ell_width=ell_width,
                       max_degree=ell_width if backend.needs_ell else -1)
    use_frontier = frontier_cap_v > 0
    if use_frontier:
        mex_slab = backend.bind_slab(
            capacity=frontier_cap_v, max_colors=max_colors,
            ell_width=ell_width,
            max_degree=ell_width if backend.needs_ell else -1)
        # per-shard incident-edge pointers, recovered on device from the
        # row-contiguous slab (partition_graph keeps global src order)
        ldeg = (jnp.zeros((Vl + 1,), jnp.int32)
                .at[lsrc_safe].add(1))[:Vl]
        lrow_ptr = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(ldeg)])

    def gather(x):
        return lax.all_gather(x, axis_names, tiled=True)

    def pv(x):
        # mark as device-varying so while_loop carries type-check under
        # shard_map's varying-manual-axes tracking
        return pvary(x, axis_names)

    if use_boundary and Bl > 0:
        Wb = halo_words(Bl, wire_colors)
        bnd_safe = jnp.minimum(bnd, Vl)
        # the boundary->halo scatter map is static per shard, so ONE gather
        # outside the round loop builds the global id map — zero per-round
        # id traffic; pad rows carry the Vp drop sentinel
        bnd_gids = gather(jnp.where(bnd < Vl, bnd + base, Vp))  # [D*Bl]

    def round_body(state):
        (colors, pending, snap, rnd, conf_hist, sweep_hist,
         front_hist, _) = state
        # (1) last round's snapshot view. ALL nonzero colors forbid —
        # including stale colors of re-pending vertices: over-forbidding
        # never breaks validity (it slightly biases re-colored vertices away
        # from the contested color, which helps) and it lets one exchange
        # per round serve both phase 1 and conflict detection (§Perf H-C2).
        snap_pad = jnp.concatenate([snap, jnp.zeros((1,), jnp.int32)])
        ppad = jnp.concatenate([pending, jnp.zeros((1,), jnp.bool_)])

        # local lockstep offsets (C virtual threads per device)
        offset = lockstep_offsets(pending, C)
        opad = jnp.concatenate([offset, jnp.full((1,), jnp.iinfo(jnp.int32).max, jnp.int32)])

        src_pending = ppad[lsrc_safe] & (lsrc < Vl)

        if use_frontier:
            nv, ne = frontier_counts(pending, lrow_ptr)
            fits_solve = ((rnd > 0) & (nv <= frontier_cap_v)
                          & (ne <= frontier_cap_e))
            # the slab wire only needs the pending VERTICES to fit; every
            # device must fit for the gathered slabs to reconstruct the
            # exact global pending set
            fits_wire = (rnd > 0) & (nv <= frontier_cap_v)
            all_fit = lax.psum(
                1 - fits_wire.astype(jnp.int32), axis_names) == 0

            # one compaction serves the local solve, the wire and the
            # conflict pass — built only when some branch will consume it
            # (spilled rounds, incl. round 0, skip the work entirely); slab
            # row space is LOCAL vertex ids, edge targets stay GLOBAL (the
            # ldst id space, pad = Vp)
            def _compact(_):
                return compact_frontier(pending, lrow_ptr, ldst,
                                        frontier_cap_v, frontier_cap_e,
                                        dst_pad=Vp)

            def _empty_slab(_):
                return FrontierSlab(
                    vert=jnp.full((frontier_cap_v,), Vl, jnp.int32),
                    owner=jnp.full((frontier_cap_e,), frontier_cap_v,
                                   jnp.int32),
                    src=jnp.full((frontier_cap_e,), Vl, jnp.int32),
                    dst=jnp.full((frontier_cap_e,), Vp, jnp.int32),
                    slot=jnp.zeros((frontier_cap_e,), jnp.int32),
                    nv=nv, ne=ne)

            slab = lax.cond(fits_solve | all_fit, _compact, _empty_slab, 0)

        # (2) local sequential greedy as an offset-DAG fixpoint (no comms):
        # preceding local-pending neighbors track the live local colors,
        # everyone else contributes the frozen global snapshot.
        def full_solve(colors):
            nbr_local_pending = ppad[dst_loc]  # local *and* pending
            precede = nbr_local_pending & (opad[dst_loc] < opad[lsrc_safe])
            spec = SweepSpec(key_v=jnp.where(src_pending, lsrc, Vl),
                             dyn_idx=dst_loc, dyn=precede,
                             static_c=snap_pad[ldst])
            colors, n_sweeps, _ = fixpoint_sweep(
                mex, spec, jnp.where(pending, 0, colors), pending,
                max_sweeps=max_sweeps, wrap=pv)
            return colors, n_sweeps

        def slab_solve(colors):
            e_local = (slab.dst >= base) & (slab.dst < base + Vl)
            e_loc = jnp.where(e_local, slab.dst - base, Vl)
            precede = ppad[e_loc] & (opad[e_loc] < opad[jnp.minimum(slab.src, Vl)])
            live = slab.src < Vl
            cpad0 = (jnp.concatenate([colors, jnp.zeros((1,), jnp.int32)])
                     .at[slab.vert].set(0, mode="drop"))
            cpad, n_sweeps, _ = frontier_sweep(
                mex_slab,
                key_v=jnp.where(live, slab.owner, frontier_cap_v),
                dyn=precede, dyn_idx=e_loc,
                static_c=snap_pad[jnp.minimum(slab.dst, Vp)],
                slot=slab.slot, write_vert=slab.vert, cpad0=cpad0,
                max_sweeps=max_sweeps, wrap=pv)
            return cpad[:Vl], n_sweeps

        if use_frontier:
            colors, n_sweeps = lax.cond(fits_solve, slab_solve, full_solve,
                                        colors)
        else:
            colors, n_sweeps = full_solve(colors)

        # (3) the wire: boundary-packed exchange (default), the full packed
        # gather (spill), or the frontier-halo exchange on top
        def full_wire(colors):
            packed_local = ((colors << 1)
                            | pending.astype(jnp.int32)).astype(jnp.int16)
            packed_glob = gather(packed_local)                  # [Vp] int16
            return (packed_glob.astype(jnp.int32) >> 1,
                    (packed_glob & 1).astype(jnp.bool_))

        def boundary_wire(colors):
            # only boundary (color, pending) entries cross the wire,
            # bit-packed; interior state of remote shards is never read, and
            # the shard's own [Vl] snapshot slice needs no collective at all
            if Bl > 0:
                cpadl = jnp.concatenate([colors, jnp.zeros((1,), jnp.int32)])
                words = pack_halo(cpadl[bnd_safe], ppad[bnd_safe],
                                  wire_colors)                  # [Wb] int32
                gw = gather(words).reshape(num_devices, Wb)
                gcol, gpend = unpack_halo(gw, Bl, wire_colors)  # [D, Bl]
                snap2 = snap.at[bnd_gids].set(gcol.reshape(-1), mode="drop")
                pend2 = (jnp.zeros((Vp,), jnp.bool_)
                         .at[bnd_gids].set(gpend.reshape(-1), mode="drop"))
            else:
                # no cross-shard edges at all (D=1, or disconnected shards):
                # the local patch below is the whole exchange
                snap2, pend2 = snap, jnp.zeros((Vp,), jnp.bool_)
            snap2 = lax.dynamic_update_slice(snap2, colors, (base,))
            pend2 = lax.dynamic_update_slice(pend2, pending, (base,))
            return snap2, pend2

        # H-C3 slab entries are (gid, color) pairs; when both fields fit one
        # 32-bit word, the slab exchange ships ONE packed int32 gather
        # instead of two (slab_entry_bytes is the shared packing rule).
        # Static decision; at billion-edge Vp the fields outgrow a word and
        # the two-gather path remains. Lossless either way, so the tiers
        # stay bit-identical. wire_colors <= 0 (a caller without a provable
        # color bound, e.g. shape-only dry runs) also keeps two gathers.
        slab_cbits = int(wire_colors).bit_length()
        slab_packed = slab_entry_bytes(Vp, wire_colors) == 4

        def slab_wire(colors):
            # only this round's pending vertices changed color or pending
            # state: gather (gid, color) of the per-device frontier slabs
            # and patch the carried snapshot/pending view
            gids = jnp.where(slab.vert < Vl, slab.vert + base, Vp)
            cols = jnp.concatenate(
                [colors, jnp.zeros((1,), jnp.int32)])[jnp.minimum(slab.vert, Vl)]
            if slab_packed:
                words = ((gids.astype(jnp.uint32) << slab_cbits)
                         | cols.astype(jnp.uint32)).astype(jnp.int32)
                gw = gather(words).astype(jnp.uint32)           # [D*cap_v]
                g_gids = (gw >> slab_cbits).astype(jnp.int32)
                g_cols = (gw & jnp.uint32((1 << slab_cbits) - 1)
                          ).astype(jnp.int32)
            else:
                g_gids = gather(gids)                           # [D*cap_v]
                g_cols = gather(cols)
            snap2 = snap.at[g_gids].set(g_cols, mode="drop")
            pend2 = (jnp.zeros((Vp,), jnp.bool_)
                     .at[g_gids].set(True, mode="drop"))
            return snap2, pend2

        round_wire = boundary_wire if use_boundary else full_wire
        if use_frontier:
            snap, pend_glob = lax.cond(all_fit, slab_wire, round_wire, colors)
        else:
            snap, pend_glob = round_wire(colors)
        cgpad = jnp.concatenate([snap, jnp.zeros((1,), jnp.int32)])
        agpad = jnp.concatenate([pend_glob, jnp.zeros((1,), jnp.bool_)])

        # (4) same-round conflicts (boundary + same-offset); higher gid
        # recolors — over the frontier slab when it holds all local rows
        def full_conf(_):
            conf_e = (src_pending & agpad[ldst]
                      & (cgpad[gsrc] == cgpad[ldst]) & (gsrc > ldst))
            return (jnp.zeros((Vl,), jnp.int32)
                    .at[lsrc].max(conf_e.astype(jnp.int32), mode="drop")
                    .astype(jnp.bool_))

        def slab_conf(_):
            gsrc_e = jnp.where(slab.src < Vl, slab.src + base, Vp)
            conf_e = (agpad[jnp.minimum(slab.dst, Vp)]
                      & (cgpad[jnp.minimum(gsrc_e, Vp)]
                         == cgpad[jnp.minimum(slab.dst, Vp)])
                      & (gsrc_e > slab.dst))
            return (jnp.zeros((Vl,), jnp.int32)
                    .at[slab.src].max(conf_e.astype(jnp.int32), mode="drop")
                    .astype(jnp.bool_))

        if use_frontier:
            new_pending = lax.cond(fits_solve, slab_conf, full_conf, 0)
        else:
            new_pending = full_conf(0)

        # (5) global termination vote
        total = lax.psum(new_pending.sum(dtype=jnp.int32), axis_names)
        conf_hist = conf_hist.at[rnd].set(total)
        # local sweep depth this round; the caller maxes across devices
        sweep_hist = sweep_hist.at[rnd].set(n_sweeps)
        if use_frontier:
            front = lax.psum(jnp.where(fits_wire, nv, 0), axis_names)
            front_hist = front_hist.at[rnd].set(
                jnp.where(all_fit, front, 0))
        return (colors, new_pending, snap, rnd + 1, conf_hist,
                sweep_hist, front_hist, total)

    def cond(state):
        total = state[-1]
        rnd = state[3]
        return jnp.logical_and(total > 0, rnd < max_rounds)

    init = (pv(jnp.zeros((Vl,), jnp.int32)), pv(jnp.ones((Vl,), jnp.bool_)),
            pv(jnp.zeros((Vp,), jnp.int32)),   # snapshot: all uncolored
            pv(jnp.asarray(0, jnp.int32)), pv(jnp.zeros((max_rounds,), jnp.int32)),
            pv(jnp.zeros((max_rounds,), jnp.int32)),
            pv(jnp.zeros((max_rounds,), jnp.int32)),
            jnp.asarray(1, jnp.int32))  # psum output is axis-invariant
    (colors, pending, snap, rnd, conf_hist, sweep_hist,
     front_hist, _) = lax.while_loop(cond, round_body, init)
    return (colors[None], rnd[None], conf_hist[None], sweep_hist[None],
            front_hist[None])


def build_distributed_coloring(mesh: Mesh, verts_local: int, edges_local: int,
                               local_concurrency: int = 1,
                               max_rounds: int = 64, max_sweeps: int = 16384,
                               engine: EngineSpec = "sort",
                               max_colors: int = 0, ell_width: int = 0,
                               frontier_cap_v: int = 0,
                               frontier_cap_e: int = 0,
                               wire: str = "boundary",
                               wire_colors: int = 0):
    """Build the jitted shard_map coloring program for a mesh.

    Returns ``fn(lsrc [D, El], ldst [D, El], bnd [D, Bl]) -> (colors
    [D, Vl], rounds, conflicts_per_round, sweeps_per_round,
    frontier_per_round)``; inputs/outputs sharded over all mesh axes
    (``sweeps_per_round`` is the deepest local fixpoint across devices each
    round; ``frontier_per_round`` the global frontier size when the round
    took the compacted wire, else 0). Static shapes, so the identical
    program serves dry-run lowering.

    ``engine`` picks the local first-fit backend; ``max_colors`` (global
    Delta+1, possibly capped by ``color_bound``) sizes the bitmap/ell
    backends; ``ell_width`` (max degree of any owned vertex) is required
    for the ELL-slab engines (``"ell_pallas"``, ``"fused_pallas"``).
    ``frontier_cap_v``/``frontier_cap_e`` enable the per-shard frontier
    slabs (0 = full sweeps every round; see repro.core.frontier).

    ``wire`` picks the per-round exchange (see :func:`_bsp_local`):
    ``"boundary"`` (default) exchanges only the packed boundary payload —
    the halo slab width Bl is the ``bnd`` operand's second dim
    (``ShardLayout.bnd``; 0 = no cross-shard edges, zero wire bytes) and
    ``wire_colors`` the *uncapped* provable Delta+1 bound sizing the packed
    entries (never the ``color_bound``-capped table capacity: a capped
    table can still assign any color up to Delta+1). ``"full"`` gathers the
    whole [Vp] packed vector every non-frontier round; the ``bnd`` operand
    is still threaded (shapes stay wire-independent) but unused.
    """
    backend = get_backend(engine)
    if backend.needs_ell and ell_width <= 0:
        raise ValueError(f"engine={backend.name!r} needs ell_width (the max "
                         "degree across owned vertices) — color_distributed "
                         "wires it from the host graph automatically")
    if backend.needs_color_bound and max_colors <= 0:
        raise ValueError(
            f"engine={backend.name!r} needs max_colors (global Delta+1) — "
            "color_distributed wires it from the host graph automatically")
    axis_names = tuple(mesh.axis_names)
    D = int(np.prod(mesh.devices.shape))
    body = functools.partial(
        _bsp_local, axis_names=axis_names, verts_local=verts_local,
        num_devices=D, local_concurrency=local_concurrency,
        max_rounds=max_rounds, max_sweeps=max_sweeps, backend=backend,
        max_colors=max_colors, ell_width=ell_width,
        frontier_cap_v=frontier_cap_v, frontier_cap_e=frontier_cap_e,
        wire=wire, wire_colors=wire_colors)
    spec_in = P(axis_names, None)
    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(spec_in, spec_in, spec_in),
        out_specs=(P(axis_names, None), P(axis_names), P(axis_names, None),
                   P(axis_names, None), P(axis_names, None)),
    )

    def run(lsrc, ldst, bnd):
        colors, rnd, conf, sweeps, fronts = smapped(lsrc, ldst, bnd)
        return (colors, rnd.max(), conf.max(axis=0), sweeps.max(axis=0),
                fronts.max(axis=0))

    return jax.jit(run)


def color_distributed(graph, mesh: Mesh, local_concurrency: int = 1,
                      max_rounds: int = 64, engine: EngineSpec = "sort",
                      color_bound: int = 0, model: str = "d1"):
    """End-to-end: partition on host, color on the mesh, return colors [V]
    (``[num_left]`` under ``model="pd2"``).

    Back-compat shim over the registered ``"distributed"``
    :class:`repro.core.api.ColoringStrategy` (which owns the
    partition/build/run sequence); kept for its legacy return shape
    ``(colors, rounds, conflicts_per_round)``. Prefer
    ``repro.core.color(graph, strategy="distributed", mesh=mesh)`` — same
    machinery, richer :class:`repro.core.api.ColoringReport` (sweeps,
    wall time), and ``ordering=`` support.

    ``model`` selects the coloring semantics ("d1" | "d2" | "pd2", the
    latter taking a :class:`repro.core.graph.BipartiteGraph`): the host
    graph is lowered to its constraint graph (repro.core.distance2) and the
    BSP machinery runs on that unchanged. The boundary exchange widens to
    two-hop halos *structurally*: partitioning (and hence the
    interior/boundary split) happens on the *constraint* graph, so a vertex
    two hops away in the input graph is one constraint edge away — already
    in the boundary set if it crosses shards. D2's wider stencil changes
    only which constraint edges exist, never the wire protocol — no new
    collective, no second exchange (DESIGN.md §Models).

    ``color_bound`` optionally caps the table-backend color capacity below
    the provable Delta+1 bound (greedy on the paper's graphs uses <= 143
    colors while Delta reaches 10^4+ on skewed R-MAT, so the provable bound
    wastes Theta(V*Delta) table memory per sweep; under ``model="d2"``
    Delta is the even larger *squared-graph* degree). It is a
    caller-asserted bound: colors at or above it lose their forbids
    silently, so only cap when the chromatic behavior of the graph family
    is known. This is also what makes the dry-run's
    ``ColoringConfig.color_bound`` program reproducible here at runtime."""
    from .api import ColoringSpec, get_strategy  # lazy: api imports us
    spec = ColoringSpec(strategy="distributed", model=model, engine=engine,
                        max_rounds=max_rounds, max_sweeps=16384,
                        color_bound=int(color_bound), mesh=mesh,
                        local_concurrency=local_concurrency)
    raw = get_strategy("distributed").oneshot(spec, graph)
    return (np.asarray(raw.colors), int(raw.rounds),
            np.asarray(raw.conflicts_per_round))
