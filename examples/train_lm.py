"""End-to-end training example: the ~130M-param mamba2-130m (the assigned
SSM arch) on the deterministic synthetic pipeline, with checkpoints.

Default invocation is CPU-sized; the full few-hundred-step run is
    PYTHONPATH=src python examples/train_lm.py --steps 300 --seq 512
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_mamba2")
    args = ap.parse_args()
    losses = train.main([
        "--arch", "mamba2-130m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25",
        "--lr", "6e-4",
    ])
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
