"""Fault-tolerant checkpointing: atomic, re-shardable, async-capable.

Layout::

    <dir>/step_000123/arrays.npz     flat {encoded-path: array}
    <dir>/step_000123/manifest.json  step, keys, shapes, dtypes, checksum,
                                     optional caller metadata (``meta=``)
    <dir>/LATEST                     text file, updated last (commit point)

Guarantees used by the elastic-restart story (DESIGN.md §6):
  * atomicity — tmp-dir write + rename; LATEST only advances after fsync,
    so a preempted writer never corrupts the previous checkpoint;
  * re-shardability — restore takes ``shardings`` and device_puts each leaf
    with the *new* mesh's NamedSharding, so a job may restart on a different
    device count / mesh shape;
  * retention — keep-last-k pruning;
  * async — snapshot to host (device_get) synchronously, write in a
    background thread (training continues).

Not train-specific: any nested dict/list tree of arrays checkpoints
through :func:`save`. Consumers that don't hold a live prototype tree
(the coloring service restoring after a kill knows nothing but the
directory) use :func:`load`, which rebuilds a plain nested-dict tree from
the flat paths alone and returns the manifest — including the ``meta``
JSON the writer attached (specs, schema versions, ...). Dict keys must
avoid ``/`` and ``__`` (the path separator and its npz encoding)."""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import numpy as np

import jax

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(flat: Dict[str, Any], proto):
    """Rebuild a tree shaped like ``proto`` from flat path->array."""
    def build(sub, prefix=""):
        if isinstance(sub, dict):
            return {k: build(v, f"{prefix}{k}{_SEP}") for k, v in sub.items()}
        if isinstance(sub, (list, tuple)):
            t = [build(v, f"{prefix}{i}{_SEP}") for i, v in enumerate(sub)]
            return type(sub)(t)
        return flat[prefix[:-1]]
    return build(proto)


def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def save(root: str, step: int, tree, *, keep: int = 3,
         async_write: bool = False,
         meta: Optional[dict] = None) -> threading.Thread | None:
    """Checkpoint ``tree`` (any nested dict/list of arrays) at ``step``.

    ``meta``: optional JSON-able dict stored in the manifest and returned
    by :func:`load` — the place for non-array state (serialized specs,
    schema versions) that must survive alongside the arrays."""
    os.makedirs(root, exist_ok=True)
    flat = _flatten(tree)
    bad = [k for k in flat if "__" in k]
    if bad:
        raise ValueError(f"checkpoint keys must not contain '__' (the npz "
                         f"path encoding): {bad[:3]}")
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # npz can't represent ml_dtypes (bf16 etc.) — store a byte-compatible
    # view and record the true dtype in the manifest for restore
    true_dtypes = {k: str(v.dtype) for k, v in host.items()}
    host = {k: (v.view(np.uint16) if v.dtype.itemsize == 2 and
                v.dtype.kind == "V" or str(v.dtype) == "bfloat16" else v)
            for k, v in host.items()}

    def write():
        tmp = step_dir(root, step) + ".tmp"
        final = step_dir(root, step)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "__"): v for k, v in host.items()})
        digest = hashlib.sha256()
        for k in sorted(host):
            digest.update(k.encode())
            digest.update(host[k].tobytes())
        manifest = {
            "step": step,
            "keys": sorted(host.keys()),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": true_dtypes,
            "checksum": digest.hexdigest(),
        }
        if meta is not None:
            manifest["meta"] = meta
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest = os.path.join(root, "LATEST")
        with open(latest + ".tmp", "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.rename(latest + ".tmp", latest)
        _prune(root, keep)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _prune(root: str, keep: int):
    steps = all_steps(root)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(step_dir(root, s), ignore_errors=True)


def all_steps(root: str):
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    latest = os.path.join(root, "LATEST")
    if os.path.exists(latest):
        with open(latest) as f:
            s = int(f.read().strip())
        if os.path.isdir(step_dir(root, s)):
            return s
    steps = all_steps(root)
    return steps[-1] if steps else None


def restore(root: str, proto, *, step: Optional[int] = None,
            shardings=None, verify: bool = True):
    """Load a checkpoint into the structure of ``proto``.

    ``shardings``: optional matching tree of NamedSharding — each leaf is
    device_put with the *current* mesh (elastic restart onto a different
    topology). Returns (tree, step).
    """
    flat, manifest, step = _load_flat(root, step, verify)
    tree = _unflatten_into(flat, proto)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step


def _load_flat(root: str, step: Optional[int], verify: bool):
    """Shared loader: flat path->array dict + manifest, checksum-verified,
    dtypes restored (bf16 stand-ins viewed back)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = step_dir(root, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(d, "arrays.npz"))
    flat = {k.replace("__", "/"): npz[k] for k in npz.files}
    if verify:
        digest = hashlib.sha256()
        for k in sorted(flat):
            digest.update(k.encode())
            digest.update(flat[k].tobytes())
        if digest.hexdigest() != manifest["checksum"]:
            raise IOError(f"checkpoint {d} failed checksum verification")
    # view 2-byte stand-ins back to their true dtypes (bf16 etc.)
    import ml_dtypes
    for k, dt in manifest.get("dtypes", {}).items():
        if k in flat and str(flat[k].dtype) != dt:
            flat[k] = flat[k].view(np.dtype(dt))
    return flat, manifest, step


def load(root: str, *, step: Optional[int] = None, verify: bool = True):
    """Structure-free restore: rebuild a nested **dict** tree from the flat
    paths alone (list/tuple nodes written by :func:`save` come back as
    dicts keyed by their stringified index) and return
    ``(tree, manifest, step)`` — ``manifest["meta"]`` carries whatever the
    writer attached. The restart path for consumers that hold no live
    prototype (e.g. a killed coloring service)."""
    flat, manifest, step = _load_flat(root, step, verify)
    tree: dict = {}
    for path, arr in flat.items():
        node = tree
        parts = path.split(_SEP)
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return tree, manifest, step
