from .optimizer import AdamWConfig, init_opt_state, abstract_opt_state, adamw_update
from .train_step import make_train_step, TrainStepConfig
from . import checkpoint, data

__all__ = [
    "AdamWConfig", "init_opt_state", "abstract_opt_state", "adamw_update",
    "make_train_step", "TrainStepConfig", "checkpoint", "data",
]
