"""Halo exactness — interior-unreferencability as a static proof (HALO).

PR 9's boundary wire is exact because of a *hypothesis* the runtime tests
check pointwise: remote **interior** state is never referenced — every
cross-shard read lands on a boundary (or frontier-slab) vertex, and the
conflict pass reads the gathered payload only through the patched ``[Vp]``
snapshot view. This pass promotes that to a dataflow-reachability proof
over the traced mesh program, in two halves:

* **payload side** (HALO201) — every per-round ``all_gather`` inside the
  boundary-wire round loop must ship a *selection*: its operand element
  count must stay below the full local state width ``Vl`` (the packed
  halo words and the frontier slab both do; a mutation that routes the
  un-selected color vector onto the wire does not);
* **read side** (HALO202) — forward taint from the per-round gather
  outputs: the raw payload may flow into the carried snapshot/pending
  views only via scatters into ``[Vp]``-sized buffers (the sanctioned
  patch — including the index-normalization compares those scatters
  lower to). Any other path to an equality compare (the conflict
  predicate is ``color == color``) or to a scatter into a non-``[Vp]``
  buffer (the mex/forbid tables) would make raw remote state — interior
  entries included — referenceable, and is an error.

The proof is per-round: a value read from the *carried* snapshot is last
round's already-patched view, which is exactly the algorithm's contract
(DESIGN.md §Distributed), so carriers enter each round untainted.
HALO101 records the successful proof (gather count, payload widths).
"""
from __future__ import annotations

from typing import List, Optional, Set, Tuple

from .findings import Finding
from .jaxpr_walk import Literal, site_of
from .spmd import (SpmdGeometry, aval_elems, cond_branches,
                   find_shard_jaxprs, iter_round_loops, sub_jaxpr,
                   while_parts)

# the conflict predicate is an equality test on colors; lt/ge etc. appear
# in the (sanctioned) scatter index normalization, so only eq/ne are sinks
_COMPARE_SINKS = frozenset({"eq", "ne"})
_SCATTER_PRIMS = frozenset({
    "scatter", "scatter-add", "scatter-max", "scatter-min", "scatter-mul",
    "scatter-and", "scatter-or",
})


class _Taint:
    """Per-scope raw-payload taint with violation collection."""

    def __init__(self, Vp: int, context: str):
        self.Vp = Vp
        self.context = context
        self.violations: List[Finding] = []
        # (eqn, operand elems, cond-branch index or None): branch 1 of an
        # in-loop gathering cond is the slab wire — its payload is a
        # frontier selection whose capacity may legitimately reach Vl
        self.gathers: List[Tuple[object, int, Optional[int]]] = []


def _run(jaxpr, in_taint: List[bool], t: _Taint,
         branch: Optional[int] = None) -> List[bool]:
    tainted: Set[object] = {v for v, tt in zip(jaxpr.invars, in_taint) if tt}

    def is_t(v) -> bool:
        return (not isinstance(v, Literal)) and v in tainted

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "all_gather":
            t.gathers.append(
                (eqn, sum(aval_elems(v) for v in eqn.invars), branch))
            tainted.update(eqn.outvars)
            continue
        if prim in ("psum", "pmin", "pmax"):
            continue  # votes: reduced aggregates, not raw payload
        if prim in _SCATTER_PRIMS:
            operand, indices, updates = (eqn.invars + [None, None])[:3]
            data_tainted = is_t(indices) or is_t(updates)
            if data_tainted:
                op_elems = aval_elems(operand) if operand is not None else 0
                if op_elems == t.Vp:
                    # the sanctioned snapshot/pending patch: raw payload
                    # lands at gathered ids in the [Vp] view; downstream
                    # reads see the patched buffer, not the raw wire
                    continue
                t.violations.append(Finding(
                    "HALO202", site_of(eqn),
                    f"raw gathered payload written into a "
                    f"{tuple(operand.aval.shape)} buffer (not the [Vp]="
                    f"[{t.Vp}] snapshot view): remote state becomes "
                    f"referenceable outside the patch", t.context))
                tainted.update(eqn.outvars)
                continue
            if is_t(operand):
                tainted.update(eqn.outvars)
            continue
        if prim in _COMPARE_SINKS:
            if any(is_t(v) for v in eqn.invars):
                t.violations.append(Finding(
                    "HALO202", site_of(eqn),
                    "raw gathered payload reaches an equality compare (the "
                    "conflict-predicate class) without passing the [Vp] "
                    "snapshot patch", t.context))
                tainted.update(eqn.outvars)
            continue
        if prim == "cond":
            outs = [False] * len(eqn.outvars)
            for idx, b in enumerate(cond_branches(eqn)):
                bouts = _run(b, [is_t(v) for v in eqn.invars[1:]], t,
                             branch=idx)
                outs = [a or bb for a, bb in zip(outs, bouts)]
            for v, tt in zip(eqn.outvars, outs):
                if tt:
                    tainted.add(v)
            continue
        if prim == "while":
            # nested fixpoint sweeps: carriers enter untainted only if the
            # init values are untainted; conservative — taint everything
            # the loop touches when any input is tainted
            _, body, cn, bn = while_parts(eqn)
            in_t = [is_t(v) for v in eqn.invars]
            if body is not None:
                bouts = _run(body, in_t[cn:], t, branch=branch)
                for v, tt in zip(eqn.outvars, bouts):
                    if tt:
                        tainted.add(v)
            continue
        sub = sub_jaxpr(eqn.params.get("jaxpr",
                                       eqn.params.get("call_jaxpr")))
        if sub is not None and prim != "pallas_call":
            bouts = _run(sub, [is_t(v) for v in eqn.invars], t,
                         branch=branch)
            for v, tt in zip(eqn.outvars, bouts):
                if tt:
                    tainted.add(v)
            continue
        if any(is_t(v) for v in eqn.invars):
            tainted.update(eqn.outvars)
    return [is_t(v) for v in jaxpr.outvars]


def check_halo_exactness(closed_jaxpr, geometry: SpmdGeometry, *,
                         context: str = "") -> List[Finding]:
    """The exactness proof, run over every round loop of every shard_map
    program in ``closed_jaxpr``. Only meaningful for the boundary wire
    (the full tier ships everything by design and is exempt)."""
    g = geometry
    if g.wire != "boundary":
        return []
    findings: List[Finding] = []
    Vl = g.verts_local
    for shard_eqn, body in find_shard_jaxprs(closed_jaxpr):
        for wl in iter_round_loops(body):
            _, wbody, _, _ = while_parts(wl)
            if wbody is None:
                continue
            t = _Taint(g.verts_global, context)
            # carriers enter each round untainted: the carried snapshot is
            # LAST round's patched view, legitimately readable everywhere
            _run(wbody, [False] * len(wbody.invars), t)
            if not t.gathers:
                continue
            wide: List[Finding] = []
            for eqn, op_elems, br in t.gathers:
                # the slab branch (1 = predicate-true of a gathering cond)
                # ships frontier selections bounded by cap_v, which may
                # legitimately reach Vl on tiny envelopes
                limit = max(Vl, g.frontier_cap_v + 1) if br == 1 else Vl
                if op_elems >= limit:
                    wide.append(Finding(
                        "HALO201", site_of(eqn),
                        f"per-round payload ships {op_elems} entries >= "
                        f"{limit} (Vl={Vl}): the full local state "
                        "(interior entries included) crosses the wire — "
                        "the boundary selection was bypassed", context))
            findings.extend(wide)
            findings.extend(t.violations)
            if not t.violations and not wide:
                widths = ",".join(str(op) for _, op, _ in t.gathers)
                findings.append(Finding(
                    "HALO101", site_of(wl, "core/distributed.py:_bsp_local"),
                    f"exactness proven: {len(t.gathers)} per-round "
                    f"gather(s) (operand widths {widths}, all boundary/"
                    f"slab selections below Vl={Vl}); raw payload reaches "
                    "no conflict compare or foreign table — every read "
                    "routes through the [Vp] snapshot patch", context))
    return findings
