"""Mixture-of-Experts with sort-based dispatch (dropping, fixed capacity).

TPU-native dispatch (DESIGN.md §2's "regularize, then go fast", same move as
the coloring kernels): tokens are *sorted* by assigned expert — O(N log N),
no [N, E·C] one-hot matmul — then scattered into a dense [E, C, d] buffer
that the expert FFNs consume as one batched einsum. Experts shard over the
"model" axis (EP) or over d_expert (TP) per ``MoEConfig.partition``.

The (src_device, dst_device) traffic implied by the dispatch is exactly what
``core/comm_schedule.py`` colors into conflict-free rounds — the paper's
technique applied to this layer's all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig, MoEConfig
from .layers import mlp_init, mlp_apply
from ..parallel.sharding import constrain


def moe_init(b, cfg: ModelConfig, moe: MoEConfig):
    d = cfg.d_model
    e_axis = "experts" if moe.partition == "expert" else None
    f_axis = "expert_mlp" if moe.partition == "expert" else "mlp"
    b.dense("router", (d, moe.num_experts), ("embed", None), scale=d ** -0.5)
    b.dense("w_gate", (moe.num_experts, d, moe.d_expert), (e_axis, "embed", f_axis))
    b.dense("w_up", (moe.num_experts, d, moe.d_expert), (e_axis, "embed", f_axis))
    b.dense("w_down", (moe.num_experts, moe.d_expert, d), (e_axis, f_axis, "embed"))
    if moe.num_shared:
        mlp_init(b.child("shared"), d, moe.num_shared * moe.d_shared, cfg.act)
    return b


def moe_apply(p, x, cfg: ModelConfig, moe: MoEConfig):
    """x: [B, T, d] -> ([B, T, d], aux_loss).

    ROW-LOCAL dispatch (§Perf H-B1): routing, sort, scatter and combine are
    batched per sequence row, so under SPMD they stay inside each batch
    shard — the only cross-device movement is the [B(data), E(model), C, d]
    buffer resharding, i.e. exactly the EP all-to-all. (The earlier global-
    argsort dispatch replicated an [E, N*k*cf/E, d] buffer on every device:
    measured 342 GiB/chip temp on deepseek train_4k.) Capacity is per row
    (T*k/E*cf); dropped tokens ride the residual.
    """
    bsz, t, d = x.shape
    e, k = moe.num_experts, moe.top_k
    nk = t * k
    cap = int(t * k / e * moe.capacity_factor + 1)
    dt = x.dtype

    logits = jnp.einsum("btd,de->bte", x, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # [B, T, E]
    top_p, top_i = lax.top_k(probs, k)                           # [B, T, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- row-local sort-based dispatch (all ops batched over B)
    flat_e = top_i.reshape(bsz, nk).astype(jnp.int32)            # [B, T*K]
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)[None], (bsz, nk))
    flat_w = top_p.reshape(bsz, nk)
    order = jnp.argsort(flat_e, axis=-1)
    e_s = jnp.take_along_axis(flat_e, order, axis=-1)
    t_s = jnp.take_along_axis(flat_t, order, axis=-1)
    w_s = jnp.take_along_axis(flat_w, order, axis=-1)
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e, dtype=jnp.int32),
                                     side="left"))(e_s)          # [B, E]
    pos = jnp.arange(nk, dtype=jnp.int32)[None] - jnp.take_along_axis(
        seg_start, e_s, axis=-1)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                            # cap -> dropped

    b_idx = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    x_disp = jnp.take_along_axis(x, t_s[..., None], axis=1)      # [B, T*K, d]
    # The buffer stays expert-REPLICATED (its inputs already are, so this is
    # free); the E-sharded expert weights localize the FFN per model shard
    # and only the OUTPUT buffer is gathered back (§Perf H-B2 — constraining
    # the scatter output to E-sharded instead forced the partitioner into a
    # replicated scatter + reshard, measured worse than baseline).
    buf = jnp.zeros((bsz, e, cap, d), dt).at[b_idx, e_s, pos_c].set(
        x_disp, mode="drop")
    buf = constrain(buf, ("batch", None, None, None))

    # ---- expert FFN (batched over rows x experts)
    wg = p["w_gate"].astype(dt)
    wu = p["w_up"].astype(dt)
    wd = p["w_down"].astype(dt)
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg)) \
            * jnp.einsum("becd,edf->becf", buf, wu)
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", buf, wu))
    h = constrain(h, ("batch", "experts", None, None))
    out_buf = jnp.einsum("becf,efd->becd", h, wd)
    out_buf = constrain(out_buf, ("batch", None, None, None))  # gather E back

    # ---- combine (gather back + weighted scatter-add, row-local)
    gathered = out_buf.at[b_idx, e_s, pos_c].get(
        mode="fill", fill_value=0)                               # [B, T*K, d]
    weighted = gathered * (w_s * keep)[..., None].astype(dt)
    out = jnp.zeros((bsz, t, d), dt).at[b_idx, t_s].add(weighted)

    if moe.num_shared:
        out = out + mlp_apply(
            {k2: v.astype(dt) for k2, v in p["shared"].items()}, x, cfg.act)

    # ---- load-balance auxiliary loss (Switch-style, global means)
    frac_tokens = (jnp.zeros((e,), jnp.float32)
                   .at[flat_e.reshape(-1)].add(1.0) / (bsz * nk))
    frac_probs = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs) * moe.router_aux_weight
    return out, aux
