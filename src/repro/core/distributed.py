"""Distributed BSP coloring via shard_map — the Bozdag et al. [6] framework
(the paper's ITERATIVE ancestor) mapped onto a JAX device mesh.

Vertices are block-partitioned across all mesh devices. Each BSP round:

  1. ``all_gather`` committed colors (pending masked 0) — the boundary-color
     exchange of the distributed framework, fused into one collective;
  2. local speculative greedy over the device's pending vertices. With local
     concurrency ``C=1`` (default) each device colors its pending set
     *sequentially* — exactly the distributed-memory algorithm — realized as
     the chaotic fixpoint of the local offset-precedence dataflow equations
     (converges in local-DAG-depth sweeps, no communication inside);
     cross-device pending neighbors are speculated against (not forbidden);
  3. ``all_gather`` of committed colors + pending flags;
  4. conflict detection: monochromatic same-round pairs — with C=1 these are
     exclusively *boundary* (cross-device) conflicts, as in [6]; the higher
     global index recolors;
  5. ``psum`` termination vote.

The whole multi-round algorithm is one ``lax.while_loop`` inside shard_map,
so it lowers/compiles as a single XLA program on the production meshes —
`launch/dryrun.py` exercises it via the rmat_coloring config.
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from .graph import Graph
from .mex import segment_mex


def partition_graph(graph: Graph, num_devices: int):
    """Host-side partitioning into per-device fixed-shape edge slabs.

    Returns (lsrc [D, El], ldst [D, El], verts_per_device). Device d owns
    global vertices [d*Vl, (d+1)*Vl); lsrc holds *local* ids (pad = Vl),
    ldst holds *global* ids (pad = Vl*D).
    """
    D = num_devices
    V = graph.num_vertices
    Vl = -(-V // D)
    Vp = Vl * D
    src, dst = graph.directed_edges()  # src sorted
    owner = src // Vl
    counts = np.bincount(owner, minlength=D)
    El = max(1, int(counts.max()))
    lsrc = np.full((D, El), Vl, np.int32)
    ldst = np.full((D, El), Vp, np.int32)
    offsets = np.zeros(D + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    for d in range(D):
        sl = slice(offsets[d], offsets[d + 1])
        k = offsets[d + 1] - offsets[d]
        lsrc[d, :k] = src[sl] - d * Vl
        ldst[d, :k] = dst[sl]
    return lsrc, ldst, Vl


def _bsp_local(lsrc, ldst, *, axis_names: Tuple[str, ...], verts_local: int,
               num_devices: int, local_concurrency: int, max_rounds: int,
               max_sweeps: int):
    """Per-device body (runs under shard_map).

    Wire format (§Perf H-C1): ONE int16 all_gather per round carrying
    ``color << 1 | pending`` — the committed snapshot for the NEXT round's
    phase 1 and the conflict-detection view of THIS round are both decodable
    from it, replacing the two int32 + one bool gathers of the naive BSP
    round (measured 4.4x collective-byte reduction). Colors must stay below
    2^14 (greedy uses <= Delta+1; the paper's graphs use <= 143).
    """
    Vl = verts_local
    Vp = Vl * num_devices
    C = local_concurrency
    lsrc = lsrc.reshape(-1)
    ldst = ldst.reshape(-1)
    didx = lax.axis_index(axis_names).astype(jnp.int32)
    base = didx * Vl
    gsrc = jnp.where(lsrc < Vl, lsrc + base, Vp)
    dst_local = (ldst >= base) & (ldst < base + Vl)
    dst_loc = jnp.where(dst_local, ldst - base, Vl)  # local id or pad
    syn_v = jnp.arange(Vl, dtype=jnp.int32)
    syn_c = jnp.zeros((Vl,), jnp.int32)
    lsrc_safe = jnp.minimum(lsrc, Vl)

    def gather(x):
        return lax.all_gather(x, axis_names, tiled=True)

    def pv(x):
        # mark as device-varying so while_loop carries type-check under
        # shard_map's varying-manual-axes tracking
        return lax.pvary(x, axis_names)

    def round_body(state):
        colors, pending, packed_glob, rnd, conf_hist, _ = state
        # (1) decode last round's wire. ALL nonzero colors forbid — including
        # stale colors of re-pending vertices: over-forbidding never breaks
        # validity (it slightly biases re-colored vertices away from the
        # contested color, which helps) and it lets one gather per round
        # serve both phase 1 and conflict detection (§Perf H-C2).
        snap = packed_glob.astype(jnp.int32) >> 1               # [Vp]
        snap_pad = jnp.concatenate([snap, jnp.zeros((1,), jnp.int32)])
        ppad = jnp.concatenate([pending, jnp.zeros((1,), jnp.bool_)])

        # local lockstep offsets (C virtual threads per device)
        r = pending.sum(dtype=jnp.int32)
        bs = lax.div(r + C - 1, C)
        rank = jnp.cumsum(pending.astype(jnp.int32)) - 1
        offset = jnp.where(pending, rank % jnp.maximum(bs, 1), 0).astype(jnp.int32)
        opad = jnp.concatenate([offset, jnp.full((1,), jnp.iinfo(jnp.int32).max, jnp.int32)])

        src_pending = ppad[lsrc_safe] & (lsrc < Vl)
        nbr_local_pending = ppad[dst_loc]  # local *and* pending
        precede = nbr_local_pending & (opad[dst_loc] < opad[lsrc_safe])
        key_v = jnp.where(src_pending, lsrc, Vl)

        # (2) local sequential greedy as an offset-DAG fixpoint (no comms)
        def sweep(s):
            cwork, _, n = s
            cpad_loc = jnp.concatenate([cwork, jnp.zeros((1,), jnp.int32)])
            contrib = jnp.where(precede, cpad_loc[dst_loc], snap_pad[ldst])
            key_c = jnp.where(src_pending, contrib, 0)
            mex = segment_mex(
                jnp.concatenate([key_v, syn_v]),
                jnp.concatenate([key_c, syn_c]), Vl)
            c_new = jnp.where(pending, mex, cwork)
            return c_new, jnp.any(c_new != cwork), n + 1

        def sweep_cond(s):
            _, changed, n = s
            return jnp.logical_and(changed, n < max_sweeps)

        c0 = jnp.where(pending, 0, colors)
        colors, _, _ = lax.while_loop(
            sweep_cond, sweep,
            (c0, pv(jnp.asarray(True)), pv(jnp.asarray(0, jnp.int32))))

        # (3) single fused wire: color<<1 | was-pending-this-round (int16)
        packed_local = ((colors << 1) | pending.astype(jnp.int32)).astype(jnp.int16)
        packed_glob = gather(packed_local)                      # [Vp] int16
        cglob2 = (packed_glob.astype(jnp.int32) >> 1)
        aglob2 = (packed_glob & 1).astype(jnp.bool_)
        cgpad = jnp.concatenate([cglob2, jnp.zeros((1,), jnp.int32)])
        agpad = jnp.concatenate([aglob2, jnp.zeros((1,), jnp.bool_)])

        # (4) same-round conflicts (boundary + same-offset); higher gid recolors
        conf_e = (src_pending & agpad[ldst]
                  & (cgpad[gsrc] == cgpad[ldst]) & (gsrc > ldst))
        new_pending = (jnp.zeros((Vl,), jnp.int32)
                       .at[lsrc].max(conf_e.astype(jnp.int32), mode="drop")
                       .astype(jnp.bool_))
        # (5) global termination vote
        total = lax.psum(new_pending.sum(dtype=jnp.int32), axis_names)
        conf_hist = conf_hist.at[rnd].set(total)
        return colors, new_pending, packed_glob, rnd + 1, conf_hist, total

    def cond(state):
        _, _, _, rnd, _, total = state
        return jnp.logical_and(total > 0, rnd < max_rounds)

    init = (pv(jnp.zeros((Vl,), jnp.int32)), pv(jnp.ones((Vl,), jnp.bool_)),
            pv(jnp.ones((Vp,), jnp.int16)),  # all uncolored+pending
            pv(jnp.asarray(0, jnp.int32)), pv(jnp.zeros((max_rounds,), jnp.int32)),
            jnp.asarray(1, jnp.int32))  # psum output is axis-invariant
    colors, pending, packed_glob, rnd, conf_hist, _ = lax.while_loop(
        cond, round_body, init)
    return colors[None], rnd[None], conf_hist[None]


def build_distributed_coloring(mesh: Mesh, verts_local: int, edges_local: int,
                               local_concurrency: int = 1,
                               max_rounds: int = 64, max_sweeps: int = 16384):
    """Build the jitted shard_map coloring program for a mesh.

    Returns ``fn(lsrc [D, El], ldst [D, El]) -> (colors [D, Vl], rounds,
    conflicts_per_round)``; inputs/outputs sharded over all mesh axes.
    Static shapes, so the identical program serves dry-run lowering.
    """
    axis_names = tuple(mesh.axis_names)
    D = int(np.prod(mesh.devices.shape))
    body = functools.partial(
        _bsp_local, axis_names=axis_names, verts_local=verts_local,
        num_devices=D, local_concurrency=local_concurrency,
        max_rounds=max_rounds, max_sweeps=max_sweeps)
    spec_in = P(axis_names, None)
    smapped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(spec_in, spec_in),
        out_specs=(P(axis_names, None), P(axis_names), P(axis_names, None)),
    )

    def run(lsrc, ldst):
        colors, rnd, conf = smapped(lsrc, ldst)
        return colors, rnd.max(), conf.max(axis=0)

    return jax.jit(run)


def color_distributed(graph: Graph, mesh: Mesh, local_concurrency: int = 1,
                      max_rounds: int = 64):
    """End-to-end: partition on host, color on the mesh, return colors [V]."""
    D = int(np.prod(mesh.devices.shape))
    lsrc, ldst, Vl = partition_graph(graph, D)
    fn = build_distributed_coloring(mesh, Vl, lsrc.shape[1],
                                    local_concurrency, max_rounds)
    with jax.set_mesh(mesh):
        colors, rounds, conf = fn(jnp.asarray(lsrc), jnp.asarray(ldst))
    colors = np.asarray(colors).reshape(-1)[: graph.num_vertices]
    return colors, int(rounds), np.asarray(conf)
