"""Core neural layers: RMSNorm, RoPE, chunked flash attention (global/local/
causal/softcapped), GQA via grouped einsum, cache attention for decode, MLA
(DeepSeek multi-head latent attention) with compressed cache + absorbed
decode, and SwiGLU/GELU MLPs.

Everything is a pure function over (params, activations); attention never
materializes the full [Tq, Tk] score matrix — q and kv are both chunked with
an online-softmax accumulator (flash-style), which is what makes the 32k
prefill cells fit the per-chip HBM budget in the dry-run.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..parallel.sharding import constrain

_NEG = -1e30


def rms_norm(x, w, eps: float = 1e-6):
    """RMSNorm with fp32 *reduction* but bf16 data path: only the [..., 1]
    inverse-rms is fp32, so no [B, T, D] fp32 boundary tensors appear in
    forward or backward (§Perf H-A3: the fp32 residual-sized collectives in
    the backward pass came from the old all-fp32 formulation)."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = lax.rsqrt(ms + eps).astype(x.dtype)
    return x * scale * (1.0 + w.astype(x.dtype))


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding, split-half convention. x: [..., T, H, D], positions [..., T]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angle = positions[..., :, None, None].astype(jnp.float32) * freq  # [..., T, 1, half]
    cos, sin = jnp.cos(angle), jnp.sin(angle)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(s, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(s / cap) * cap
    return s


def flash_attention(
    q, k, v, *,
    q_positions, kv_positions,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    scale: Optional[float] = None,
):
    """Chunked online-softmax attention with GQA grouping.

    q: [B, Tq, H, D]; k, v: [B, Tk, KH, Dk/Dv] with H % KH == 0. Never forms
    [Tq, Tk]; peak score block is [B, KH, G, q_chunk, kv_chunk] fp32.
    Returns [B, Tq, H, Dv].
    """
    b, tq, h, d = q.shape
    _, tk, kh, dk = k.shape
    dv = v.shape[-1]
    g = h // kh
    scale = scale if scale is not None else d ** -0.5

    qc = min(q_chunk, tq)
    kc = min(kv_chunk, tk)
    tq_p = -(-tq // qc) * qc
    tk_p = -(-tk // kc) * kc
    qp = jnp.pad(q, ((0, 0), (0, tq_p - tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, tk_p - tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, tk_p - tk), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, (0, tq_p - tq), constant_values=-1)
    kpos = jnp.pad(kv_positions, (0, tk_p - tk), constant_values=jnp.iinfo(jnp.int32).max)

    # [nq, B, KH, G, qc, D] / [nk, B, KH, kc, D]. Pin the KV-head sharding on
    # the chunked operands so the q/kv loops stay collective-free — without
    # this the SPMD partitioner re-gathers operands INSIDE the (remat'd
    # backward) chunk loops, multiplying collective traffic by nq x nk
    # (§Perf H-A2).
    qb = (qp.reshape(b, tq_p // qc, qc, kh, g, d)
          .transpose(1, 0, 3, 4, 2, 5))
    kb = kp.reshape(b, tk_p // kc, kc, kh, dk).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(b, tk_p // kc, kc, kh, dv).transpose(1, 0, 3, 2, 4)
    qb = constrain(qb, (None, "batch", "kv_heads", None, None, None))
    kb = constrain(kb, (None, "batch", "kv_heads", None, None))
    vb = constrain(vb, (None, "batch", "kv_heads", None, None))
    qpb = qpos.reshape(tq_p // qc, qc)
    kpb = kpos.reshape(tk_p // kc, kc)

    def q_step(qi):
        q_i, qpos_i = qi  # [B, KH, G, qc, D], [qc]

        def kv_step(carry, kv):
            m, l, o = carry
            k_j, v_j, kpos_j = kv
            s = jnp.einsum("bkgqd,bksd->bkgqs", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            valid = jnp.ones((qpos_i.shape[0], kpos_j.shape[0]), jnp.bool_)
            if causal:
                valid &= kpos_j[None, :] <= qpos_i[:, None]
            if window and window > 0:
                valid &= (qpos_i[:, None] - kpos_j[None, :]) < window
            s = jnp.where(valid[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, kh, g, q_i.shape[3]), _NEG, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_i.shape[3]), jnp.float32)
        o0 = jnp.zeros((b, kh, g, q_i.shape[3], dv), jnp.float32)
        (m, l, o), _ = lax.scan(kv_step, (m0, l0, o0), (kb, vb, kpb))
        return o / jnp.maximum(l, 1e-30)[..., None]

    out = lax.map(q_step, (qb, qpb))  # [nq, B, KH, G, qc, Dv]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, tq_p, h, dv)
    return out[:, :tq].astype(q.dtype)


def local_attention(q, k, v, *, window: int, q_positions, softcap: float = 0.0):
    """Banded causal self-attention, O(T·window) FLOPs.

    Processes w-sized query blocks against (previous + own) key blocks, so
    arbitrarily long sequences cost 2·w keys per query block — this is what
    makes gemma2/recurrentgemma local layers sub-quadratic.
    """
    b, t, h, d = q.shape
    w = window
    tp = -(-t // w) * w
    n = tp // w

    def blocks(x):
        x = jnp.pad(x, ((0, 0), (0, tp - t)) + ((0, 0),) * (x.ndim - 2))
        return x.reshape(b, n, w, *x.shape[2:])

    qb, kb, vb = blocks(q), blocks(k), blocks(v)
    pos = jnp.pad(q_positions, (0, tp - t), constant_values=-1).reshape(n, w)
    # key positions pad with +inf so padded keys never pass the causal mask
    # (the -1 query pad is harmless: padded outputs are sliced off)
    posk_all = jnp.pad(q_positions, (0, tp - t),
                       constant_values=jnp.iinfo(jnp.int32).max - 1).reshape(n, w)
    # previous block (zeros/invalid for the first)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    pprev = jnp.concatenate(
        [jnp.full((1, w), jnp.iinfo(jnp.int32).max - 1, pos.dtype), posk_all[:-1]],
        axis=0)

    def one_block(args):
        q_i, k_i, v_i, kp_i, vp_i, posq, posk, pospk = args
        kk = jnp.concatenate([kp_i, k_i], axis=1)
        vv = jnp.concatenate([vp_i, v_i], axis=1)
        pk = jnp.concatenate([pospk, posk], axis=0)
        return flash_attention(
            q_i, kk, vv, q_positions=posq, kv_positions=pk,
            causal=True, window=w, softcap=softcap,
            q_chunk=min(1024, w), kv_chunk=min(1024, 2 * w))

    qb_ = qb.transpose(1, 0, 2, 3, 4)
    kb_ = kb.transpose(1, 0, 2, 3, 4)
    vb_ = vb.transpose(1, 0, 2, 3, 4)
    kprev_ = kprev.transpose(1, 0, 2, 3, 4)
    vprev_ = vprev.transpose(1, 0, 2, 3, 4)
    out = lax.map(one_block, (qb_, kb_, vb_, kprev_, vprev_, pos, posk_all, pprev))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, tp, h, -1)
    return out[:, :t].astype(q.dtype)


def cache_attention(q, k_cache, v_cache, *, cur_len, window: int = 0,
                    softcap: float = 0.0, scale: Optional[float] = None):
    """Single-token decode attention over a [B, S, KH, D] cache.

    The cache S dim may be sharded over the "model" mesh axis; XLA inserts
    the LSE-combine collectives (partial max/sum/out all-reduce) — the
    sequence-parallel decode scheme of DESIGN.md §6.
    """
    b, tq, h, d = q.shape
    _, s, kh, dk = k_cache.shape
    g = h // kh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, tq, kh, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, softcap)
    kv_pos = jnp.arange(s)
    valid = kv_pos[None, :] < cur_len[:, None]            # [B, S]
    if window and window > 0:
        valid &= kv_pos[None, :] >= (cur_len[:, None] - window)
    scores = jnp.where(valid[:, None, None, None, :], scores, _NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, tq, h, -1).astype(q.dtype)


def mlp_apply(p, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


def mlp_init(b, d_model: int, d_ff: int, act: str, *, ff_axis: str = "mlp",
             embed_axis: str = "embed"):
    if act == "swiglu":
        b.dense("w_gate", (d_model, d_ff), (embed_axis, ff_axis))
    b.dense("w_up", (d_model, d_ff), (embed_axis, ff_axis))
    b.dense("w_down", (d_ff, d_model), (ff_axis, embed_axis))
    return b
