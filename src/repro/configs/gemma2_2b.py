"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000,
local(4096)+global alternating, attn softcap 50 / logit softcap 30, tied
embeddings, post-norms. [arXiv:2408.00118]"""
from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense", num_layers=26, d_model=2304,
        n_heads=8, n_kv_heads=4, head_dim=256, d_ff=9216, vocab_size=256000,
        layer_pattern="local_global", local_window=4096,
        attn_softcap=50.0, logit_softcap=30.0, post_norms=True,
        tie_embeddings=True, emb_scale=True)


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", family="dense", num_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        layer_pattern="local_global", local_window=64,
        attn_softcap=50.0, logit_softcap=30.0, post_norms=True,
        tie_embeddings=True, emb_scale=True)
