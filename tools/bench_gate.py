"""Bench regression gate: compare fresh ``benchmarks/run.py --json`` output
against the committed pinned-scale baselines and fail on real regressions.

The committed ``BENCH_*.json`` baselines are produced on whatever machine
cut the PR, while the gate reruns on a CI runner of unknown speed — so
absolute ``us_per_call`` comparisons are meaningless. The gate is made
machine-invariant by normalization: every matched row's ratio
``current/baseline`` is divided by the MEDIAN ratio across all rows of all
pairs (the machine-speed factor), and each pair (one benchmark family)
fails only if the geometric mean of its normalized ratios exceeds
``1 + tolerance``. A uniform machine-speed change moves every ratio
equally and cancels; a family that got slower *relative to the others*
does not. (The median is taken across pairs precisely so a whole-family
regression cannot normalize itself away — run the gate with >= 2 pairs.)

Usage:
  python tools/bench_gate.py [--tolerance 0.25] [BASELINE:CURRENT ...]
e.g.
  python tools/bench_gate.py BENCH_engine_compare.json:fresh_engine.json \
      BENCH_frontier_compare.json:fresh_frontier.json

With no pairs, the DEFAULT GATED SET runs: every family the repo commits
a pinned-scale baseline for, against the ``fresh_<family>.json`` files a
prior ``benchmarks/run.py`` step produced (the CI bench-gate layout).
"""
from __future__ import annotations

import argparse
import json
import math
import statistics
import sys

# the default gated set: committed baseline -> fresh rerun. Every family
# added here must commit its BENCH_*.json at the pinned scale and emit
# only machine-speed-scaling us_per_call rows (serve_bench gates flush
# execution time per request, NOT its deadline-dominated latencies, which
# are machine-invariant and would poison the median normalization).
DEFAULT_PAIRS = [
    ("BENCH_engine_compare.json", "fresh_engine_compare.json"),
    ("BENCH_frontier_compare.json", "fresh_frontier_compare.json"),
    ("BENCH_serve_bench.json", "fresh_serve_bench.json"),
    ("BENCH_stream_compare.json", "fresh_stream_compare.json"),
    ("BENCH_dist_scale.json", "fresh_dist_scale.json"),
]


def load_rows(path: str) -> dict:
    """name -> us_per_call for every timed row (us_per_call > 0)."""
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in payload["rows"]
            if float(r.get("us_per_call", 0.0)) > 0.0}


def match_pairs(pairs):
    """[(baseline_path, current_path)] -> [(label, [(name, ratio)])].

    Rows present in only one side are reported but never gated on — a
    renamed row must not silently shrink the gate's coverage to nothing,
    so an empty intersection is an error."""
    out = []
    for base_path, cur_path in pairs:
        base, cur = load_rows(base_path), load_rows(cur_path)
        common = sorted(set(base) & set(cur))
        if not common:
            raise SystemExit(
                f"bench_gate: no common rows between {base_path} and "
                f"{cur_path} — wrong family or renamed rows?")
        missing = sorted(set(base) - set(cur))
        if missing:
            print(f"WARNING {base_path}: rows missing from current run "
                  f"(not gated): {missing}")
        ratios = [(n, cur[n] / base[n]) for n in common]
        out.append((base_path, ratios))
    return out


def gate(matched, tolerance: float):
    """Returns (failures, report_lines). One entry per pair: the geomean
    of median-normalized ratios vs 1 + tolerance."""
    all_ratios = [r for _, ratios in matched for _, r in ratios]
    machine = statistics.median(all_ratios)
    lines = [f"machine-speed factor (median ratio): {machine:.3f}"]
    failures = []
    for label, ratios in matched:
        norm = [r / machine for _, r in ratios]
        geo = math.exp(sum(math.log(x) for x in norm) / len(norm))
        worst_name, worst = max(ratios, key=lambda nr: nr[1] / machine)
        status = "OK" if geo <= 1.0 + tolerance else "FAIL"
        lines.append(
            f"{status:4s} {label}: normalized geomean {geo:.3f} "
            f"(limit {1.0 + tolerance:.2f}), worst row {worst_name} "
            f"at {worst / machine:.3f}")
        if status == "FAIL":
            failures.append(label)
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("pairs", nargs="*", metavar="BASELINE:CURRENT",
                    help="baseline/current JSON path pairs, colon-separated "
                         "(default: the committed gated set vs fresh_*.json)")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed normalized geomean regression (0.25 = 25%%)")
    args = ap.parse_args(argv)
    if not args.pairs:
        pairs = list(DEFAULT_PAIRS)
    else:
        pairs = []
        for p in args.pairs:
            if ":" not in p:
                ap.error(f"expected BASELINE:CURRENT, got {p!r}")
            pairs.append(tuple(p.split(":", 1)))
    failures, lines = gate(match_pairs(pairs), args.tolerance)
    print("\n".join(lines))
    if failures:
        print(f"\nbench_gate: REGRESSION in {len(failures)} famil"
              f"{'y' if len(failures) == 1 else 'ies'}: {failures}")
        return 1
    print("\nbench_gate: all families within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
