"""Prefill + decode == full teacher-forced forward, per architecture family
(the serving path's correctness contract)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_config
from repro import models

from conftest import SLOW_ARCHS, arch_params

# decode additionally crawls on mistral; grok's MoE decode drifts from the
# full forward by ~1.2 in logits at step 2 — a pre-existing model-layer bug
# independent of the coloring engine, xfailed (non-strict) so the slow CI
# lane stays meaningful
DECODE_SLOW = SLOW_ARCHS | {"mistral-nemo-12b"}
DECODE_XFAIL = {"grok-1-314b": [pytest.mark.xfail(
    reason="MoE decode/full-forward logits mismatch (pre-existing)")]}


@pytest.mark.parametrize(
    "arch", arch_params(ARCH_IDS, slow_set=DECODE_SLOW,
                        extra_marks=DECODE_XFAIL))
def test_prefill_then_decode_matches_full(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:  # capacity dropping is batch-context dependent
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params, _ = models.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, T, S = 2, 16, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm.num_image_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encdec.enc_seq, cfg.d_model)), jnp.float32)

    caches = models.init_cache(cfg, B, S)
    _, _, caches = models.forward(cfg, params, batch, caches=caches)
    assert int(caches["cur_len"][0]) == T

    # decode two tokens autoregressively; compare each against full forward
    cur_toks = toks
    for step in range(2):
        nt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)), jnp.int32)
        logits_dec, caches = models.decode_step(cfg, params, caches, nt)
        cur_toks = jnp.concatenate([cur_toks, nt[:, None]], axis=1)
        full, _, _ = models.forward(cfg, params, dict(batch, tokens=cur_toks))
        err = float(jnp.abs(logits_dec - full[:, -1]).max())
        ref = float(jnp.abs(full[:, -1]).max()) + 1e-6
        assert err < 0.05 * max(ref, 10.0), (arch, step, err, ref)


def test_decode_batch_isolated():
    """Per-sequence cur_len: decoding must not leak across batch rows."""
    cfg = get_smoke_config("qwen3-4b")
    params, _ = models.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, T, S = 2, 8, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    caches = models.init_cache(cfg, B, S)
    _, _, caches = models.forward(cfg, params, {"tokens": toks}, caches=caches)
    nt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)), jnp.int32)
    la, _ = models.decode_step(cfg, params, caches, nt)

    # swap row order; outputs must swap accordingly
    toks2 = toks[::-1]
    caches2 = models.init_cache(cfg, B, S)
    _, _, caches2 = models.forward(cfg, params, {"tokens": toks2}, caches=caches2)
    lb, _ = models.decode_step(cfg, params, caches2, nt[::-1])
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb[::-1]),
                               rtol=0, atol=2e-2)
