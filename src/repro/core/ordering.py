"""Vertex ordering techniques (paper §1, §5.1; Gebremedhin et al. [19]).

Orderings matter twice: (a) greedy color quality, (b) on cached machines,
locality — the paper deliberately *shuffles* to kill locality (§5.1). We
expose the standard menu; ``apply`` relabels a graph so that the parallel
algorithms (which always process in index order) inherit the ordering.

The ``ORDERINGS`` registry is the ordering namespace of
:class:`repro.core.api.ColoringSpec`: every entry is callable as
``fn(graph, seed) -> order`` (``order[k]`` = the vertex visited k-th), and
the spec/plan layer applies it by relabeling the constraint graph and
un-relabeling the resulting colors, so reports stay in original vertex ids.
"""
from __future__ import annotations

import heapq

import numpy as np

from .graph import Graph


def natural(graph: Graph, seed: int = 0) -> np.ndarray:
    return np.arange(graph.num_vertices, dtype=np.int64)


def random_shuffle(graph: Graph, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(graph.num_vertices).astype(np.int64)


def largest_degree_first(graph: Graph, seed: int = 0) -> np.ndarray:
    """Welsh-Powell: visit high-degree vertices first (stable tie-break)."""
    deg = graph.degrees()
    return np.argsort(-deg, kind="stable").astype(np.int64)


def smallest_degree_last(graph: Graph, seed: int = 0) -> np.ndarray:
    """Iteratively peel minimum-degree vertices; color in reverse peel order.
    Bounds colors by degeneracy+1. Lazy-deletion binary heap, O(E log V):
    decrease-key is a fresh push, and popped entries whose recorded degree
    is stale (or whose vertex is already peeled) are skipped."""
    n = graph.num_vertices
    deg = graph.degrees().astype(np.int64).copy()
    removed = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    heap = [(int(d), int(v)) for v, d in enumerate(deg)]
    heapq.heapify(heap)
    row_ptr, col_idx = graph.row_ptr, graph.col_idx
    k = n - 1
    while heap:
        d, v = heapq.heappop(heap)
        if removed[v] or d != deg[v]:
            continue
        removed[v] = True
        order[k] = v
        k -= 1
        for w in col_idx[row_ptr[v]:row_ptr[v + 1]]:
            if not removed[w]:
                deg[w] -= 1
                heapq.heappush(heap, (int(deg[w]), int(w)))
    return order


ORDERINGS = {
    "natural": natural,
    "random": random_shuffle,
    "largest_first": largest_degree_first,
    "smallest_last": smallest_degree_last,
}


def apply(graph: Graph, order: np.ndarray) -> Graph:
    """Relabel so that ``order[i]`` becomes vertex ``i`` (index-order greedy
    over the result == greedy in ``order`` over the original)."""
    perm = np.empty_like(order)
    perm[order] = np.arange(order.shape[0], dtype=np.int64)
    return graph.relabel(perm)
