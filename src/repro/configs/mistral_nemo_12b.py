"""mistral-nemo-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407]"""
from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense", num_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=131072,
        rope_theta=1_000_000.0)


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-smoke", family="dense", num_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        rope_theta=1_000_000.0)
