"""The frontier execution layer: active-set compaction for speculation rounds.

The paper's central empirical fact (§5, Fig. 10) is that after the first
speculation round the pending set collapses to a tiny conflicted tail —
typically well under 1% of |V| — yet a naive SIMD driver keeps sweeping the
full padded edge list every round. Rokos et al. (arXiv:1505.04086) show that
recoloring only the conflicted frontier is where the multi-core speedup
comes from; the distributed-GPU line (Bogle et al., arXiv:2107.00075) uses
the same active-set compaction to bound communication. This module is that
mechanism, shared by all three strategies:

* :func:`frontier_capacities` — the static bucket ladder: slab capacities
  derived from the graph envelope via :func:`repro.core.graph.pad_bucket`,
  so shapes stay static under ``jit``/``while_loop`` and a
  :class:`repro.core.api.ColoringPlan` keeps its zero-retrace guarantee.
* :func:`compact_frontier` — ``lax.sort``-free cumsum-scatter compaction of
  the active vertices AND their incident constraint edges into a
  fixed-capacity :class:`FrontierSlab`, one CSR gather (the DeviceGraph's
  ``inc_ptr`` auxiliary; the distributed driver derives per-shard pointers
  on device).
* :func:`frontier_sweep` — the speculation inner loop over the slab only:
  each sweep costs O(cap_e + cap_v·C) instead of O(E + V·C). Bit-identical
  to :func:`repro.core.engine.fixpoint_sweep` on the full edge list, because
  the slab carries *every* constraint edge incident to an active vertex and
  inactive vertices cannot change.
* :func:`frontier_conflicts` — Alg. 2 phase 2 over the slab edges only.

Spill semantics: capacities are static, frontiers are data. Every round the
driver checks the actual active counts against the slab capacities and falls
back to the full-edge sweep when the frontier overflows (``lax.cond``), so
results are bit-identical in ALL regimes — the slab is purely an execution
bypass. Round 0 (everything pending) always takes the full path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp
from jax import lax

from .engine import SlabMexFn
from .graph import pad_bucket

_INT32_MAX = jnp.iinfo(jnp.int32).max

FRONTIER_MODES = ("auto", "on", "off")


def frontier_capacities(num_vertices: int, padded_edges: int,
                        max_degree: int = 0, *,
                        capacity: int = 0) -> Tuple[int, int]:
    """The static bucket ladder: (vertex capacity, edge capacity) slabs.

    Defaults size the vertex slab at ~|V|/32 and the edge slab at the
    matching average-degree share with 2x skew headroom (never below one
    full max-degree row, so a single conflicted hub does not force a
    spill), both rounded up the :func:`repro.core.graph.pad_bucket` ladder
    so plan envelopes stay quantized. ``capacity`` overrides the vertex
    capacity (the ``ColoringSpec.frontier_capacity`` knob); the edge slab
    scales with it. All inputs are static envelope values — same envelope,
    same capacities, zero retrace. A degenerate envelope (V=0 or E=0) has
    nothing to compact and gets ``(0, 0)`` — frontier disabled — instead
    of a phantom minimum-bucket slab."""
    if int(num_vertices) <= 0 or int(padded_edges) <= 0:
        return 0, 0
    V = max(1, int(num_vertices))
    E = max(1, int(padded_edges))
    cap_v = int(capacity) if capacity > 0 else max(64, V // 32)
    cap_v = pad_bucket(min(cap_v, V), min_bucket=8)
    avg_share = (2 * E // V) * cap_v  # cap_v rows of twice-average degree
    cap_e = max(cap_v, avg_share, 2 * max(0, int(max_degree)))
    cap_e = pad_bucket(min(cap_e, E), min_bucket=8)
    return cap_v, cap_e


def resolve_frontier(mode: str, capacity: int, *, num_vertices: int,
                     padded_edges: int, max_degree: int,
                     has_inc: bool) -> Tuple[int, int]:
    """Resolve a spec-level ``frontier=`` knob against a concrete graph
    envelope into static slab capacities ((0, 0) = frontier disabled).

    ``"auto"`` enables the frontier whenever the graph carries the
    incident-edge auxiliary (``DeviceGraph.inc_ptr``; wedge-lowered
    multisets do not — their edge space is not row-deduped); ``"on"``
    demands it and raises otherwise; ``"off"`` disables."""
    if mode not in FRONTIER_MODES:
        raise ValueError(f"unknown frontier mode {mode!r}; "
                         f"choose from {FRONTIER_MODES}")
    usable = has_inc and padded_edges > 0 and num_vertices > 0
    if mode == "off":
        return 0, 0
    if not usable:
        if mode == "on":
            raise ValueError(
                "frontier='on' needs the incident-edge auxiliary: build the "
                "graph via Graph.to_device() (any layout attaches inc_ptr) "
                "— wedge-lowered d2/pd2 multisets don't carry it, use "
                "lowering='square'")
        return 0, 0
    return frontier_capacities(num_vertices, padded_edges, max_degree,
                               capacity=capacity)


class FrontierSlab(NamedTuple):
    """The compacted active set: ``cap_v`` vertex rows + ``cap_e`` incident
    edges, fixed shapes, padded with inert sentinels.

    vert:  [cap_v] int32 vertex id of each slab row; ``V`` = empty row.
    owner: [cap_e] int32 slab row owning each slab edge; ``cap_v`` = pad.
    src:   [cap_e] int32 vertex id of the owning row (= vert[owner]);
           ``V`` = pad.
    dst:   [cap_e] int32 edge target in the *original* dst id space
           (global ids under the distributed driver); ``dst_pad`` = pad.
    slot:  [cap_e] int32 position of the edge within its row (the ELL slot
           the ``ell_pallas`` slab bind scatters through).
    nv/ne: scalar int32 true active counts — may EXCEED the capacities;
           callers must spill to the full path when they do.
    """

    vert: jnp.ndarray
    owner: jnp.ndarray
    src: jnp.ndarray
    dst: jnp.ndarray
    slot: jnp.ndarray
    nv: jnp.ndarray
    ne: jnp.ndarray


def frontier_counts(active: jnp.ndarray,
                    inc_ptr: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(nv, ne) of an active mask — the O(V) spill check, computable without
    building the slab."""
    deg = inc_ptr[1:] - inc_ptr[:-1]
    nv = active.sum(dtype=jnp.int32)
    ne = jnp.where(active, deg, 0).sum(dtype=jnp.int32)
    return nv, ne


def compact_frontier(active: jnp.ndarray, inc_ptr: jnp.ndarray,
                     dst: jnp.ndarray, cap_v: int, cap_e: int,
                     *, dst_pad: Optional[int] = None) -> FrontierSlab:
    """Compact the active vertices and their incident CSR rows into a
    :class:`FrontierSlab` — no sort: a rank cumsum places vertices, a
    degree cumsum + scatter + running max assigns edges to rows, and one
    gather through ``inc_ptr`` pulls the edge targets.

    ``active``  [V] bool; ``inc_ptr`` [V+1] int32 row pointers into ``dst``
    (rows must be contiguous — true of every ``Graph.to_device`` edge list
    and of ``partition_graph`` slabs); ``dst`` the edge-target array the
    rows index; ``dst_pad`` the sentinel for padded slab edges (defaults to
    V — the distributed driver passes its global phantom id instead).

    Overflow never corrupts: rows landing beyond the capacities are dropped
    by the scatters, and ``nv``/``ne`` report the TRUE counts so callers
    spill to the full path.
    """
    V = active.shape[0]
    fill = V if dst_pad is None else int(dst_pad)
    act32 = active.astype(jnp.int32)
    deg = inc_ptr[1:] - inc_ptr[:-1]
    nv = act32.sum()
    ne = jnp.where(active, deg, 0).sum(dtype=jnp.int32)

    # vertices: rank-within-active-set IS the slab row (order-preserving)
    rank = jnp.cumsum(act32) - 1
    vert = (jnp.full((cap_v,), V, jnp.int32)
            .at[jnp.where(active, rank, cap_v)]
            .set(jnp.arange(V, dtype=jnp.int32), mode="drop"))

    # edges: exclusive cumsum of slab-row degrees gives each row's start;
    # scatter row ids at the starts, running max floods them rightwards
    degp = jnp.concatenate([deg, jnp.zeros((1,), jnp.int32)])
    vdeg = degp[jnp.minimum(vert, V)]            # empty rows contribute 0
    starts = jnp.cumsum(vdeg) - vdeg
    owner = (jnp.zeros((cap_e,), jnp.int32)
             .at[jnp.where(vdeg > 0, starts, cap_e)]
             .max(jnp.arange(cap_v, dtype=jnp.int32), mode="drop"))
    owner = lax.cummax(owner)
    eidx = jnp.arange(cap_e, dtype=jnp.int32)
    valid = eidx < jnp.minimum(ne, cap_e)
    slot = eidx - starts[owner]
    src = vert[owner]                            # [cap_e], V on empty rows
    gidx = inc_ptr[jnp.minimum(src, V)] + slot   # src <= V indexes [V+1] ptr
    gdst = dst[jnp.clip(gidx, 0, dst.shape[0] - 1)]
    return FrontierSlab(
        vert=vert,
        owner=jnp.where(valid, owner, cap_v),
        src=jnp.where(valid, src, V),
        dst=jnp.where(valid, gdst, fill),
        slot=jnp.where(valid, slot, 0),
        nv=nv, ne=ne)


def frontier_sweep(mex_slab: SlabMexFn, *, key_v: jnp.ndarray,
                   dyn: jnp.ndarray, dyn_idx: jnp.ndarray,
                   static_c: jnp.ndarray, slot: jnp.ndarray,
                   write_vert: jnp.ndarray, cpad0: jnp.ndarray,
                   max_sweeps: int, wrap=lambda x: x):
    """The speculation inner loop over a compacted slab: chaotic sweeps of
    ``c[vert[i]] <- mex{ contribution(e) : e in row i }`` to a fixpoint.

    Mirrors :func:`repro.core.engine.fixpoint_sweep` in slab space — same
    contribution classification (``dyn`` re-reads the live padded color
    vector at ``dyn_idx``, else the frozen ``static_c``), same convergence
    rule, so sweep counts and fixpoints are bit-identical to the full-edge
    path. ``cpad0`` is the padded color carrier ([V+1]; the trailing 0 is
    the phantom gather target); ``write_vert`` the cpad index of each slab
    row, with any value >= len(cpad)-1 treated as an inert row.

    Returns ``(cpad, sweeps, still_changing)``.
    """
    n_pad = cpad0.shape[0]                       # V + 1
    widx = jnp.where(write_vert < n_pad - 1, write_vert, n_pad)
    wok = write_vert < n_pad - 1

    def body(state):
        cpad, _, n = state
        key_c = jnp.where(dyn, cpad[dyn_idx], static_c)
        mexv = mex_slab(key_v, key_c, slot)
        old = cpad[jnp.minimum(widx, n_pad - 1)]
        changed = jnp.any(wok & (mexv != old))
        return cpad.at[widx].set(mexv, mode="drop"), changed, n + 1

    def cond(state):
        _, changed, n = state
        return jnp.logical_and(changed, n < max_sweeps)

    cpad, changed, n = lax.while_loop(
        cond, body,
        (cpad0, wrap(jnp.asarray(True)), wrap(jnp.asarray(0, jnp.int32))))
    return cpad, n, changed


def frontier_conflicts(slab: FrontierSlab, cpad: jnp.ndarray,
                       ppad: jnp.ndarray, num_vertices: int) -> jnp.ndarray:
    """Alg. 2 phase 2 over the slab edges only — the frontier counterpart of
    :func:`repro.core.engine.speculation_conflicts`. Exact, because every
    conflict edge has a pending ``src`` and the slab holds ALL edges
    incident to pending vertices. Returns the next round's pending mask
    ([V] bool)."""
    conf_e = (ppad[jnp.minimum(slab.dst, num_vertices)]
              & (cpad[jnp.minimum(slab.src, num_vertices)]
                 == cpad[jnp.minimum(slab.dst, num_vertices)])
              & (slab.src > slab.dst))
    return (jnp.zeros((num_vertices,), jnp.int32)
            .at[slab.src].max(conf_e.astype(jnp.int32), mode="drop")
            .astype(jnp.bool_))
