"""Pallas TPU kernel: ONE fused speculation round — detect→mex→assign in a
single launch over the frontier slab (ROADMAP item 2, ISSUE 6 tentpole).

The paper's iterative algorithm (Alg. 1 + 2) spends every round in three
separate memory passes over the neighborhood data:

  1. *detect*  — gather endpoint colors, test ``c[u] == c[v] and u > v``
                 (Alg. 2 line 13, :mod:`repro.kernels.conflict`);
  2. *mex*     — gather neighbor colors again, build ``forbiddenColors``,
                 scan for the minimum free color (Alg. 1 lines 5-6,
                 :mod:`repro.kernels.firstfit`);
  3. *assign*  — write the new colors back.

On every system the paper studies the round is bandwidth-bound, not
compute-bound, so the pass count IS the round cost. This kernel fuses all
three into one launch in the spirit of Rokos et al.'s atomic-free
detect-and-recolor (arXiv:1505.04086): per vertex tile of the (compacted)
ELL slab it

  * builds the per-row forbidden-color **bitset** in VMEM scratch (the
    ``firstfit.py`` word-mask idiom — ``W = C/32`` uint32 words,
    accumulated across neighbor-slot tiles);
  * applies the Alg. 2 conflict predicate against the row's own color in
    the same slab read;
  * emits the mex (the row's next color) and the per-row conflict flag on
    the last slot tile — one read of the ELL slab per round instead of
    three (`benchmarks/roofline.py --round` measures exactly this).

The gather stays OUTSIDE the kernel (DESIGN.md §2 / §FusedRound:
"regularize, then go fast"): neighbor colors arrive as a pre-gathered,
pre-packed ELL block. Each int32 slab entry packs the neighbor's color
with two predicate bits:

  * ``FORBID``  (bit 28) — the entry contributes to the forbidden bitset
    (the ``SweepSpec`` precedence mask, applied at pack time);
  * ``CONFLICT`` (bit 29) — the entry is conflict-eligible: its endpoint
    is pending and ranks below the row (``u > v``), so an equal color
    queues the row for recoloring.

Entries without either bit (slab padding, masked-out edges) are inert:
color 0 is always forbidden by construction, exactly as in ``firstfit``.

Colors are assumed ``< 32*words`` (the greedy Δ+2 bound; out-of-range
colors drop from the bitset just like the bitmap backend's out-of-range
scatters — they can never lower a mex that provably stays in range).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tpu_compat import TPUCompilerParams

# packed-entry layout: bits 0..27 color, bit 28 forbid, bit 29 conflict
COLOR_MASK = (1 << 28) - 1
FORBID_BIT = 1 << 28
CONFLICT_BIT = 1 << 29


def pack_entries(colors: jnp.ndarray, forbid: jnp.ndarray,
                 conflict: jnp.ndarray) -> jnp.ndarray:
    """Pack an ELL block of neighbor colors + predicate masks into the
    kernel's int32 entry format. ``colors`` int32 (values < 2^28),
    ``forbid``/``conflict`` broadcastable booleans."""
    colors = colors.astype(jnp.int32) & COLOR_MASK
    return (colors
            | jnp.where(forbid, jnp.int32(FORBID_BIT), jnp.int32(0))
            | jnp.where(conflict, jnp.int32(CONFLICT_BIT), jnp.int32(0)))


def _round_fused_kernel(ent_ref, own_ref, mex_ref, conf_ref, forb_ref,
                        hit_ref, *, words: int):
    """One (vertex-tile, slot-tile) grid step.

    ent_ref:  [BV, BD] int32 packed entries (color | FORBID? | CONFLICT?)
    own_ref:  [BV]     int32 the row's current color (conflict operand)
    mex_ref:  [BV]     int32 mex output (written on the last slot tile)
    conf_ref: [BV]     int32 conflict flag output (last slot tile)
    forb_ref: [BV, W]  uint32 VMEM scratch, persists across slot tiles
    hit_ref:  [BV]     int32 VMEM scratch: conflict accumulator
    """
    j = pl.program_id(1)
    nd = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        # color 0 ("uncolored") is always forbidden: bit 0 of word 0
        init = jnp.zeros(forb_ref.shape, jnp.uint32)
        forb_ref[...] = init.at[:, 0].set(jnp.uint32(1))
        hit_ref[...] = jnp.zeros(hit_ref.shape, jnp.int32)

    ent = ent_ref[...]                                     # [BV, BD] int32
    color = ent & COLOR_MASK
    forbid = (ent & FORBID_BIT) != 0
    elig = (ent & CONFLICT_BIT) != 0

    # --- detect: Alg. 2 line 13 against the row's own color -------------
    own = own_ref[...]                                     # [BV]
    hit = elig & (color == own[:, None]) & (own[:, None] > 0)
    hit_ref[...] = hit_ref[...] | hit.any(axis=1).astype(jnp.int32)

    # --- mex part 1: accumulate the forbidden bitset (firstfit idiom) ---
    word_idx = (color >> 5).astype(jnp.int32)              # [BV, BD]
    bit = (color & 31).astype(jnp.uint32)
    bitval = jnp.where(forbid, jnp.uint32(1) << bit, jnp.uint32(0))
    contrib = jnp.where(
        word_idx[:, :, None] == jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, words), 2),
        bitval[:, :, None],
        jnp.uint32(0),
    )                                                      # [BV, BD, W]
    orred = jax.lax.reduce(contrib, jnp.uint32(0), jax.lax.bitwise_or, (1,))
    forb_ref[...] = forb_ref[...] | orred

    @pl.when(j == nd - 1)
    def _finish():
        # mex part 2: expand words to bit lanes, min-reduce free candidates
        forb = forb_ref[...]                               # [BV, W]
        lanes = jax.lax.broadcasted_iota(jnp.uint32, (1, words, 32), 2)
        bits = (forb[:, :, None] >> lanes) & jnp.uint32(1)  # [BV, W, 32]
        value = (
            jax.lax.broadcasted_iota(jnp.int32, (1, words, 32), 1) * 32
            + jax.lax.broadcasted_iota(jnp.int32, (1, words, 32), 2)
        )
        cand = jnp.where(bits == 0, value, jnp.iinfo(jnp.int32).max)
        mex_ref[...] = jnp.min(cand.reshape(cand.shape[0], -1), axis=1)
        conf_ref[...] = hit_ref[...]


def vmem_estimate(*, words: int = 16, block_v: int = 512,
                  block_d: int = 128) -> int:
    """Per-grid-step VMEM footprint (bytes) of :func:`round_fused`'s launch
    geometry, for the analyzer's budget checker (repro.analysis.budgets):
    the packed-entry + own-color input blocks, the mex/conflict output
    blocks, the ``[BV, W]`` bitset and ``[BV]`` hit scratch, and the larger
    of the ``[BV, BD, W]`` contribution tensor and the ``[BV, W, 32]``
    bit-lane expansion (same idiom as ``firstfit.vmem_estimate``)."""
    blocks = 4 * block_v * (block_d + 3)
    scratch = 4 * block_v * (words + 1)
    intermediate = 4 * block_v * words * max(block_d, 32)
    return blocks + scratch + intermediate


@functools.partial(
    jax.jit, static_argnames=("words", "block_v", "block_d", "interpret")
)
def round_fused(
    entries: jnp.ndarray,
    own_colors: jnp.ndarray,
    *,
    words: int = 16,
    block_v: int = 512,
    block_d: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused detect→mex pass over a packed ELL slab.

    entries:    [V, D] int32 packed (:func:`pack_entries`); rows are slab
                rows (compacted frontier rows, or whole-graph vertices).
    own_colors: [V] int32, each row's current color (0 = uncolored — such
                rows never report a conflict).

    Returns ``(mex, conflict)``: mex [V] int32 >= 1 (the smallest positive
    color absent from the row's FORBID entries) and conflict [V] int32
    (1 iff some CONFLICT-eligible entry matches the row's own color).
    The caller applies *assign* as ``where(recolor, mex, own)`` — for the
    speculation inner loop ``recolor = pending`` (fixpoint sweeps); for a
    Rokos detect-and-recolor round ``recolor = conflict``. V and D are
    padded internally to the block shape (pad entries are inert).
    """
    v, d = entries.shape
    vp = -(-v // block_v) * block_v
    dp = -(-d // block_d) * block_d
    x = jnp.zeros((vp, dp), jnp.int32).at[:v, :d].set(entries)
    own = jnp.zeros((vp,), jnp.int32).at[:v].set(own_colors)
    grid = (vp // block_v, dp // block_d)
    mex, conf = pl.pallas_call(
        functools.partial(_round_fused_kernel, words=words),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_v,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_v,), lambda i, j: (i,)),
            pl.BlockSpec((block_v,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((vp,), jnp.int32),
            jax.ShapeDtypeStruct((vp,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_v, words), jnp.uint32),
            pltpu.VMEM((block_v,), jnp.int32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, own)
    return mex[:v], conf[:v]


def tile_conflict_counts(conflict: jnp.ndarray,
                         block_v: int = 512) -> jnp.ndarray:
    """Per-vertex-tile conflict counts from the kernel's per-row flags —
    the (padded) sum over each ``block_v`` tile of the launch grid."""
    v = conflict.shape[0]
    vp = -(-v // block_v) * block_v
    padded = jnp.zeros((vp,), jnp.int32).at[:v].set(conflict)
    return padded.reshape(vp // block_v, block_v).sum(axis=1)


def round_fused_ref(entries: jnp.ndarray,
                    own_colors: jnp.ndarray,
                    *, words: int = 16) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pure-jnp oracle for :func:`round_fused` (tests)."""
    color = entries & COLOR_MASK
    forbid = (entries & FORBID_BIT) != 0
    elig = (entries & CONFLICT_BIT) != 0
    C = 32 * words
    v = entries.shape[0]
    rows = jnp.repeat(jnp.arange(v, dtype=jnp.int32), entries.shape[1])
    key_c = jnp.where(forbid, color, 0).reshape(-1)
    forb = (jnp.zeros((v, C), jnp.uint8)
            .at[rows, jnp.minimum(key_c, C - 1)]
            .set(jnp.where(key_c < C, 1, 0).astype(jnp.uint8).reshape(-1)))
    forb = forb.at[:, 0].set(1)
    value = jnp.arange(C, dtype=jnp.int32)[None, :]
    mex = jnp.where(forb == 0, value,
                    jnp.iinfo(jnp.int32).max).min(axis=1).astype(jnp.int32)
    own = own_colors.astype(jnp.int32)
    conf = (elig & (color == own[:, None])
            & (own[:, None] > 0)).any(axis=1).astype(jnp.int32)
    return mex, conf
