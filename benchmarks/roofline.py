"""Roofline report builder.

Two modes:

* default — reads the dry-run JSON records and renders the EXPERIMENTS.md
  §Roofline table (per arch x shape x mesh: three terms, dominant
  bottleneck, MODEL_FLOPS ratio, roofline fraction);
* ``--round`` — a MEASURED coloring-round comparison (ISSUE 6 / ROADMAP
  item 2): runs real Rokos detect-and-recolor rounds on a k-regular
  circulant graph two ways — the 3-pass ``ell_pallas`` path (conflict
  kernel, ELL gather + ``firstfit`` mex kernel, assign) vs the 1-pass
  ``fused_pallas`` path (pack, ``round_fused``, assign) — asserts the two
  are bit-identical every round, accounts the bytes each path moves
  (padded kernel shapes, every materialized array counted once per
  producing and once per consuming pass), and reports achieved-vs-peak
  bandwidth against a measured element-wise-copy peak. The headline
  numbers: the fused path reads the slab ONCE per round where the 3-pass
  path's kernels read 4 edge-scale arrays + the slab (5x at degree =
  block_d), and total bytes drop >2x. Wall times are honest but, off-TPU,
  dominated by Pallas interpret overhead — bytes are the roofline metric.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
        [--markdown]
        PYTHONPATH=src python -m benchmarks.roofline --round [--scale 10]
        [--degree 128] [--max-rounds 12] [--json BENCH_roofline_round.json]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time

ARCH_ORDER = [
    "mistral-nemo-12b", "qwen3-4b", "starcoder2-3b", "gemma2-2b",
    "mamba2-130m", "whisper-medium", "recurrentgemma-2b",
    "llama-3.2-vision-11b", "grok-1-314b", "deepseek-v2-lite-16b",
    "rmat-coloring",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "coloring"]


def load(dir_: str, tag: str = "baseline"):
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, f"*__{tag}.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    recs.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99,
                             SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 99,
                             len(r["mesh"])))
    return recs


def one_liner(r):
    rf = r.get("roofline", {})
    mesh = "x".join(str(d) for d in r["mesh"])
    dom = rf.get("dominant", "?").replace("_s", "")
    frac = r.get("roofline_fraction", 0.0)
    ratio = r.get("useful_flops_ratio", 0.0)
    return (f"{r['arch']:22s} {r['shape']:12s} {mesh:8s} "
            f"C={rf.get('compute_s', 0):9.3e} M={rf.get('memory_s', 0):9.3e} "
            f"X={rf.get('collective_s', 0):9.3e} dom={dom:10s} "
            f"useful={ratio:5.2f} frac={frac:6.3f}")


def markdown_table(recs):
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | useful FLOPs | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        rf = r.get("roofline", {})
        mesh = "x".join(str(d) for d in r["mesh"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {rf.get('compute_s', 0):.3e} | {rf.get('memory_s', 0):.3e} "
            f"| {rf.get('collective_s', 0):.3e} "
            f"| {rf.get('dominant', '?').replace('_s', '')} "
            f"| {r.get('useful_flops_ratio', 0):.2f} "
            f"| {r.get('roofline_fraction', 0):.3f} |")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# --round: measured coloring-round mode (3-pass ell_pallas vs 1-pass fused)
# --------------------------------------------------------------------------
_I4 = 4  # int32 bytes — every array in the round loop


def circulant_ell(num_vertices: int, degree: int):
    """k-regular circulant graph (vertex i ~ i±1..i±k/2 mod V): the
    structured-mesh analogue with an exactly full ELL slab, so the padded
    kernel shapes match the true neighborhood work. Returns (ell [V, k]
    neighbor ids, src [E], dst [E]) as numpy int32, E = V*k directed."""
    import numpy as np

    if degree % 2 or degree >= num_vertices:
        raise ValueError("degree must be even and < num_vertices")
    half = np.arange(1, degree // 2 + 1)
    offs = np.concatenate([half, -half])
    ids = np.arange(num_vertices)[:, None]
    ell = ((ids + offs[None, :]) % num_vertices).astype(np.int32)
    src = np.repeat(np.arange(num_vertices, dtype=np.int32), degree)
    return ell, src, ell.reshape(-1)


def round_bytes_model(num_vertices: int, degree: int, num_edges: int,
                      block_v: int = 512, block_d: int = 128) -> dict:
    """Analytic bytes moved per round by each path (int32 everywhere;
    padded kernel shapes; each materialized array counted once per
    producing pass and once per consuming pass).

    3-pass:  detect  — kernel reads cs, cd, src, dst [4E], writes conf [E],
                       pending scatter reads conf [E] writes [V]
                       (+ the cs/cd gather writes [2E]);
             mex     — gather reads ell slab, writes nbr slab; firstfit
                       reads nbr slab, writes mex [V];
             assign  — reads mex, pending, colors [3V], writes colors [V].
    fused:   pack    — reads ell slab, writes entries slab;
             kernel  — round_fused reads entries slab + own [V], writes
                       mex + conf [2V];
             assign  — reads mex, conf, colors [3V], writes colors [V].
    """
    vp = -(-num_vertices // block_v) * block_v
    dp = -(-degree // block_d) * block_d
    slab = vp * dp * _I4
    e = num_edges * _I4
    v = num_vertices * _I4
    three_reads = 4 * e + e + slab + slab + 3 * v
    three_writes = 2 * e + e + v + slab + v + v
    fused_reads = slab + slab + v + 3 * v
    fused_writes = slab + 2 * v + v
    # slab-scale arrays consumed by the Pallas kernels themselves — the
    # ISSUE metric ("one read of the ELL slab per round instead of three")
    kernel_slab_reads_three = (4 * e + slab) / slab
    kernel_slab_reads_fused = slab / slab
    return {
        "slab_bytes": slab,
        "three_pass_bytes": three_reads + three_writes,
        "fused_bytes": fused_reads + fused_writes,
        "bytes_ratio": (three_reads + three_writes)
        / (fused_reads + fused_writes),
        "kernel_slab_reads_three": kernel_slab_reads_three,
        "kernel_slab_reads_fused": kernel_slab_reads_fused,
        "kernel_slab_read_ratio": kernel_slab_reads_three
        / kernel_slab_reads_fused,
    }


def _timed_call(fn, *args, reps: int = 3) -> float:
    """Best-of-``reps`` wall seconds for fn(*args) (blocks on the result)."""
    import jax

    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measured_peak_gbps(mbytes: int = 64) -> float:
    """Measured element-wise-copy bandwidth (read+write) as the 'peak' the
    achieved numbers are normalized against — a STREAM-style ceiling on
    whatever backend is attached, not a datasheet number."""
    import jax
    import jax.numpy as jnp

    n = mbytes * (1 << 20) // _I4
    x = jnp.arange(n, dtype=jnp.int32)
    f = jax.jit(lambda a: a + 1)
    jax.block_until_ready(f(x))  # compile
    t = _timed_call(f, x, reps=5)
    return 2 * n * _I4 / t / 1e9


def round_report(scale: int = 10, degree: int = 128, max_rounds: int = 12,
                 seed: int = 0, interpret=None) -> dict:
    """Run detect→mex→assign rounds both ways, assert bit-parity, account
    bytes, measure wall time and achieved-vs-peak bandwidth."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.kernels as K
    from repro.core.engine import num_color_words

    interp = K.resolve_interpret(interpret)
    V = 1 << scale
    ell_np, src_np, dst_np = circulant_ell(V, degree)
    E = src_np.shape[0]
    words = num_color_words(degree + 1)
    ell = jnp.asarray(ell_np)
    src, dst = jnp.asarray(src_np), jnp.asarray(dst_np)
    row = jnp.arange(V, dtype=jnp.int32)[:, None]
    real = ell < V
    elig = real & (ell < row)  # Alg. 2: u recolors iff some nbr v < u ties

    @jax.jit
    def three_pass_round(colors):
        cpad = jnp.concatenate([colors, jnp.zeros((1,), jnp.int32)])
        cs, cd = cpad[src], cpad[dst]
        conf_e = K.conflict_mask(cs, cd, src, dst, interpret=interp)
        pending = (jnp.zeros((V,), jnp.int32)
                   .at[src].max(conf_e, mode="drop")) > 0
        nbr = K.ell_gather_colors(colors, ell)
        mex = K.firstfit(nbr, words=words, interpret=interp)
        return jnp.where(pending, mex, colors), pending.sum(dtype=jnp.int32)

    @jax.jit
    def fused_round(colors):
        nbr = K.ell_gather_colors(colors, ell)
        ent = K.pack_entries(nbr, real, elig)
        mex, conf = K.round_fused(ent, colors, words=words, interpret=interp)
        return jnp.where(conf > 0, mex, colors), conf.sum(dtype=jnp.int32)

    rng = np.random.default_rng(seed)
    c0 = jnp.asarray(rng.integers(1, degree + 2, size=V).astype(np.int32))
    # warm up / compile both paths once before timing
    jax.block_until_ready(three_pass_round(c0))
    jax.block_until_ready(fused_round(c0))

    rounds, c3, cf = [], c0, c0
    for r in range(max_rounds):
        t3 = _timed_call(three_pass_round, c3)
        tf = _timed_call(fused_round, cf)
        (c3, n3) = three_pass_round(c3)
        (cf, nf) = fused_round(cf)
        if not np.array_equal(np.asarray(c3), np.asarray(cf)):
            raise AssertionError(f"round {r}: fused != 3-pass colors")
        if int(n3) != int(nf):
            raise AssertionError(f"round {r}: conflict counts differ")
        rounds.append({"round": r, "conflicts": int(n3),
                       "three_pass_us": t3 * 1e6, "fused_us": tf * 1e6})
        if int(n3) == 0:
            break

    bytes_ = round_bytes_model(V, degree, E)
    peak = measured_peak_gbps()
    t3m = min(r["three_pass_us"] for r in rounds) * 1e-6
    tfm = min(r["fused_us"] for r in rounds) * 1e-6
    ach3 = bytes_["three_pass_bytes"] / t3m / 1e9
    achf = bytes_["fused_bytes"] / tfm / 1e9
    return {
        "kind": "roofline_round",
        "graph": {"kind": "circulant", "num_vertices": V, "degree": degree,
                  "num_edges_directed": E},
        "words": words,
        "interpret": bool(interp),
        "backend": jax.default_backend(),
        "parity": True,
        "rounds": rounds,
        "bytes": bytes_,
        "bandwidth": {
            "peak_gbps": peak,
            "three_pass_achieved_gbps": ach3,
            "fused_achieved_gbps": achf,
            "three_pass_fraction": ach3 / peak,
            "fused_fraction": achf / peak,
        },
    }


def print_round_report(rep: dict) -> None:
    g, b, bw = rep["graph"], rep["bytes"], rep["bandwidth"]
    print(f"coloring round roofline — circulant V={g['num_vertices']} "
          f"k={g['degree']} E={g['num_edges_directed']} "
          f"words={rep['words']} backend={rep['backend']}"
          f"{' (interpret)' if rep['interpret'] else ''}")
    print(f"  bytes/round   three-pass {b['three_pass_bytes']:>12,}  "
          f"fused {b['fused_bytes']:>12,}  ratio {b['bytes_ratio']:.2f}x")
    print(f"  kernel slab reads/round   three-pass "
          f"{b['kernel_slab_reads_three']:.2f}  fused "
          f"{b['kernel_slab_reads_fused']:.2f}  "
          f"ratio {b['kernel_slab_read_ratio']:.2f}x")
    print(f"  bandwidth (peak {bw['peak_gbps']:.1f} GB/s)   three-pass "
          f"{bw['three_pass_achieved_gbps']:.3f} GB/s "
          f"({bw['three_pass_fraction']:.4f})   fused "
          f"{bw['fused_achieved_gbps']:.3f} GB/s "
          f"({bw['fused_fraction']:.4f})")
    for r in rep["rounds"]:
        print(f"  round {r['round']}: conflicts {r['conflicts']:>6}  "
              f"three-pass {r['three_pass_us']:>10.1f} us  "
              f"fused {r['fused_us']:>10.1f} us")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--round", action="store_true",
                    help="measured coloring-round mode (3-pass vs fused)")
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--degree", type=int, default=128)
    ap.add_argument("--max-rounds", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="--round: also write the report to this path")
    args = ap.parse_args()
    if args.round:
        rep = round_report(scale=args.scale, degree=args.degree,
                           max_rounds=args.max_rounds, seed=args.seed)
        print_round_report(rep)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rep, f, indent=1, sort_keys=True)
            print(f"wrote {args.json}")
        return
    recs = load(args.dir, args.tag)
    if args.markdown:
        print(markdown_table(recs))
        return
    for r in recs:
        print(one_liner(r))
    print(f"\n{len(recs)} cells")


if __name__ == "__main__":
    main()
