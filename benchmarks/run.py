"""Benchmark harness — one function per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows plus per-benchmark detail blocks.
Scales are CPU-feasible reductions of the paper's scale-24..27 graphs (the
claims validated are structural/relative, not absolute wall-clock).

Benchmark-family registry (run all by default; select with
``--families a,b,...``; every family accepts the global ``--scale``
override, ``engine_compare`` additionally honors ``--ell``):

  family                    | what it measures                 | default scale
  --------------------------|----------------------------------|--------------
  table2_graph_properties   | paper Table 2 (+Table 4 columns) | 16
  fig7_9_strong_scaling     | ITERATIVE cost vs concurrency    | 15
  fig10_conflicts           | conflicts/round, total, iters    | 16
  fig11_colors              | colors vs concurrency vs serial  | 15
  dataflow_exactness        | DATAFLOW == serial + sweep count | 15
  engine_compare            | sort vs bitmap (vs ell_pallas +  | 13
                            | fused_pallas with --ell)         |
  d2_compare                | distance-2 + bipartite partial-  | 9
                            | D2 models vs serial D2/PD2       |
                            | oracles, sort/bitmap parity      |
  plan_throughput           | graphs/s: per-call drivers vs    | 11
                            | compile_plan reuse vs plan.map   |
  frontier_compare          | frontier on/off x engine:        | 13
                            | round-2+ sweep cost + bit parity |
  stream_compare            | streaming deltas: incremental    | 10
                            | "recolor" repair vs fresh full   |
                            | recoloring, per batch size       |
  kernel_firstfit           | Pallas firstfit + fused round    | 13
                            | engines vs sort engine           |
  serve_bench               | async service under open-loop    | 10
                            | mixed-tenant load: p50/p99,      |
                            | hit rate, deadline-bound ages    |
  dist_scale                | distributed wire: bytes-on-wire, | 10
                            | rounds, us_per_round vs shard    |
                            | count, 1d vs 2d, boundary vs     |
                            | full gather (bit parity asserted)|
  comm_schedule             | coloring-scheduled all-to-all    | (none)

``--json out.json`` additionally writes every row machine-readably
(us_per_call plus each row's structured fields: rounds, colors, frontier
sizes, cost ratios, ...) — the format the CI slow lane archives as the
repo's perf trajectory. The file is (re)written atomically after EVERY
completed family (tmp file + rename), so one crashing family can never
lose the rows the earlier families already produced.

See README.md §Benchmarks for the full CLI documentation.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

import jax

from repro.core import (rmat, BipartiteGraph, greedy_color, greedy_color_d2,
                        greedy_color_pd2, color_iterative, color_dataflow,
                        dataflow_levels, validate_coloring,
                        validate_d2_coloring, validate_pd2_coloring,
                        num_colors, schedule_transfers)
from repro.core.comm_schedule import moe_all_to_all_transfers
from repro.core.distance2 import wedge_count

GRAPHS = ["RMAT-ER", "RMAT-G", "RMAT-B"]
ROWS = []
RECORDS = []  # machine-readable mirror of ROWS (--json)


def _row(name, us, derived, **fields):
    """One benchmark result: the CSV line everyone greps, plus a structured
    record for ``--json`` (``fields`` carries whatever the family measured
    beyond the us_per_call scalar)."""
    ROWS.append(f"{name},{us:.1f},{derived}")
    RECORDS.append(dict(name=name, us_per_call=round(us, 1),
                        derived=derived, **fields))
    print(f"{name},{us:.1f},{derived}")


def _timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / repeat * 1e6


def table2_graph_properties(scale=16):
    print(f"\n== Table 2/4: graph structural properties (scale {scale}) ==")
    print(f"{'graph':8s} {'|V|':>9s} {'|E|':>10s} {'avgdeg':>7s} {'maxdeg':>7s} "
          f"{'var':>10s} {'%isol':>6s}")
    for name in GRAPHS:
        t0 = time.perf_counter()
        g = rmat.paper_graph(name, scale=scale, seed=0)
        us = (time.perf_counter() - t0) * 1e6
        s = g.stats()
        print(f"{name:8s} {s['num_vertices']:9d} {s['num_edges']:10d} "
              f"{s['avg_degree']:7.2f} {s['max_degree']:7d} "
              f"{s['degree_variance']:10.1f} {s['pct_isolated']:6.2f}")
        _row(f"table2/{name}", us,
             f"maxdeg={s['max_degree']};var={s['degree_variance']:.1f};"
             f"isol={s['pct_isolated']:.2f}%")


def fig7_9_strong_scaling(scale=15):
    """Runtime of ITERATIVE vs concurrency (the paper's thread axis).

    On one CPU device the SIMD work per round is constant; what scales is
    rounds x sweeps (the serialization the paper's Fig. 7-9 hides inside
    thread counts). We report device-time per run and the sweep counts.
    """
    print(f"\n== Fig 7/8/9 proxy: ITERATIVE cost vs concurrency (scale {scale}) ==")
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        dg = g.to_device()
        for p in [1, 16, 128, 1024, 16384]:
            res, us = _timed(color_iterative, dg, concurrency=p, repeat=1)
            _row(f"fig7/{name}/P{p}", us,
                 f"rounds={res.rounds};sweeps={res.sweeps};"
                 f"conflicts={res.total_conflicts};colors={res.num_colors}")


def fig10_conflicts(scale=16):
    print(f"\n== Fig 10: conflicts (RMAT-B, scale {scale}) ==")
    g = rmat.paper_graph("RMAT-B", scale=scale, seed=0)
    dg = g.to_device()
    # the XMT row uses the paper's thread:vertex RATIO (12800 : 2^24), not
    # the absolute thread count — at reduced scale that's what preserves the
    # conflict regime; the absolute-P row is kept for the stress reading
    xmt_ratio_p = max(2, int(12800 * g.num_vertices / (1 << 24)))
    for p, label in [(16, "nehalem-16T"), (128, "niagara-128T"),
                     (xmt_ratio_p, f"xmt-ratio-{xmt_ratio_p}T"),
                     (12800, "xmt-absolute-12800T")]:
        res, us = _timed(color_iterative, dg, concurrency=p, repeat=1)
        cpr = [int(c) for c in np.asarray(res.conflicts_per_round)[:res.rounds]]
        frac1 = cpr[0] / max(1, sum(cpr))
        _row(f"fig10/{label}", us,
             f"total={res.total_conflicts};iters={res.rounds};"
             f"frac_round1={frac1:.2f};conflicts_per_round={cpr[:12]}")
        if p < g.num_vertices:  # the paper's regime; at reduced --scale the
            # absolute-thread row can exceed |V| conflicts summed over rounds
            assert res.total_conflicts < g.num_vertices, "conflicts must be << |V|"


def fig11_colors(scale=15):
    print(f"\n== Fig 11: colors used vs serial (scale {scale}) ==")
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        serial = num_colors(greedy_color(g))
        dg = g.to_device()
        cols = {}
        for p in [16, 128, 12800]:
            res = color_iterative(dg, concurrency=p)
            assert validate_coloring(g, np.asarray(res.colors))
            cols[p] = res.num_colors
        df = color_dataflow(dg).num_colors
        _row(f"fig11/{name}", 0.0,
             f"serial={serial};iter16={cols[16]};iter128={cols[128]};"
             f"iter12800={cols[12800]};dataflow={df}")
        assert df == serial, "DATAFLOW must equal serial (C4)"


def dataflow_exactness(scale=15):
    print(f"\n== DATAFLOW: exactness + sweeps vs DAG depth (scale {scale}) ==")
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        dg = g.to_device()
        res, us = _timed(color_dataflow, dg, repeat=1)
        _, depth = dataflow_levels(dg)
        same = bool(np.array_equal(np.asarray(res.colors), greedy_color(g)))
        _row(f"dataflow/{name}", us,
             f"sweeps={res.sweeps};dag_depth={depth};equals_serial={same}")
        assert same


def engine_compare(scale=13, concurrency=256, with_ell=False):
    """Mex-backend shootout: the sort-based O(E log E) inner loop vs the
    O(E) scatter-or bitmap (vs the Pallas ELL kernel with --ell), on all
    three paper graph families. Same speculation driver, same semantics —
    the per-round sweep/conflict histories must match exactly; what differs
    is us_per_call of the first-fit formulation (Rokos arXiv:1505.04086:
    the inner loop dominates and rewards the cheaper per-sweep form)."""
    engines = ["sort", "bitmap"] + (["ell_pallas", "fused_pallas"]
                                    if with_ell else [])
    print(f"\n== engine compare: {'/'.join(engines)} "
          f"(scale {scale}, P={concurrency}) ==")
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        dg = g.to_device(layout=("edges", "ell") if with_ell else "edges")
        ref = None
        for eng in engines:
            res, us = _timed(color_iterative, dg, concurrency=concurrency,
                             engine=eng, repeat=1)
            assert validate_coloring(g, np.asarray(res.colors)), (name, eng)
            cpr = [int(c) for c in
                   np.asarray(res.conflicts_per_round)[:res.rounds]]
            spr = [int(s) for s in
                   np.asarray(res.sweeps_per_round)[:res.rounds]]
            _row(f"engine/{name}/{eng}", us,
                 f"colors={res.num_colors};rounds={res.rounds};"
                 f"sweeps_per_round={spr[:12]};conflicts_per_round={cpr[:12]}")
            if ref is None:
                ref = (cpr, spr)
            else:
                assert ref == (cpr, spr), \
                    f"backend divergence on {name}: {ref} != {(cpr, spr)}"


def d2_compare(scale=9):
    """Coloring-model shootout: distance-2 and bipartite partial distance-2
    through the same engine (repro.core.distance2). Validates each parallel
    D2 coloring against the serial D2 oracle, checks sort/bitmap backend
    parity under model="d2" (identical colors + histories), and reports the
    D2-vs-D1 color/constraint blowup per graph family; plus a PD2 row on a
    random bipartite graph (Jacobian-compression shape)."""
    print(f"\n== d2 compare: D2/PD2 models vs oracles (scale {scale}) ==")
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        serial_d2 = greedy_color_d2(g)
        df = color_dataflow(g, model="d2")
        assert np.array_equal(np.asarray(df.colors), serial_d2), \
            "DATAFLOW(d2) must equal the serial D2 oracle"
        ref = None
        for eng in ["sort", "bitmap"]:
            # D2 constraint graphs are ~avg_degree x denser, so speculation
            # conflicts more per round: keep concurrency moderate and the
            # round cap generous
            res, us = _timed(color_iterative, g, concurrency=16, engine=eng,
                             model="d2", max_rounds=256, repeat=1)
            assert validate_d2_coloring(g, np.asarray(res.colors)), (name, eng)
            cols = np.asarray(res.colors)
            if ref is None:
                ref = cols
            else:
                assert np.array_equal(cols, ref), \
                    f"sort/bitmap divergence under model=d2 on {name}"
            _row(f"d2/{name}/{eng}", us,
                 f"colors={res.num_colors};serial_d2={int(serial_d2.max())};"
                 f"d1_serial={num_colors(greedy_color(g))};"
                 f"rounds={res.rounds};conflicts={res.total_conflicts};"
                 f"wedges={wedge_count(g)}")
    rng = np.random.default_rng(0)
    L, R = 1 << scale, 1 << (scale - 1)
    edges = np.stack([rng.integers(0, L, 8 * L), rng.integers(0, R, 8 * L)], 1)
    bg = BipartiteGraph.from_edges(L, R, edges)
    serial_pd2 = greedy_color_pd2(bg)
    res, us = _timed(color_iterative, bg, concurrency=16, model="pd2",
                     max_rounds=256, repeat=1)
    assert validate_pd2_coloring(bg, np.asarray(res.colors))
    dfp = color_dataflow(bg, model="pd2")
    assert np.array_equal(np.asarray(dfp.colors), serial_pd2)
    _row(f"d2/bipartite-{L}x{R}/pd2", us,
         f"colors={res.num_colors};serial_pd2={int(serial_pd2.max())};"
         f"rounds={res.rounds};conflicts={res.total_conflicts}")


def plan_throughput(scale=11, batch=8):
    """Compile-once serving throughput (the ROADMAP's color-many path):
    graphs/second of (a) the per-call legacy drivers — which retrace for
    every distinct (edge count, max degree) — vs (b) ``compile_plan`` +
    reuse, where every same-bucket graph rides ONE compiled program, vs
    (c) ``plan.map``, one vmapped program for the whole batch. Reported
    per engine and strategy on the three R-MAT families; all three paths
    must produce identical colors per graph (asserted)."""
    from repro.core import ColoringSpec, PlanShape, compile_plan
    from repro.core.graph import pad_bucket
    print(f"\n== plan throughput: per-call vs plan-reuse vs plan.map "
          f"(scale {scale}, batch {batch}) ==")
    for name in GRAPHS:
        family = [rmat.paper_graph(name, scale=scale, seed=s)
                  for s in range(batch)]
        shape = PlanShape(
            num_vertices=family[0].num_vertices,
            padded_edges=pad_bucket(max(g.num_directed_edges
                                        for g in family)),
            max_degree=max(g.max_degree() for g in family))
        for strategy in ["iterative", "dataflow"]:
            for eng in ["sort", "bitmap"]:
                if strategy == "iterative":
                    def legacy(g, e=eng):
                        return color_iterative(g, concurrency=64, engine=e)
                else:
                    def legacy(g, e=eng):
                        return color_dataflow(g, engine=e)
                t0 = time.perf_counter()
                legacy_colors = [np.asarray(legacy(g).colors) for g in family]
                t_call = time.perf_counter() - t0

                spec = ColoringSpec(strategy=strategy, engine=eng,
                                    concurrency=64)
                plan = compile_plan(spec, shape)
                plan(family[0])  # warm: the single jit trace
                t0 = time.perf_counter()
                reused = [plan(g) for g in family]
                t_reuse = time.perf_counter() - t0

                plan.map(family)  # warm the vmapped program
                t0 = time.perf_counter()
                mapped = plan.map(family)
                t_map = time.perf_counter() - t0
                assert plan.traces == 2, "plan reuse must not retrace"
                for ref, a, b in zip(legacy_colors, reused, mapped):
                    assert np.array_equal(ref, a.colors), (name, strategy, eng)
                    assert np.array_equal(ref, b.colors), (name, strategy, eng)
                _row(f"plan/{name}/{strategy}/{eng}", t_map / batch * 1e6,
                     f"per_call_gps={batch / t_call:.1f};"
                     f"reuse_gps={batch / t_reuse:.1f};"
                     f"map_gps={batch / t_map:.1f};"
                     f"reuse_speedup={t_call / t_reuse:.1f}x;"
                     f"map_speedup={t_call / t_map:.1f}x;"
                     f"colors={mapped[0].num_colors}")


def frontier_compare(scale=13, concurrency=64):
    """Frontier on/off shootout (the ISSUE-4 tentpole claim): after round 1
    the pending set collapses to a conflicted tail (~1% of |V| in the
    paper's 16-128-thread regime, which ``concurrency`` defaults to), so
    compacted rounds cut the per-sweep work from O(E_pad + V*C) to
    O(cap_e + cap_v*C). Reported per engine and R-MAT family: us_per_call
    both ways, per-round frontier sizes, and the round-2+ sweep-cost ratio
    (the slab is fixed-capacity, so capacities ARE the honest per-sweep
    cost, not the occupancies; spilled rounds pay the full price). Results
    are asserted bit-identical — the frontier is an execution bypass,
    never a semantics change."""
    from repro.core import ColoringSpec, color
    from repro.core.frontier import frontier_capacities
    print(f"\n== frontier compare: on/off x engine (scale {scale}, "
          f"P={concurrency}) ==")
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        E_pad, V = g.num_directed_edges, g.num_vertices
        cap_v, cap_e = frontier_capacities(V, E_pad, g.max_degree())
        for eng in ["sort", "bitmap"]:
            base = dict(strategy="iterative", engine=eng,
                        concurrency=concurrency, max_rounds=256)
            rep_off, us_off = _timed(
                color, g, ColoringSpec(frontier="off", **base), repeat=3)
            rep_on, us_on = _timed(
                color, g, ColoringSpec(frontier="on", **base), repeat=3)
            assert np.array_equal(rep_off.colors, rep_on.colors), (name, eng)
            assert rep_off.rounds == rep_on.rounds
            assert np.array_equal(rep_off.conflicts_per_round,
                                  rep_on.conflicts_per_round)
            assert validate_coloring(g, rep_on.colors)
            fs = rep_on.frontier_sizes_per_round
            sweeps = np.asarray(rep_on.sweeps_per_round)
            # round-2+ sweep cost: edges+vertices processed per sweep, full
            # path vs the static slab (spilled rounds pay the full price)
            unit_full, unit_slab = E_pad + V, cap_e + cap_v
            cost_off = int((sweeps[1:]).sum()) * unit_full
            cost_on = int(sum(
                int(s) * (unit_slab if f > 0 else unit_full)
                for s, f in zip(sweeps[1:], fs[1:])))
            ratio = cost_off / cost_on if cost_on else float("nan")
            _row(f"frontier/{name}/{eng}", us_on,
                 f"us_off={us_off:.1f};rounds={rep_on.rounds};"
                 f"colors={rep_on.num_colors};"
                 f"round2plus_cost_ratio={ratio:.1f};"
                 f"frontier_sizes={[int(f) for f in fs][:12]}",
                 us_per_call_off=round(us_off, 1),
                 rounds=int(rep_on.rounds),
                 colors=int(rep_on.num_colors),
                 sweeps_per_round=[int(s) for s in sweeps],
                 conflicts_per_round=[int(c) for c in
                                      rep_on.conflicts_per_round],
                 frontier_sizes_per_round=[int(f) for f in fs],
                 cap_v=cap_v, cap_e=cap_e,
                 round2plus_cost_off=cost_off,
                 round2plus_cost_on=cost_on,
                 round2plus_cost_ratio=round(ratio, 2))


def stream_compare(scale=10, concurrency=64, batch_fracs=(0.001, 0.01, 0.1)):
    """Streaming-delta shootout (the ISSUE-5 tentpole claim): a graph under
    edge churn is repaired incrementally — the endpoints of newly
    conflicting edges seed the compacted frontier of a ``"recolor"`` run
    (repro.core.dynamic) — vs recolored from scratch. Both paths run the
    SAME compiled plan (warm start vs cold start of one program), so the
    ratio isolates the algorithmic saving: O(seed slab) sweeps + zero
    retrace vs a full speculation pass over the padded edge list. Reported
    per engine, R-MAT family and delta-batch size (0.1% / 1% / 10% of
    |E|); repaired colorings are asserted valid and within the provable
    ``max_degree_seen + 1`` palette bound for every engine backend, and
    the fresh-vs-incremental color ratio rides the JSON row."""
    from repro.core import ColoringSpec, DynamicColoring
    print(f"\n== stream compare: incremental repair vs full recolor "
          f"(scale {scale}, P={concurrency}) ==")
    rng = np.random.default_rng(0)
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        V = g.num_vertices
        for eng in ["sort", "bitmap"]:
            dyn = DynamicColoring(
                g, ColoringSpec(strategy="recolor", engine=eng,
                                concurrency=concurrency, max_rounds=256))
            for frac in batch_fracs:
                m = max(1, int(dyn.graph.num_edges * frac))
                ins = np.stack([rng.integers(0, V, m),
                                rng.integers(0, V, m)], 1)
                cur = dyn.graph.undirected_edges()
                dels = cur[rng.choice(cur.shape[0], m, replace=False)]
                dr = dyn.apply_batch(inserts=ins, deletes=dels)
                us_inc = dr.wall_time_s * 1e6
                # fresh full recoloring of the SAME updated graph through
                # the same plan (cold start: no state = everything pending)
                fresh, us_full = _timed(dyn.plan, dyn.graph, repeat=1)
                assert validate_coloring(dyn.graph, dyn.colors), (name, eng)
                assert validate_coloring(dyn.graph, fresh.colors), (name, eng)
                # the provable invariant is on color VALUES (no assigned
                # color exceeds max_degree_seen + 1); the distinct count
                # is <= that but would not catch a runaway color
                assert int(dyn.colors.max()) <= dyn.color_bound, (name, eng)
                assert dyn.num_colors <= dyn.color_bound, (name, eng)
                ratio = us_full / max(us_inc, 1e-9)
                _row(f"stream/{name}/{eng}/b{frac}", us_inc,
                     f"us_full={us_full:.1f};repair_speedup={ratio:.1f}x;"
                     f"seed={dr.seed_size};delta=+{dr.inserted}/-{dr.deleted};"
                     f"colors_inc={dyn.num_colors};"
                     f"colors_fresh={fresh.num_colors};"
                     f"bound={dyn.color_bound}",
                     us_per_call_full=round(us_full, 1),
                     repair_speedup=round(ratio, 2),
                     batch_frac=frac, inserted=dr.inserted,
                     deleted=dr.deleted, seed_size=dr.seed_size,
                     repaired=dr.repaired,
                     colors_incremental=dyn.num_colors,
                     colors_fresh=fresh.num_colors,
                     color_bound=dyn.color_bound,
                     color_ratio=round(
                         dyn.num_colors / max(1, fresh.num_colors), 3),
                     plan_traces=dyn.plan.traces,
                     recompiles=dyn.recompiles)


def serve_bench(scale=10, requests=48, tenants=3, max_batch=8):
    """Async coloring service under open-loop mixed-tenant load (the
    ISSUE-7 tentpole claim): Poisson arrivals from ``tenants`` coloring
    tenants plus one streaming tenant submitting edge-delta batches, all
    through the bounded-admission + deficit-round-robin + deadline-flush
    scheduler (repro.serve.coloring.AsyncColoringService). The plan cache
    is warmed off-clock, so the load measures serving, not compilation.

    The gated ``us_per_call`` is flush EXECUTION time per request
    (``exec_s / requests``) — a machine-speed-scaling quantity — NOT the
    end-to-end latency, which is deadline-dominated by construction
    (waiting out a 5ms flush budget is invariant across machines and
    would poison the bench gate's median normalization). p50/p99 latency,
    cache hit rate, the flush-reason histogram and max queue age ride the
    JSON fields instead.

    Asserted per family: every served coloring is valid, AND the deadline
    guarantee holds — no request's queue age exceeded the flush budget
    plus in-flight-flush stall (a few ``max_exec_s``) plus scheduler slop.
    Arrival rate and deadline are CALIBRATED against the measured warm
    flush cost (~50% utilization, deadline = one max_batch's worth of
    work), so the load — and the age-bound assertion — is meaningful on
    any machine speed rather than trivially over- or under-saturated.
    """
    from repro.core import ColoringSpec
    from repro.serve.coloring import AdmissionError, AsyncColoringService
    print(f"\n== serve bench: open-loop mixed-tenant async serving "
          f"(scale {scale}, {requests} req x {tenants} tenants + 1 stream, "
          f"batch {max_batch}, calibrated deadline) ==")
    for name in GRAPHS:
        spec = ColoringSpec(strategy="iterative", engine="sort",
                            concurrency=64)
        graphs = [rmat.paper_graph(name, scale=scale, seed=s)
                  for s in range(requests)]
        svc = AsyncColoringService(
            default_spec=spec, max_batch=max_batch, max_delay_s=1.0,
            max_queue_depth=4 * max_batch * (tenants + 1))
        # warm every envelope off-clock — compile AND trace both serving
        # paths (single call + the fixed-shape padded map the flush uses):
        # the load measures serving, not compilation. The warm map cost
        # calibrates the open-loop rate below.
        by_env = {}
        for g in graphs:
            by_env.setdefault(svc.plans.envelope(spec, g), g)
        t_req = 0.0
        for env, g in by_env.items():
            plan, _, _ = svc.plans.get(spec, env)
            plan(g)
            plan.map([g] * max_batch)
            t0 = time.perf_counter()
            plan.map([g] * max_batch)
            t_req = max(t_req,
                        (time.perf_counter() - t0) / max_batch)
        # deadline = one full batch's worth of serving; arrivals at ~50%
        # utilization -> a mix of size flushes (bursts) and deadline
        # flushes (lulls), never steady-state overload
        deadline_s = max_batch * t_req
        svc.max_delay_s = deadline_s
        g0 = graphs[0]
        dyn = svc.open_stream("stream", g0,
                              ColoringSpec(strategy="recolor", engine="sort",
                                           concurrency=64, max_rounds=256))
        # prime the stream's warm-start trace: one conflicting edge insert
        # (two same-colored endpoints always exist: colors < |V|)
        c = np.asarray(dyn.colors)
        u = int(np.argmax(np.bincount(c) >= 2))
        uu, vv = np.flatnonzero(c == u)[:2]
        dyn.apply_batch(inserts=[[int(uu), int(vv)]])
        rng = np.random.default_rng(0)
        m = max(1, g0.num_edges // 100)
        base_edges = g0.undirected_edges()
        deltas = [
            (np.stack([rng.integers(0, g0.num_vertices, m),
                       rng.integers(0, g0.num_vertices, m)], 1),
             base_edges[rng.integers(0, base_edges.shape[0], m)])
            for _ in range(requests // 6)]
        arrivals = np.cumsum(rng.exponential(2.0 * t_req, requests))

        t0 = time.perf_counter()
        handles, di = [], 0
        i = 0
        while i < requests:
            if time.perf_counter() - t0 >= arrivals[i]:
                try:
                    handles.append(
                        svc.submit(graphs[i], tenant=f"t{i % tenants}"))
                    if i % 6 == 5 and di < len(deltas):
                        ins, dels = deltas[di]
                        svc.submit_delta("stream", inserts=ins,
                                         deletes=dels)
                        di += 1
                    i += 1
                except AdmissionError:
                    svc.pump()
            else:
                svc.pump()
        svc.drain()
        wall = time.perf_counter() - t0

        for h, g in zip(handles, graphs):
            assert validate_coloring(g, h.result().report.colors), name
        dyn = svc.stream("stream")
        assert validate_coloring(dyn.graph, dyn.colors), name
        snap = svc.metrics.snapshot()
        cum, win = snap["cumulative"], snap["window"]
        # the deadline-flush guarantee, asserted on the real clock: queue
        # age is bounded by budget + in-flight-flush stall + slop (pump
        # flushes due batches serially, so a batch can wait out a few
        # earlier flushes)
        age_bound = deadline_s + 5 * cum["max_exec_s"] + 0.05
        assert cum["max_queue_age_s"] <= age_bound, (
            f"{name}: max queue age {cum['max_queue_age_s']:.4f}s exceeds "
            f"deadline bound {age_bound:.4f}s")
        us_exec = cum["exec_s"] / cum["requests"] * 1e6
        _row(f"serve/{name}/mixed", us_exec,
             f"p50={win['p50_ms']:.1f}ms;p99={win['p99_ms']:.1f}ms;"
             f"hit_rate={snap['cache_hit_rate']:.2f};"
             f"flushes={cum['flushes']};"
             f"reasons={cum['flush_reasons']};"
             f"max_age={cum['max_queue_age_s'] * 1e3:.1f}ms;"
             f"gps={cum['requests'] / wall:.1f}",
             p50_ms=round(win["p50_ms"], 2),
             p99_ms=round(win["p99_ms"], 2),
             max_ms=round(win["max_ms"], 2),
             cache_hit_rate=round(snap["cache_hit_rate"], 3),
             flushes=cum["flushes"],
             flush_reasons=cum["flush_reasons"],
             batched_requests=cum["batched_requests"],
             stream_deltas=cum["stream_deltas"],
             rejected=cum["rejected"],
             retraces=cum["retraces"],
             max_queue_age_ms=round(cum["max_queue_age_s"] * 1e3, 2),
             deadline_ms=round(deadline_s * 1e3, 2),
             age_bound_ms=round(age_bound * 1e3, 2),
             requests=cum["requests"], tenants=tenants,
             throughput_rps=round(cum["requests"] / wall, 1))


def _dist_worker(payload: str) -> None:
    """``--dist-worker`` entry point: one fixed-size host mesh (the parent
    set XLA_FLAGS before spawning us, so jax initializes with exactly
    ``devices`` CPU devices), all three R-MAT families x {1d, 2d}
    partitioning x {boundary, full} wire through the distributed BSP
    program. Prints one JSON object on the last stdout line."""
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.analysis.wirecost import closed_form_table
    from repro.core.distributed import (build_distributed_coloring,
                                        partition_graph, slab_entry_bytes)
    from repro.core.frontier import frontier_capacities
    from repro.parallel.compression import halo_bytes, halo_words
    from repro.jax_compat import set_mesh

    cfg = json.loads(payload)
    scale, D = int(cfg["scale"]), int(cfg["devices"])
    assert len(jax.devices()) >= D, "parent must set XLA_FLAGS device count"
    mesh = Mesh(np.asarray(jax.devices()[:D]), ("x",))
    out = {"devices": D, "graphs": {}}
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        V, wc = g.num_vertices, g.max_degree() + 1
        per_scheme = {}
        for scheme in ("1d", "2d"):
            lay = partition_graph(g, D, scheme=scheme)
            Vp = D * lay.verts_local
            fcv, fce = frontier_capacities(V, D * lay.edges_local,
                                           g.max_degree(),
                                           capacity=int(cfg["fcv"]))
            res = {}
            for wire in ("boundary", "full"):
                fn = build_distributed_coloring(
                    mesh, lay.verts_local, lay.edges_local, engine="sort",
                    max_colors=wc, frontier_cap_v=fcv, frontier_cap_e=fce,
                    wire=wire, wire_colors=wc)
                ops = (jnp.asarray(lay.lsrc), jnp.asarray(lay.ldst),
                       jnp.asarray(lay.bnd))
                with set_mesh(mesh):
                    c, r, conf, sw, fr = fn(*ops)  # compile + warm
                    t0 = time.perf_counter()
                    for _ in range(3):
                        jax.block_until_ready(fn(*ops))
                    us = (time.perf_counter() - t0) / 3 * 1e6
                cols, r = lay.unpermute(np.asarray(c).reshape(-1)), int(r)
                assert validate_coloring(g, cols), (name, scheme, wire)
                res[wire] = dict(
                    colors=cols.tolist(), rounds=r, us=us,
                    conf=np.asarray(conf)[:r].tolist(),
                    front=np.asarray(fr)[:r].tolist())
            b, f = res["boundary"], res["full"]
            assert (b["colors"], b["rounds"], b["conf"], b["front"]) == \
                   (f["colors"], f["rounds"], f["conf"], f["front"]), \
                "boundary and full wires must be bit-identical"
            # bytes-on-wire per round (all_gather payload; D cancels from
            # ring-traffic ratios so per-exchange payload is the honest
            # unit). The per-tier byte counts come from the runtime
            # helpers (halo_bytes / slab_entry_bytes — the code the wire
            # actually compiles); both wires share the slab tier on
            # rounds where the frontier fits (front > 0)
            Bl, Wb = lay.boundary_local, halo_words(lay.boundary_local, wc)
            slab_entry = slab_entry_bytes(Vp, wc)
            t_halo = halo_bytes(Bl, wc, D)
            t_slab = D * fcv * slab_entry
            t_spill = Vp * 2
            # cross-check against the SPMD verifier's independently
            # derived closed forms AT THE MEASURED LAYOUT: runtime-vs-
            # analyzer drift in either accounting fails the benchmark
            # (the WIRE cost table is the contract, DESIGN.md §Perf)
            tab = closed_form_table(
                num_devices=D, verts_local=lay.verts_local,
                boundary_local=Bl, wire_colors=wc, frontier_cap_v=fcv,
                wire="boundary", scheme=scheme)["tiers"]
            full_tab = closed_form_table(
                num_devices=D, verts_local=lay.verts_local,
                boundary_local=Bl, wire_colors=wc, frontier_cap_v=fcv,
                wire="full", scheme=scheme)["tiers"]
            assert tab["halo"]["bytes_per_round"] == t_halo, \
                (tab["halo"], t_halo)
            assert tab["setup"]["bytes_once"] == D * Bl * 4
            assert tab["slab"]["bytes_per_round"] == t_slab, \
                (tab["slab"], t_slab)
            assert full_tab["spill"]["bytes_per_round"] == t_spill
            rounds, n_slab = b["rounds"], sum(1 for x in b["front"] if x > 0)
            bnd_bytes = ((rounds - n_slab) * t_halo
                         + n_slab * t_slab) / rounds
            full_bytes = ((rounds - n_slab) * t_spill
                          + n_slab * t_slab) / rounds
            per_scheme[scheme] = dict(
                rounds=rounds, conf=b["conf"], front=b["front"],
                us_boundary=b["us"], us_full=f["us"], rounds_full=f["rounds"],
                verts_local=lay.verts_local, boundary_local=Bl,
                halo_words=Wb, fcv=fcv, slab_rounds=n_slab,
                tier_halo_bytes=t_halo, tier_slab_bytes=t_slab,
                tier_spill_bytes=t_spill,
                boundary_bytes_per_round=bnd_bytes,
                full_wire_bytes_per_round=full_bytes,
                gather16_bytes_per_round=Vp * 2,
                gather32_bytes_per_round=Vp * 4,
                wire_ratio=Vp * 4 / bnd_bytes,
                wire_ratio_vs_full=full_bytes / bnd_bytes)
        out["graphs"][name] = per_scheme
    print(json.dumps(out))


def dist_scale(scale=10, shards=(2, 4, 8), fcv=16):
    """Distributed-wire scaling sweep (the ISSUE-9 tentpole claim): the
    boundary-only halo exchange vs the full ``[Vp]`` gather, per shard
    count and partitioning scheme, on multi-process host meshes (one
    subprocess per shard count — XLA's device count is fixed at process
    start, so each D gets a fresh interpreter with
    ``--xla_force_host_platform_device_count=D``).

    Reported per (graph, scheme, D): bytes-on-wire per round for the
    boundary wire (halo words on plain rounds, the packed H-C3 slab on
    frontier rounds), for the full-wire spill tier, and for the raw
    ``[Vp]`` int32 color gather a naive BSP round ships — ``wire_ratio``
    is boundary vs that raw gather (selection x bit-packing x slab),
    ``wire_ratio_vs_full`` is boundary vs the repo's own packed-int16
    spill tier. At scale 10 / edge factor 8 essentially every vertex is
    boundary (the R-MAT families have no cut structure), so vs the
    packed-int16 tier the win is the packing factor (~2x); the >= 4x
    criterion is asserted against the raw int32 gather on the 4-shard
    1d mesh (larger meshes report their measured ratios — RMAT-B's
    9-bit halo entries land at ~3.9x on 8 shards). Bit parity between
    the wires (colors, rounds, conflict and frontier histories) is
    asserted in-worker for every cell, and round counts must match the
    full wire within +1."""
    print(f"\n== dist scale: boundary vs full wire x shards x scheme "
          f"(scale {scale}, shards {list(shards)}, fcv {fcv}) ==")
    import repro.core  # namespace package: anchor on a real module file
    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(repro.core.__file__))))
    for D in shards:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={D}")
        env["PYTHONPATH"] = os.pathsep.join(
            [src_dir] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                         if p])
        payload = json.dumps(dict(scale=scale, devices=D, fcv=fcv))
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--dist-worker", payload],
            capture_output=True, text=True, env=env, timeout=1200)
        if proc.returncode != 0:
            raise RuntimeError(
                f"dist worker (D={D}) failed:\n{proc.stderr[-4000:]}")
        res = json.loads(proc.stdout.strip().splitlines()[-1])
        for name, per_scheme in res["graphs"].items():
            for scheme, r in per_scheme.items():
                assert r["rounds"] <= r["rounds_full"] + 1, (name, scheme, D)
                # the measured per-round average is a mix of plain-halo
                # rounds and slab rounds: it must land inside the static
                # WIRE cost table's tier envelope (the in-worker asserts
                # already pinned each tier to the closed form exactly)
                lo = min(r["tier_halo_bytes"], r["tier_slab_bytes"])
                hi = max(r["tier_halo_bytes"], r["tier_slab_bytes"])
                assert lo <= r["boundary_bytes_per_round"] <= hi, (
                    f"{name}/{scheme}/D{D}: measured "
                    f"{r['boundary_bytes_per_round']:.0f} B/round outside "
                    f"the static tier envelope [{lo}, {hi}]")
                if D == 4 and scheme == "1d":
                    assert r["wire_ratio"] >= 4.0, (
                        f"{name}/D{D}: boundary wire ships "
                        f"{r['boundary_bytes_per_round']:.0f} B/round, under "
                        f"4x vs the {r['gather32_bytes_per_round']} B raw "
                        f"[Vp] int32 gather")
                _row(f"dist/{name}/{scheme}/D{D}", r["us_boundary"],
                     f"us_full={r['us_full']:.1f};rounds={r['rounds']};"
                     f"bytes_bnd={r['boundary_bytes_per_round']:.0f};"
                     f"bytes_full={r['full_wire_bytes_per_round']:.0f};"
                     f"ratio_i32={r['wire_ratio']:.2f}x;"
                     f"ratio_full={r['wire_ratio_vs_full']:.2f}x;"
                     f"Bl={r['boundary_local']}/{r['verts_local']}",
                     us_per_call_full=round(r["us_full"], 1),
                     us_per_round=round(r["us_boundary"] / r["rounds"], 1),
                     devices=D, scheme=scheme, rounds=r["rounds"],
                     rounds_full=r["rounds_full"],
                     conflicts_per_round=r["conf"],
                     frontier_sizes_per_round=r["front"],
                     verts_local=r["verts_local"],
                     boundary_local=r["boundary_local"],
                     halo_words=r["halo_words"], fcv=r["fcv"],
                     slab_rounds=r["slab_rounds"],
                     boundary_bytes_per_round=round(
                         r["boundary_bytes_per_round"], 1),
                     full_wire_bytes_per_round=round(
                         r["full_wire_bytes_per_round"], 1),
                     gather16_bytes_per_round=r["gather16_bytes_per_round"],
                     gather32_bytes_per_round=r["gather32_bytes_per_round"],
                     wire_ratio=round(r["wire_ratio"], 2),
                     wire_ratio_vs_full=round(r["wire_ratio_vs_full"], 2))


def kernel_firstfit(scale=13):
    print(f"\n== Pallas firstfit/fused engines vs sort-mex engine "
          f"(scale {scale}) ==")
    g = rmat.paper_graph("RMAT-G", scale=scale, seed=0)
    dg = g.to_device(layout=("edges", "ell"))
    res_s, us_s = _timed(color_iterative, dg, concurrency=256, repeat=1)
    res_k, us_k = _timed(color_iterative, dg, concurrency=256,
                         engine="ell_pallas", repeat=1)
    res_f, us_f = _timed(color_iterative, dg, concurrency=256,
                         engine="fused_pallas", repeat=1)
    ok = validate_coloring(g, np.asarray(res_k.colors))
    okf = validate_coloring(g, np.asarray(res_f.colors))
    assert np.array_equal(np.asarray(res_k.colors), np.asarray(res_f.colors))
    _row("kernel/sort_engine", us_s, f"colors={res_s.num_colors}")
    _row("kernel/pallas_engine", us_k,
         f"colors={res_k.num_colors};valid={ok};interpret_mode=True")
    _row("kernel/fused_engine", us_f,
         f"colors={res_f.num_colors};valid={okf};interpret_mode=True")


def comm_schedule_bench():
    print("\n== Coloring-scheduled MoE all-to-all (framework application) ==")
    rng = np.random.default_rng(0)
    for d in [16, 64, 256]:
        counts = (rng.random((d, d)) < 0.3).astype(int)
        tr = moe_all_to_all_transfers(counts)
        sch, us = _timed(schedule_transfers, tr, repeat=1)
        _row(f"comm/{d}dev", us,
             f"transfers={len(tr)};rounds={sch.num_rounds};"
             f"lower_bound={sch.lower_bound};gap={sch.optimality_gap:.2f}")


# family name -> (runner(args, scale), default scale or None). The default
# lives HERE only (main() applies ``--scale`` over it); keep the
# module-docstring table in sync. --help lists exactly these names.
FAMILIES = {
    "table2_graph_properties":
        (lambda a, s: table2_graph_properties(scale=s), 16),
    "fig7_9_strong_scaling": (lambda a, s: fig7_9_strong_scaling(scale=s), 15),
    "fig10_conflicts": (lambda a, s: fig10_conflicts(scale=s), 16),
    "fig11_colors": (lambda a, s: fig11_colors(scale=s), 15),
    "dataflow_exactness": (lambda a, s: dataflow_exactness(scale=s), 15),
    "engine_compare":
        (lambda a, s: engine_compare(scale=s, with_ell=a.ell), 13),
    "d2_compare": (lambda a, s: d2_compare(scale=s), 9),
    "plan_throughput": (lambda a, s: plan_throughput(scale=s), 11),
    "frontier_compare": (lambda a, s: frontier_compare(scale=s), 13),
    "stream_compare": (lambda a, s: stream_compare(scale=s), 10),
    "kernel_firstfit": (lambda a, s: kernel_firstfit(scale=s), 13),
    "serve_bench": (lambda a, s: serve_bench(scale=s), 10),
    "dist_scale": (lambda a, s: dist_scale(scale=s), 10),
    "comm_schedule": (lambda a, s: comm_schedule_bench(), None),
}


def _flush_json(path: str, families_done, args) -> None:
    """(Re)write the JSON artifact atomically — tmp file in the target's
    directory, then rename — so a crash mid-run (or mid-write) can never
    lose or corrupt the rows of already-completed families."""
    payload = {
        "schema": 1,
        "families": list(families_done),
        "scale_override": args.scale,
        "backend": jax.default_backend(),
        "rows": RECORDS,
    }
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)


def run_families(selected, args, json_path=None) -> None:
    """Run each family in order, flushing the JSON artifact after EVERY
    completed family — one crashing family loses only its own rows."""
    done = []
    for fam in selected:
        runner, default_scale = FAMILIES[fam]
        runner(args, args.scale or default_scale)
        done.append(fam)
        if json_path:
            _flush_json(json_path, done, args)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="paper-reproduction benchmark harness; families: "
                    + ", ".join(FAMILIES))
    ap.add_argument("--families", default=None, metavar="A,B,...",
                    help="comma-separated subset of benchmark families to "
                         f"run (default: all). Known: {', '.join(FAMILIES)}")
    ap.add_argument("--scale", type=int, default=None,
                    help="override graph scale for the heavy benchmarks "
                         "(per-family defaults in the registry table)")
    ap.add_argument("--ell", action="store_true",
                    help="include the ell_pallas and fused_pallas backends "
                         "in engine_compare (slow off-TPU: kernels run in "
                         "interpret mode)")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="also write every row machine-readably (name, "
                         "us_per_call, per-family structured fields) — the "
                         "format CI archives as the perf trajectory")
    ap.add_argument("--dist-worker", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--verify", action="store_true",
                    help="run the repro.analysis registry sweep against the "
                         "committed baseline before timing anything (off by "
                         "default — the CI slow lane turns it on): a "
                         "benchmark of a plan the analyzer rejects is a "
                         "number about broken code")
    args = ap.parse_args()
    if args.dist_worker is not None:  # dist_scale subprocess entry point
        _dist_worker(args.dist_worker)
        return
    selected = (list(FAMILIES) if args.families is None
                else [f.strip() for f in args.families.split(",") if f.strip()])
    unknown = [f for f in selected if f not in FAMILIES]
    if unknown:
        ap.error(f"unknown families {unknown}; known: {', '.join(FAMILIES)}")
    if args.verify:
        from repro.analysis import (dedupe, sweep_distributed,
                                    sweep_registry, verify_findings)
        print("verify: sweeping the strategy x engine x model registry...",
              flush=True)
        findings = sweep_registry()
        if "dist_scale" in selected:
            # gate the distributed benchmark on the SPMD verifier: every
            # wire x scheme x engine mesh program must prove collective-
            # safe, cost-accounted and halo-exact before we time it
            print("verify: sweeping the distributed wire x scheme grid...",
                  flush=True)
            findings += sweep_distributed()
        verify_findings(dedupe(findings), mode="error")
        print("verify: clean against the committed baseline")
    print("name,us_per_call,derived")
    run_families(selected, args, json_path=args.json)
    print("\n-- CSV --")
    print("name,us_per_call,derived")
    for r in ROWS:
        print(r)
    if args.json:
        print(f"\nwrote {len(RECORDS)} rows to {args.json}")


if __name__ == "__main__":
    main()
