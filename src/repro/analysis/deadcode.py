"""Dead-export report: public names defined but referenced nowhere.

A *public export* is a top-level ``def``/``class``/assignment whose name has
no leading underscore (or the module's ``__all__``, when declared). A name
is *referenced* when it appears (word-boundary match) anywhere in the
corpus — ``.py`` under ``src``/``tests``/``tools``/``benchmarks`` plus the
repo's markdown docs — beyond its own definition. Two plumbing rules keep
re-exports from laundering dead symbols:

* import statements and ``__all__`` blocks are stripped from every file
  before matching (a bare ``from .m import name`` re-export is not usage);
* in the defining module itself, the definition binding is discounted, so
  a symbol used only where it is defined still needs a second mention
  (an internal call, a registration, a docstring cross-reference) to
  count as live.

Intentionally-dormant modules opt out with a pragma comment of the form
"pending: <why>" after a hash at the start of a line (see
``parallel/compression.py``), which downgrades the module's would-be
DEAD001 findings to a single DEAD100 info ("exports exempt until wired
up"). The pragma is a *promise with a name* — grep the pragma to find the
debt.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Sequence, Tuple

from .findings import Finding

PENDING_PRAGMA = re.compile(r"^\s*#\s*pending:\s*(?P<why>\S.*)$", re.M)

# names that are structurally referenced even when no source mentions them
_IMPLICIT = frozenset({"main"})


def module_exports(source: str, filename: str) -> List[str]:
    """Public export names of one module (``__all__`` wins if declared)."""
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError:
        return []
    declared: List[str] = []
    names: List[str] = []
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.append(node.name)
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    if tgt.id == "__all__" and isinstance(
                            node.value, (ast.List, ast.Tuple)):
                        declared.extend(
                            e.value for e in node.value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str))
                    else:
                        names.append(tgt.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Name):
            names.append(node.target.id)
    if declared:
        return declared
    return [n for n in names if not n.startswith("_")]


def strip_plumbing(source: str) -> str:
    """Blank out import statements and ``__all__`` blocks (including their
    parenthesized/bracketed continuation lines) so re-export plumbing does
    not count as a reference."""
    out_lines: List[str] = []
    active = False  # inside an import/__all__ statement
    depth = 0       # its unclosed () / [] brackets
    for line in source.splitlines():
        if not active and line.lstrip().startswith(
                ("from ", "import ", "__all__")):
            active = True
            depth = 0
        if active:
            depth += (line.count("(") + line.count("[")
                      - line.count(")") - line.count("]"))
            out_lines.append("")
            if depth <= 0 and not line.rstrip().endswith("\\"):
                active = False
            continue
        out_lines.append(line)
    return "\n".join(out_lines)


def _corpus(repo_root: str) -> List[Tuple[str, str]]:
    """(path, plumbing-stripped text) for every reference-countable file."""
    out: List[Tuple[str, str]] = []
    for sub in ("src", "tests", "tools", "benchmarks"):
        base = os.path.join(repo_root, sub)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in sorted(os.walk(base)):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for fname in sorted(filenames):
                if fname.endswith(".py"):
                    path = os.path.join(dirpath, fname)
                    with open(path, "r", encoding="utf-8") as f:
                        out.append((path, strip_plumbing(f.read())))
    for doc in ("README.md", "DESIGN.md", "ROADMAP.md"):
        path = os.path.join(repo_root, doc)
        if os.path.isfile(path):
            with open(path, "r", encoding="utf-8") as f:
                out.append((path, f.read()))
    return out


def scan_package(package_root: str, repo_root: str,
                 context: str = "deadcode") -> List[Finding]:
    """DEAD001/DEAD100 findings for every module under ``package_root``."""
    corpus = _corpus(repo_root)
    findings: List[Finding] = []
    for dirpath, dirnames, filenames in sorted(os.walk(package_root)):
        dirnames[:] = [d for d in dirnames
                       if d not in ("__pycache__", ".git")]
        for fname in sorted(filenames):
            if not fname.endswith(".py") or fname == "__init__.py":
                continue
            path = os.path.join(dirpath, fname)
            with open(path, "r", encoding="utf-8") as f:
                source = f.read()
            rel = os.path.relpath(path, os.path.dirname(package_root))
            rel = rel.replace(os.sep, "/")
            site_file = rel.split("repro/", 1)[-1] if "repro/" in rel else rel
            pragma = PENDING_PRAGMA.search(source)
            exports = module_exports(source, path)
            own = strip_plumbing(source)
            dead = [n for n in exports
                    if n not in _IMPLICIT
                    and not _referenced(n, path, own, corpus)]
            if pragma is not None:
                if dead:
                    findings.append(Finding(
                        "DEAD100", f"{site_file}:<module>",
                        f"pending ({pragma.group('why').strip()}): "
                        f"{len(dead)} unreferenced export(s) exempt: "
                        + ", ".join(sorted(dead)), context))
                continue
            for name in sorted(dead):
                findings.append(Finding(
                    "DEAD001", f"{site_file}:{name}",
                    "public export referenced nowhere outside its defining "
                    "module (re-exports in __init__.py do not count)",
                    context))
    return findings


def _referenced(name: str, defining_path: str, defining_stripped: str,
                corpus: Sequence[Tuple[str, str]]) -> bool:
    pat = re.compile(rf"\b{re.escape(name)}\b")
    # in-module: any mention beyond the definition binding itself
    if len(pat.findall(defining_stripped)) > 1:
        return True
    for path, text in corpus:
        if path == defining_path:
            continue
        if pat.search(text):
            return True
    return False
