"""Framework application of the paper: schedule a MoE expert all-to-all into
conflict-free communication rounds by coloring the transfer-conflict graph
(DESIGN.md §3).

    PYTHONPATH=src python examples/color_comm_schedule.py --devices 64
"""
import argparse

import numpy as np

from repro.core import schedule_transfers
from repro.core.comm_schedule import moe_all_to_all_transfers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=64)
    ap.add_argument("--density", type=float, default=0.3,
                    help="fraction of (src,dst) pairs with traffic")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    counts = (rng.random((args.devices, args.devices)) < args.density).astype(int)
    transfers = moe_all_to_all_transfers(counts)
    sch = schedule_transfers(transfers)

    t = np.asarray(transfers)
    for r in sch.rounds:  # verify: no port reused within a round
        assert len(set(t[r, 0])) == len(r) and len(set(t[r, 1])) == len(r)

    print(f"{len(transfers)} transfers across {args.devices} devices")
    print(f"scheduled into {sch.num_rounds} conflict-free rounds "
          f"(port-degree lower bound {sch.lower_bound}, "
          f"gap {sch.optimality_gap:.2f}x)")
    for i, r in enumerate(sch.rounds[:5]):
        print(f"  round {i}: {len(r)} transfers")
    if len(sch.rounds) > 5:
        print(f"  ... {len(sch.rounds) - 5} more rounds")


if __name__ == "__main__":
    main()
