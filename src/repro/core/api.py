"""The front door: ``ColoringSpec`` -> compiled ``ColoringPlan`` -> unified
``ColoringReport`` (DESIGN.md §API).

The paper's thesis is that ONE speculate-then-resolve scheme spans radically
different machines once the machine-specific pieces are pluggable. PR 1 made
the *mex inner loop* pluggable (:class:`repro.core.engine.MexBackend`); this
module does the same for the *algorithms*: the three drivers become
registered :class:`ColoringStrategy` instances behind one declarative entry
point, so every cross-cutting axis (engine, model, ordering, bounds) is
threaded once — here — instead of once per driver.

Three ways in, strictest first:

* ``color(graph, spec)`` — one-shot: resolve the spec, run the strategy,
  return a :class:`ColoringReport`. The ergonomic path; compiles per call
  shape like the legacy functions.
* ``compile_plan(spec, graph_or_shape)`` -> :class:`ColoringPlan` — the
  compile-once, color-many path the serving roadmap needs. The plan lowers
  the model, binds the mex backend, fixes every static shape (vertex count,
  bucket-padded edge capacity, color capacity) and jit-specializes ONCE;
  ``plan(graph)`` then serves **any same-bucket graph with zero retrace**
  (:func:`repro.core.graph.pad_bucket` quantizes edge counts so "same
  shape" is achievable in practice), and ``plan.map(graphs)`` vmaps a batch
  through one program for throughput.
* the legacy ``color_iterative`` / ``color_dataflow`` / ``color_distributed``
  functions — thin back-compat shims over the same registry (bit-identical
  results; see iterative.py / dataflow.py / distributed.py).

Orderings (paper §5.1, ``repro.core.ordering.ORDERINGS``) are applied by
relabeling the *constraint* graph before coloring and un-relabeling the
colors on the way out — reports are **always in original vertex ids**, for
every model (under ``d2``/``pd2`` the ordering ranks constraint-graph
degrees, which is the quantity that matters for D2 color quality).

Registering a new algorithm (Rokos-style detect-and-recolor, a distributed
recoloring pass, ...) is a :class:`ColoringStrategy` subclass plus one
:func:`register_strategy` call — the spec/plan/report plumbing, ordering,
model lowering and batching come for free.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from .distance2 import MODELS, as_constraint_graph, constraint_host_graph
from .distributed import PARTITION_SCHEMES
from .engine import EngineSpec, MexBackend, get_backend
from .frontier import FRONTIER_MODES, frontier_capacities, resolve_frontier
from .graph import BipartiteGraph, DeviceGraph, Graph, pad_bucket
from .ordering import ORDERINGS

_LOWERINGS = ("auto", "wedge", "square")
WIRE_MODES = ("auto", "boundary", "full")


# --------------------------------------------------------------------------
# the spec
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ColoringSpec:
    """Declarative description of a coloring run: *what* to compute and on
    *which* machinery — everything :func:`compile_plan` needs to specialize
    a program, and nothing data-dependent.

    strategy     registered :class:`ColoringStrategy` name (or instance):
                 ``"iterative"`` | ``"dataflow"`` | ``"distributed"``;
    model        coloring semantics: ``"d1"`` | ``"d2"`` | ``"pd2"``
                 (repro.core.distance2);
    engine       first-fit mex backend name/instance (repro.core.engine);
    ordering     vertex-visit priority, a ``repro.core.ordering.ORDERINGS``
                 key — applied as a relabeling of the constraint graph,
                 un-applied on the way out (reports stay in original ids);
    ordering_seed  seed for stochastic orderings (``"random"``);
    lowering     D2/PD2 constraint lowering: ``"auto"`` | ``"wedge"`` |
                 ``"square"`` (distance2.py; plans always use the dedup'd
                 square lowering so shapes are paddable);
    side         the colored class under ``model="pd2"``;
    concurrency  ITERATIVE's lockstep virtual-thread count;
    max_rounds / max_sweeps / color_bound  as on the legacy drivers;
    mesh         jax Mesh for the distributed strategy (None = 1-device);
    local_concurrency  distributed per-device concurrency (C=1 is the
                 classic Bozdag scheme);
    frontier     active-set execution (repro.core.frontier): ``"auto"``
                 (compact rounds >= 1 whenever the graph carries the
                 incident-edge auxiliary — the default), ``"on"`` (require
                 it), ``"off"`` (full sweeps every round). Bit-identical
                 results either way — the frontier is an execution bypass,
                 never a semantics change;
    frontier_capacity  static vertex-slab capacity override (0 = the
                 |V|/32 bucket ladder; the edge slab scales with it);
    wire         the distributed per-round exchange: ``"auto"`` (boundary
                 wire; a plan whose served graph overflows the pinned halo
                 capacity spills to a lazily-compiled full-gather program),
                 ``"boundary"`` (require the boundary wire — halo overflow
                 raises), ``"full"`` (the legacy ``[Vp]`` gather, kept as
                 the parity oracle). All three are bit-identical;
    partition    distributed vertex ownership: ``"1d"`` contiguous blocks
                 or ``"2d"`` block-cyclic over a device grid (spreads
                 R-MAT hub regions — repro.core.distributed).
    """

    strategy: Union[str, "ColoringStrategy"] = "iterative"
    model: str = "d1"
    engine: EngineSpec = "sort"
    ordering: str = "natural"
    ordering_seed: int = 0
    lowering: str = "auto"
    side: str = "left"
    concurrency: int = 64
    max_rounds: int = 64
    max_sweeps: int = 4096
    color_bound: int = 0
    mesh: Optional[object] = None  # jax.sharding.Mesh; object keeps the
    # dataclass importable without touching jax.sharding at class-def time
    local_concurrency: int = 1
    frontier: str = "auto"
    frontier_capacity: int = 0
    wire: str = "auto"
    partition: str = "1d"

    def __post_init__(self):
        if self.model not in MODELS:
            raise ValueError(f"unknown coloring model {self.model!r}; "
                             f"choose from {MODELS}")
        if self.lowering not in _LOWERINGS:
            raise ValueError(f"unknown lowering {self.lowering!r}; "
                             f"choose from {_LOWERINGS}")
        if self.frontier not in FRONTIER_MODES:
            raise ValueError(f"unknown frontier mode {self.frontier!r}; "
                             f"choose from {FRONTIER_MODES}")
        if self.wire not in WIRE_MODES:
            raise ValueError(f"unknown wire mode {self.wire!r}; "
                             f"choose from {WIRE_MODES}")
        if self.partition not in PARTITION_SCHEMES:
            raise ValueError(f"unknown partition scheme {self.partition!r}; "
                             f"choose from {PARTITION_SCHEMES}")

    def resolve(self) -> Tuple["ColoringStrategy", MexBackend]:
        """Resolve the registered pieces (strategy, mex backend) by name."""
        return get_strategy(self.strategy), get_backend(self.engine)

    def to_dict(self) -> dict:
        """JSON-able export (the checkpoint/restore wire format): every
        field by registry *name*, so a restored process resolves them
        against its own registries. Mesh-bound specs are process-local
        (device handles don't serialize) and are rejected."""
        if self.mesh is not None:
            raise ValueError(
                "mesh-bound specs are process-local and cannot be "
                "serialized; rebuild the spec with the restoring "
                "process's mesh instead")
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "mesh"}
        if not isinstance(d["strategy"], str):
            d["strategy"] = get_strategy(d["strategy"]).name
        if not isinstance(d["engine"], str):
            d["engine"] = get_backend(d["engine"]).name
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ColoringSpec":
        """Inverse of :meth:`to_dict` — unknown keys are rejected by the
        dataclass constructor, so a stale checkpoint fails loudly."""
        return cls(**d)


# --------------------------------------------------------------------------
# the report
# --------------------------------------------------------------------------
class RawColoring(NamedTuple):
    """What every strategy returns (a pytree, so it flows through jit/vmap):
    colors in the *strategy's* label space, per-round histories, and an
    unconverged flag. :class:`ColoringPlan`/:func:`color` normalize it into
    a :class:`ColoringReport` (un-relabeling, host transfer, wall time)."""

    colors: jnp.ndarray               # [V] int32 >= 1
    rounds: jnp.ndarray               # scalar int32
    conflicts_per_round: jnp.ndarray  # [max_rounds] int32
    sweeps_per_round: jnp.ndarray     # [max_rounds] int32
    unconverged: jnp.ndarray          # scalar bool
    frontier_per_round: jnp.ndarray   # [max_rounds] int32: active vertices
    # compacted in each round (0 = the round took the full-edge path; for
    # DATAFLOW, entry 0 counts the slab-compacted sweeps instead)


def _invert_order(order: np.ndarray) -> np.ndarray:
    """``order[k]`` = vertex visited k-th -> ``perm[v]`` = new id of vertex
    v (the relabel argument of :meth:`Graph.relabel`; ``colors[perm]`` is
    the exact inverse on the way out)."""
    perm = np.empty_like(order)
    perm[order] = np.arange(order.shape[0], dtype=order.dtype)
    return perm


def _build_report(raw: "RawColoring", spec: "ColoringSpec",
                  strategy_name: str, perm: Optional[np.ndarray],
                  t0: float, *, batch_denom: int = 1) -> "ColoringReport":
    """Normalize a strategy's RawColoring into the unified report: raise on
    non-convergence, un-relabel to original vertex ids, trim histories,
    stamp (amortized) wall time. The one place this logic lives — both the
    one-shot :func:`color` path and :class:`ColoringPlan` route here."""
    if bool(raw.unconverged):
        raise RuntimeError(
            f"{strategy_name} did not converge within "
            f"max_rounds={spec.max_rounds} / max_sweeps={spec.max_sweeps}")
    colors = np.asarray(raw.colors)
    if perm is not None:
        colors = colors[perm]  # back to original vertex ids
    rounds = int(raw.rounds)
    return ColoringReport(
        colors=colors, rounds=rounds,
        conflicts_per_round=np.asarray(raw.conflicts_per_round)[:rounds],
        sweeps_per_round=np.asarray(raw.sweeps_per_round)[:rounds],
        frontier_sizes_per_round=(
            np.asarray(raw.frontier_per_round)[:rounds]),
        wall_time_s=(time.perf_counter() - t0) / max(1, batch_denom),
        spec=spec)


def _trivial_report(spec: "ColoringSpec", num_vertices: int, t0: float, *,
                    batch_denom: int = 1,
                    colors: Optional[np.ndarray] = None) -> "ColoringReport":
    """The degenerate result (V=0, or no constraint edges at all): every
    vertex takes color 1 — vacuously valid — in zero rounds. The engines
    never run, so no phantom slab is ever allocated. ``colors`` preserves
    a recolor warm start: committed (positive) entries pass through
    untouched — any positive coloring is valid without constraints — and
    only uncolored slots take color 1."""
    if colors is not None:
        carried = np.asarray(colors).astype(np.int32)
        carried = np.where(carried > 0, carried, 1).astype(np.int32)
    else:
        carried = np.ones(num_vertices, np.int32)
    empty = np.zeros(0, np.int32)
    return ColoringReport(
        colors=carried, rounds=0,
        conflicts_per_round=empty, sweeps_per_round=empty.copy(),
        frontier_sizes_per_round=empty.copy(),
        wall_time_s=(time.perf_counter() - t0) / max(1, batch_denom),
        spec=spec)


def _graph_extent(g, spec: "ColoringSpec") -> Tuple[int, int]:
    """(colored-class size, raw edge count) of an input graph, readable
    without lowering the coloring model — the degenerate-input check."""
    if isinstance(g, BipartiteGraph):
        n = g.num_left if spec.side == "left" else g.num_right
        return n, g.num_edges
    return g.num_vertices, g.num_directed_edges


@dataclasses.dataclass
class ColoringReport:
    """The one result type every strategy produces.

    ``colors`` is a host int32 array **in original vertex ids** (any
    ``ordering`` relabeling is undone). Histories are trimmed to ``rounds``
    entries. ``frontier_sizes_per_round[r]`` is the number of active
    vertices round r swept through the compacted frontier slab (0 = the
    round took the full-edge path; DATAFLOW reports its slab-compacted
    sweep count in entry 0). ``wall_time_s`` covers lowering + execution +
    host transfer (plan-batched runs report the amortized per-graph time).

    Summary scalars (``num_colors``, ``total_conflicts``, ``sweeps``) are
    memoized — reports get re-summarized in benchmark/serving loops, and a
    distinct-count over a large coloring is not free. ``num_colors`` is
    the number of DISTINCT positive colors, not ``colors.max()``:
    recolor/delete paths legitimately leave palette gaps."""

    colors: np.ndarray
    rounds: int
    conflicts_per_round: np.ndarray
    sweeps_per_round: np.ndarray
    wall_time_s: float
    spec: ColoringSpec
    frontier_sizes_per_round: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))

    @functools.cached_property
    def num_colors(self) -> int:
        # distinct positive colors, NOT colors.max(): recolor/delete paths
        # leave palette gaps, and the max would overstate the count
        from .metrics import num_colors as _distinct
        return _distinct(self.colors)

    @functools.cached_property
    def total_conflicts(self) -> int:
        return int(self.conflicts_per_round.sum())

    @functools.cached_property
    def sweeps(self) -> int:
        return int(self.sweeps_per_round.sum())

    def __repr__(self) -> str:  # compact: reports get printed in loops
        s = self.spec
        return (f"ColoringReport(strategy={s.strategy!r}, model={s.model!r}, "
                f"colors={self.num_colors}, rounds={self.rounds}, "
                f"sweeps={self.sweeps}, conflicts={self.total_conflicts}, "
                f"wall_time_s={self.wall_time_s:.4f})")


# --------------------------------------------------------------------------
# the strategy layer
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ColoringStrategy:
    """Base class: a named, registered coloring algorithm.

    A strategy supplies ONE thing: how to turn a constraint
    :class:`DeviceGraph` into a :class:`RawColoring`
    (:meth:`device_program`). The base class derives everything else —
    one-shot execution over the legacy lowering path (:meth:`oneshot`),
    plan compilation with a trace-counting jit wrapper (:meth:`compile`),
    and vmapped batching (:meth:`compile_batched`). Host-level strategies
    (the distributed BSP driver partitions on host) override
    :meth:`compile`/:meth:`oneshot` wholesale and set ``wants = "host"``.
    """

    name = "abstract"
    supports_map = True    # plan.map() batching via vmap
    wants = "device"       # "device": executor consumes a DeviceGraph;
                           # "host": executor consumes the host constraint
                           # Graph (strategies that partition themselves)

    # -- the one required hook -------------------------------------------
    def device_program(self, spec: ColoringSpec,
                       backend: MexBackend) -> Callable[[DeviceGraph], RawColoring]:
        raise NotImplementedError

    # -- derived machinery ------------------------------------------------
    def oneshot(self, spec: ColoringSpec, g) -> RawColoring:
        """Run once on ``g`` exactly as the legacy driver would: same model
        lowering (wedge-by-default for d2/pd2), same jit cache, no padding.
        The legacy shims and :func:`color` route through this."""
        backend = get_backend(spec.engine)
        dg = as_constraint_graph(g, spec.model, needs_ell=backend.needs_ell,
                                 strategy=spec.lowering, side=spec.side)
        return self.device_program(spec, backend)(dg)

    def plan_state(self, spec: ColoringSpec, statics: "PlanShape",
                   **runtime) -> Tuple:
        """Normalize per-call runtime state (``plan(g, key=value, ...)``)
        into the extra device arguments this strategy's compiled program
        takes. The base strategies are stateless — any runtime kwarg is an
        error; the ``"recolor"`` strategy overrides this to accept the
        (colors, seed) warm-start pair. Shapes derive from ``statics``
        only, so state never breaks the zero-retrace guarantee."""
        if runtime:
            raise TypeError(
                f"strategy {self.name!r} takes no per-call state; got "
                f"{sorted(runtime)}")
        return ()

    def compile(self, spec: ColoringSpec, statics: "PlanShape",
                trace_hook: Callable[[], None]) -> Callable:
        """Plan-time compilation: one jitted program over the canonical
        (bucket-padded) DeviceGraph (plus any :meth:`plan_state` extras).
        ``trace_hook`` runs at trace time only — the plan counts traces
        with it, and tests assert the count stays at one across
        same-bucket graphs."""
        prog = self.device_program(spec, get_backend(spec.engine))

        def run(dg, *state):
            trace_hook()
            return prog(dg, *state)

        return jax.jit(run)

    def compile_batched(self, spec: ColoringSpec, statics: "PlanShape",
                        trace_hook: Callable[[], None]) -> Callable:
        """The ``plan.map`` program: the same per-graph program vmapped over
        a stacked batch of canonical DeviceGraphs."""
        prog = self.device_program(spec, get_backend(spec.engine))

        def run(dg):
            trace_hook()
            return prog(dg)

        return jax.jit(jax.vmap(run))


_REGISTRY: Dict[str, ColoringStrategy] = {}

StrategySpec = Union[str, ColoringStrategy]


def register_strategy(strategy: ColoringStrategy, *,
                      overwrite: bool = False) -> ColoringStrategy:
    """Register a strategy instance under ``strategy.name`` so every spec
    resolves it via ``strategy="<name>"`` (mirror of
    :func:`repro.core.engine.register_backend`)."""
    if strategy.name in _REGISTRY and not overwrite:
        raise ValueError(f"coloring strategy {strategy.name!r} already "
                         "registered")
    _REGISTRY[strategy.name] = strategy
    return strategy


def get_strategy(strategy: StrategySpec) -> ColoringStrategy:
    """Resolve ``strategy`` — a registered name or an instance."""
    if isinstance(strategy, ColoringStrategy):
        return strategy
    try:
        return _REGISTRY[strategy]
    except KeyError:
        raise ValueError(
            f"unknown coloring strategy {strategy!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# the three shipped strategies
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class IterativeStrategy(ColoringStrategy):
    """The paper's Algorithm 2 (speculation + iteration) — iterative.py."""

    name = "iterative"

    def device_program(self, spec, backend):
        from .iterative import _iterative_impl

        def run(dg):
            fcv, fce = resolve_frontier(
                spec.frontier, int(spec.frontier_capacity),
                num_vertices=dg.num_vertices, padded_edges=dg.padded_edges,
                max_degree=dg.max_degree, has_inc=dg.has_frontier)
            colors, rnd, conf, sweeps, fronts, left = _iterative_impl(
                dg, concurrency=int(spec.concurrency),
                max_rounds=int(spec.max_rounds),
                max_sweeps=int(spec.max_sweeps), backend=backend,
                color_bound=int(spec.color_bound),
                frontier_cap_v=fcv, frontier_cap_e=fce)
            return RawColoring(colors, rnd, conf, sweeps, left, fronts)

        return run


@dataclasses.dataclass(frozen=True)
class DataflowStrategy(ColoringStrategy):
    """The paper's Algorithms 3-5 as a chaotic fixpoint — dataflow.py.
    One conflict-free speculative round; ``sweeps_per_round`` holds the
    DAG-depth sweep count."""

    name = "dataflow"

    def device_program(self, spec, backend):
        from .dataflow import _dataflow_impl

        def run(dg):
            fcv, fce = resolve_frontier(
                spec.frontier, int(spec.frontier_capacity),
                num_vertices=dg.num_vertices, padded_edges=dg.padded_edges,
                max_degree=dg.max_degree, has_inc=dg.has_frontier)
            colors, n, changed, nslab = _dataflow_impl(
                dg, max_sweeps=int(spec.max_sweeps), backend=backend,
                color_bound=int(spec.color_bound),
                frontier_cap_v=fcv, frontier_cap_e=fce)
            return RawColoring(colors, jnp.asarray(1, jnp.int32),
                               jnp.zeros((1,), jnp.int32),
                               jnp.reshape(n, (1,)).astype(jnp.int32),
                               changed,
                               jnp.reshape(nslab, (1,)).astype(jnp.int32))

        return run


@dataclasses.dataclass(frozen=True)
class DistributedStrategy(ColoringStrategy):
    """The Bozdag-style BSP driver over a jax mesh — distributed.py. A host
    strategy: it partitions the constraint graph itself, so plans hand it
    the host graph and it manages its own (slab-shaped) jit program.
    ``plan.map`` is unsupported (one mesh program is already the batch)."""

    name = "distributed"
    supports_map = False
    wants = "host"

    @staticmethod
    def _mesh(spec: ColoringSpec):
        if spec.mesh is not None:
            return spec.mesh
        from jax.sharding import Mesh
        return Mesh(np.asarray(jax.devices()[:1]), ("x",))

    def _build(self, spec: ColoringSpec, mesh, *, verts_local: int,
               edges_local: int, max_colors: int, ell_width: int,
               wire: str = "boundary", wire_colors: int = 0):
        from .distributed import build_distributed_coloring
        fcv = fce = 0
        if spec.frontier != "off":
            # per-shard slabs: the BSP driver recovers incident-edge
            # pointers on device, so the frontier is always available here
            fcv, fce = frontier_capacities(
                verts_local, edges_local, ell_width,
                capacity=int(spec.frontier_capacity))
        return build_distributed_coloring(
            mesh, verts_local, edges_local,
            local_concurrency=int(spec.local_concurrency),
            max_rounds=int(spec.max_rounds),
            max_sweeps=int(spec.max_sweeps),
            engine=spec.engine, max_colors=max_colors, ell_width=ell_width,
            frontier_cap_v=fcv, frontier_cap_e=fce,
            wire=wire, wire_colors=wire_colors)

    def _raw(self, spec: ColoringSpec, num_vertices: int, colors, rounds,
             conf, sweeps, fronts) -> RawColoring:
        colors = np.asarray(colors).reshape(-1)[:num_vertices]
        rounds = int(rounds)
        conf = np.asarray(conf)
        unconverged = bool(rounds >= int(spec.max_rounds)
                           and rounds > 0 and conf[rounds - 1] > 0)
        return RawColoring(colors, np.int32(rounds), conf, np.asarray(sweeps),
                           np.bool_(unconverged), np.asarray(fronts))

    def oneshot(self, spec: ColoringSpec, g) -> RawColoring:
        from ..jax_compat import set_mesh
        from .distributed import partition_graph
        host = constraint_host_graph(g, spec.model, side=spec.side)
        mesh = self._mesh(spec)
        D = int(np.prod(mesh.devices.shape))
        layout = partition_graph(host, D, scheme=spec.partition)
        max_colors = host.max_degree() + 1
        if spec.color_bound > 0:
            max_colors = min(max_colors, int(spec.color_bound))
        # one-shot slabs fit the graph exactly, so "auto" never spills
        wire = "full" if spec.wire == "full" else "boundary"
        fn = self._build(spec, mesh, verts_local=layout.verts_local,
                         edges_local=layout.edges_local,
                         max_colors=max_colors, ell_width=host.max_degree(),
                         wire=wire, wire_colors=host.max_degree() + 1)
        with set_mesh(mesh):
            colors, rounds, conf, sweeps, fronts = fn(
                jnp.asarray(layout.lsrc), jnp.asarray(layout.ldst),
                jnp.asarray(layout.bnd))
        colors = layout.unpermute(np.asarray(colors).reshape(-1))
        return self._raw(spec, host.num_vertices, colors, rounds, conf,
                         sweeps, fronts)

    def compile(self, spec: ColoringSpec, statics: "PlanShape",
                trace_hook: Callable[[], None]) -> Callable:
        from ..jax_compat import set_mesh
        from .distributed import partition_graph
        mesh = self._mesh(spec)
        D = int(np.prod(mesh.devices.shape))
        Vl = -(-statics.num_vertices // D)
        # slab capacity: even-split share + R-MAT-skew headroom, bucketed —
        # a graph whose densest partition overflows it raises at call time
        slab = pad_bucket(int(-(-statics.padded_edges // D) * 1.35))
        max_colors = statics.max_degree + 1
        if spec.color_bound > 0:
            max_colors = min(max_colors, int(spec.color_bound))
        use_boundary = spec.wire != "full"
        # halo capacity the boundary program pins; _plan_shape derived it
        # from the compile graph (with headroom). wire_colors is the
        # UNCAPPED Delta+1: packed entries must hold any color the solve
        # can assign, and color_bound caps only the forbid tables
        bcap = int(statics.boundary_cap) if use_boundary else 0
        fn = self._build(spec, mesh, verts_local=Vl, edges_local=slab,
                         max_colors=max_colors, ell_width=statics.max_degree,
                         wire=("boundary" if use_boundary else "full"),
                         wire_colors=statics.max_degree + 1)

        def counted(lsrc, ldst, bnd):
            trace_hook()
            return fn(lsrc, ldst, bnd)

        jfn = jax.jit(counted)
        spill: Dict[str, Callable] = {}

        def spill_fn():
            # wire="auto" halo overflow: a lazily-compiled full-gather
            # program (one extra counted trace, ever). Its bnd operand is
            # an ignored [D, 1] dummy so the spill shape is call-invariant.
            if "fn" not in spill:
                f = self._build(spec, mesh, verts_local=Vl, edges_local=slab,
                                max_colors=max_colors,
                                ell_width=statics.max_degree, wire="full",
                                wire_colors=statics.max_degree + 1)

                def counted_full(lsrc, ldst, bnd):
                    trace_hook()
                    return f(lsrc, ldst, bnd)

                spill["fn"] = jax.jit(counted_full)
            return spill["fn"]

        def executor(host: Graph) -> RawColoring:
            layout = partition_graph(host, D, pad_edges_to=slab,
                                     scheme=spec.partition)
            if not use_boundary:
                # the full wire never reads bnd; a fixed dummy keeps the
                # jit signature constant across served graphs
                run = jfn
                bnd = np.full((D, 1), layout.verts_local, np.int32)
            elif layout.boundary_local <= bcap:
                run, bnd = jfn, layout.padded_boundary(bcap)
            elif spec.wire == "boundary":
                raise ValueError(
                    f"graph has {layout.boundary_local} boundary vertices "
                    f"on its densest shard, above the plan halo capacity "
                    f"{bcap}; compile a plan from this graph, or use "
                    "wire='auto' to spill to the full-gather wire")
            else:
                run = spill_fn()
                bnd = np.full((D, 1), layout.verts_local, np.int32)
            with set_mesh(mesh):
                colors, rounds, conf, sweeps, fronts = run(
                    jnp.asarray(layout.lsrc), jnp.asarray(layout.ldst),
                    jnp.asarray(bnd))
            colors = layout.unpermute(np.asarray(colors).reshape(-1))
            return self._raw(spec, statics.num_vertices, colors, rounds,
                             conf, sweeps, fronts)

        return executor


@dataclasses.dataclass(frozen=True)
class RecolorStrategy(ColoringStrategy):
    """Rokos-style detect-and-recolor (arXiv:1505.04086) as a registered
    strategy — the paper's speculation loop run from a caller-supplied
    warm start instead of the cold (no colors, all pending) one.

    Per-call state rides :meth:`plan_state`: ``plan(g, colors=, seed=)``
    hands the compiled program the committed color vector plus the seed
    mask of vertices to repair (the endpoints of newly conflicting edges,
    under streaming deltas — repro.core.dynamic builds exactly that).
    Phase 1 then recolors ONLY the seed set — committed neighbors forbid
    their colors, so a repaired coloring is valid by the same argument as
    a fresh one — and because the seed is a tiny conflicted tail, round 0
    already takes the compacted frontier path (``seed_frontier``), making
    a delta repair cost O(frontier slab), not O(E).

    With no state supplied (``color(g, strategy="recolor")``, or a bare
    ``plan(g)``), the warm start degenerates to the cold start and the
    strategy is bit-identical to ``"iterative"``. Both arrays are [V] in
    the plan's vertex-id space, so ``ordering`` must stay ``"natural"``
    whenever state is passed. ``plan.map`` is unsupported (delta repairs
    are latency-bound single calls, not throughput batches)."""

    name = "recolor"
    supports_map = False

    def device_program(self, spec, backend):
        from .iterative import _iterative_impl

        def run(dg, colors0=None, pending0=None):
            fcv, fce = resolve_frontier(
                spec.frontier, int(spec.frontier_capacity),
                num_vertices=dg.num_vertices, padded_edges=dg.padded_edges,
                max_degree=dg.max_degree, has_inc=dg.has_frontier)
            colors, rnd, conf, sweeps, fronts, left = _iterative_impl(
                dg, colors0, pending0, concurrency=int(spec.concurrency),
                max_rounds=int(spec.max_rounds),
                max_sweeps=int(spec.max_sweeps), backend=backend,
                color_bound=int(spec.color_bound),
                frontier_cap_v=fcv, frontier_cap_e=fce,
                seed_frontier=True)
            return RawColoring(colors, rnd, conf, sweeps, left, fronts)

        return run

    def plan_state(self, spec, statics, colors=None, seed=None):
        if (colors is not None or seed is not None) \
                and spec.ordering != "natural":
            # cold starts are ordering-invariant (the plan relabels and
            # un-relabels as usual); only a WARM start pins vertex ids
            raise ValueError(
                "recolor repairs an existing coloring in place: state "
                "arrays are in plan vertex ids, so ordering must be "
                "'natural' (got {!r})".format(spec.ordering))
        V = statics.num_vertices
        if colors is None:
            colors_d = jnp.zeros((V,), jnp.int32)
        else:
            colors = np.asarray(colors)
            if colors.shape != (V,):
                raise ValueError(f"recolor state: colors shape "
                                 f"{colors.shape} != ({V},)")
            colors_d = jnp.asarray(colors.astype(np.int32))
        if seed is None:
            seed_d = jnp.ones((V,), jnp.bool_)
        else:
            seed = np.asarray(seed)
            if seed.shape != (V,):
                raise ValueError(f"recolor state: seed shape "
                                 f"{seed.shape} != ({V},)")
            seed_d = jnp.asarray(seed.astype(np.bool_))
        return colors_d, seed_d


register_strategy(IterativeStrategy())
register_strategy(DataflowStrategy())
register_strategy(DistributedStrategy())
register_strategy(RecolorStrategy())


# --------------------------------------------------------------------------
# plans
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PlanShape:
    """The static envelope a :class:`ColoringPlan` specializes on — in
    *constraint-graph* space (after the d2/pd2 lowering, where applicable).

    num_vertices   exact vertex count every served graph must match;
    padded_edges   directed-edge capacity (graphs pad up to it; derived
                   shapes pass through :func:`repro.core.graph.pad_bucket`);
    max_degree     constraint max-degree bound: sizes the table backends'
                   color capacity and the ELL slab width. Graphs above it
                   are rejected (a too-small table silently drops forbids);
    boundary_cap   distributed halo capacity: the per-shard boundary slab
                   width the plan's boundary wire pins (``_plan_shape``
                   derives it, with headroom, by partitioning the compile
                   graph). 0 = no halo slab — correct for device-strategy
                   plans and 1-device meshes; a served graph overflowing
                   the cap spills to the full wire (``wire="auto"``) or is
                   rejected (``wire="boundary"``).
    """

    num_vertices: int
    padded_edges: int
    max_degree: int
    boundary_cap: int = 0


def _plan_shape(spec: ColoringSpec, graph_or_shape) -> PlanShape:
    if isinstance(graph_or_shape, PlanShape):
        return graph_or_shape
    if isinstance(graph_or_shape, DeviceGraph):
        raise TypeError(
            "compile_plan needs a host Graph/BipartiteGraph (plans relabel "
            "and pad on host) or an explicit PlanShape")
    host = constraint_host_graph(graph_or_shape, spec.model, side=spec.side)
    boundary_cap = 0
    if get_strategy(spec.strategy).wants == "host" and spec.wire != "full":
        # halo envelope for the boundary wire: partition the compile graph
        # and give the densest shard's boundary count the same skew
        # headroom as the edge slab, capped at Vl (every vertex boundary)
        from .distributed import partition_graph
        mesh = DistributedStrategy._mesh(spec)
        D = int(np.prod(mesh.devices.shape))
        if D > 1:
            Bl = partition_graph(host, D,
                                 scheme=spec.partition).boundary_local
            if Bl:
                Vl = -(-host.num_vertices // D)
                boundary_cap = min(Vl, pad_bucket(int(Bl * 1.35)))
    return PlanShape(num_vertices=host.num_vertices,
                     padded_edges=pad_bucket(host.num_directed_edges),
                     max_degree=host.max_degree(),
                     boundary_cap=boundary_cap)


class ColoringPlan:
    """A compiled coloring program: spec + static shape envelope, serving
    any same-bucket graph with zero recompilation.

    ``plan(graph)`` -> :class:`ColoringReport`;
    ``plan.map([g0, g1, ...])`` -> list of reports via ONE vmapped program
    (strategies with ``supports_map``).

    ``plan.traces`` counts jit traces of the underlying program(s) — it
    stays at 1 (2 once ``map`` is also used) however many same-bucket
    graphs are served; the test suite pins this.
    """

    def __init__(self, spec: ColoringSpec, graph_or_shape):
        self.spec = spec
        self.strategy, self._backend = spec.resolve()
        self.statics = _plan_shape(spec, graph_or_shape)
        if spec.ordering not in ORDERINGS:
            raise ValueError(f"unknown ordering {spec.ordering!r}; "
                             f"choose from {sorted(ORDERINGS)}")
        self._traces = 0
        # a degenerate envelope (no vertices, or no constraint-edge
        # capacity at all) never compiles or runs a program: every served
        # graph is vacuously colored with color 1 — no phantom slabs
        self._degenerate = (self.statics.num_vertices == 0
                            or self.statics.padded_edges == 0)
        self._executor = (None if self._degenerate else
                          self.strategy.compile(spec, self.statics,
                                                self._count_trace))
        self._batched: Optional[Callable] = None

    # ------------------------------------------------------------- internals
    def _count_trace(self):
        self._traces += 1

    @property
    def traces(self) -> int:
        """Number of jit traces taken by this plan's program(s)."""
        return self._traces

    def _canonicalize(self, g) -> Tuple[object, Optional[np.ndarray]]:
        """Host graph -> (canonical input, relabel perm or None).

        Lowers the model (square lowering: paddable, dedup'd), applies the
        ordering relabel, pads edges to the bucket and pins every static
        DeviceGraph field to the plan envelope so the jit cache key is
        constant across served graphs."""
        spec, st = self.spec, self.statics
        host = constraint_host_graph(g, spec.model, side=spec.side)
        if host.num_vertices != st.num_vertices:
            raise ValueError(
                f"plan compiled for {st.num_vertices} vertices, got a graph "
                f"with {host.num_vertices}; compile a new plan")
        perm = None
        if spec.ordering != "natural":
            order = ORDERINGS[spec.ordering](host, spec.ordering_seed)
            perm = _invert_order(order)
            host = host.relabel(perm)
        if host.num_directed_edges > st.padded_edges:
            raise ValueError(
                f"graph has {host.num_directed_edges} constraint edges, "
                f"above the plan bucket {st.padded_edges}; compile a plan "
                "from this graph (or a larger PlanShape)")
        if host.max_degree() > st.max_degree:
            raise ValueError(
                f"graph max degree {host.max_degree()} exceeds the plan "
                f"bound {st.max_degree}; compile a plan with a larger "
                "PlanShape.max_degree (the color tables would drop forbids)")
        if self.strategy.wants == "host":
            return host, perm
        layout = ("edges", "ell") if self._backend.needs_ell else "edges"
        dg = host.to_device(layout=layout, pad_edges_to=st.padded_edges,
                            ell_width=max(1, st.max_degree))
        # pin the static metadata to the envelope: num_directed_edges and
        # max_degree are pytree aux data (= jit cache key), and the impls
        # read them only to size color tables, for which the envelope bound
        # is exactly as correct as the per-graph value
        dg = dataclasses.replace(dg, num_directed_edges=st.padded_edges,
                                 max_degree=st.max_degree)
        return dg, perm

    def _finish(self, raw: RawColoring, perm: Optional[np.ndarray],
                t0: float, *, batch_denom: int = 1) -> ColoringReport:
        return _build_report(raw, self.spec, self.strategy.name, perm, t0,
                             batch_denom=batch_denom)

    # ----------------------------------------------------------- introspection
    def wire_cost(self) -> Optional[dict]:
        """The closed-form bytes-on-wire cost table for this plan's
        envelope (distributed plans only; ``None`` otherwise).

        The same per-tier accounting the SPMD verifier checks the traced
        mesh program against (``repro.analysis.wirecost``) and the
        ``dist_scale`` benchmark asserts its measured bytes against —
        ``{"tiers": {"halo": {...}, "setup": {...}, ...}, ...}`` keyed by
        the plan's resolved wire, with the formula strings alongside the
        numbers."""
        if self.strategy.wants != "host":
            return None
        from ..analysis.wirecost import wire_cost_table
        return wire_cost_table(self.spec, self.statics)

    # ------------------------------------------------------------ execution
    def __call__(self, g, **runtime) -> ColoringReport:
        """Color ``g`` through the compiled program. ``runtime`` kwargs are
        per-call state for strategies that take it (``"recolor"``:
        ``colors=``, ``seed=``); stateless strategies reject any."""
        t0 = time.perf_counter()
        canon, perm = self._canonicalize(g)
        state = self.strategy.plan_state(self.spec, self.statics, **runtime)
        if self._degenerate:  # validated above; nothing to run — but a
            # recolor warm start keeps its committed colors (the strategy
            # contract: non-seed vertices never change)
            return _trivial_report(self.spec, self.statics.num_vertices, t0,
                                   colors=runtime.get("colors"))
        raw = self._executor(canon, *state)
        return self._finish(raw, perm, t0)

    def map(self, graphs: Sequence) -> list:
        """Color a batch of same-bucket graphs through ONE vmapped program.

        Returns one :class:`ColoringReport` per graph (original vertex ids,
        per-graph histories; ``wall_time_s`` is the batch time amortized
        per graph)."""
        if not self.strategy.supports_map:
            raise NotImplementedError(
                f"strategy {self.strategy.name!r} does not support batched "
                "plan.map execution")
        graphs = list(graphs)
        if not graphs:
            return []
        t0 = time.perf_counter()
        canons, perms = zip(*(self._canonicalize(g) for g in graphs))
        if self._degenerate:
            return [_trivial_report(self.spec, self.statics.num_vertices,
                                    t0, batch_denom=len(graphs))
                    for _ in graphs]
        if self._batched is None:
            self._batched = self.strategy.compile_batched(
                self.spec, self.statics, self._count_trace)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *canons)
        raws = self._batched(stacked)
        return [
            self._finish(jax.tree.map(lambda x, i=i: x[i], raws), perms[i],
                         t0, batch_denom=len(graphs))
            for i in range(len(graphs))
        ]


def compile_plan(spec: ColoringSpec, graph_or_shape,
                 verify: Optional[str] = None) -> ColoringPlan:
    """Compile ``spec`` against a graph (or an explicit :class:`PlanShape`)
    into a reusable :class:`ColoringPlan`.

    When given a graph, the envelope is derived from its *constraint* form:
    vertex count exact, directed-edge capacity rounded up to the
    :func:`repro.core.graph.pad_bucket` grid, max-degree bound taken as-is.
    Any later graph matching the envelope is served with zero retrace; pass
    a hand-built ``PlanShape`` to leave headroom for a whole family.

    ``verify`` runs the :mod:`repro.analysis` static analyzer over the
    plan's program and envelope before returning (DESIGN.md §Analysis):
    ``"warn"`` emits a Python warning for any finding not covered by the
    committed baseline, ``"error"`` raises
    :class:`repro.analysis.AnalysisError` instead. Distributed plans also
    run the SPMD verifier (collective safety, wire-cost model, halo
    exactness) over the traced mesh program. The analysis happens after
    construction but before the first trace, so a hazardous spec is
    reported (or refused) before any program runs."""
    plan = ColoringPlan(spec, graph_or_shape)
    if verify is not None:
        from ..analysis import verify_plan  # deferred: analysis optional
        verify_plan(plan.spec, plan.statics, mode=verify)
    return plan


# --------------------------------------------------------------------------
# one-shot front door
# --------------------------------------------------------------------------
def color(g, spec: Optional[ColoringSpec] = None, **overrides) -> ColoringReport:
    """One-shot front door: ``color(graph, spec)`` or
    ``color(graph, strategy="dataflow", model="d2", ...)``.

    Resolves the spec against the strategy/backend registries, applies the
    ordering (relabel in, un-relabel out — the report is in original vertex
    ids), runs the strategy exactly as its legacy driver would, and returns
    a :class:`ColoringReport`."""
    spec = ColoringSpec() if spec is None else spec
    if overrides:
        spec = dataclasses.replace(spec, **overrides)
    strategy = get_strategy(spec.strategy)
    if spec.ordering not in ORDERINGS:
        raise ValueError(f"unknown ordering {spec.ordering!r}; "
                         f"choose from {sorted(ORDERINGS)}")
    t0 = time.perf_counter()
    num_colored, num_edges = _graph_extent(g, spec)
    if num_colored == 0 or num_edges == 0:
        # degenerate input: nothing constrains anything — color 1
        # everywhere is valid under every model, and no engine program
        # needs to run (the distributed lowering cannot even express V=0)
        return _trivial_report(spec, num_colored, t0)
    perm = None
    if spec.ordering != "natural":
        if isinstance(g, DeviceGraph):
            raise ValueError(
                "ordering != 'natural' relabels on host: pass a Graph/"
                "BipartiteGraph (or pre-apply repro.core.ordering.apply)")
        host = constraint_host_graph(g, spec.model, side=spec.side)
        perm = _invert_order(ORDERINGS[spec.ordering](host,
                                                      spec.ordering_seed))
        # the constraint graph IS the d1 encoding of the model
        raw = strategy.oneshot(dataclasses.replace(spec, model="d1"),
                               host.relabel(perm))
    else:
        raw = strategy.oneshot(spec, g)
    return _build_report(raw, spec, strategy.name, perm, t0)
