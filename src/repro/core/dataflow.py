"""DATAFLOW / DATAFLOWRECURSIVE — the paper's Algorithms 3-5, adapted to TPU.

The XMT version blocks each vertex's thread on ``readff(color[w])`` for every
smaller-index neighbor ``w`` — hardware dataflow over the dependency DAG
``w -> v  iff  (v,w) in E and w < v``. A TPU has no full/empty bits, so we
execute the *same DAG* as a chaotic fixpoint iteration of the dataflow
equations (DESIGN.md §2):

    c[v] <- mex{ c[w] : w in adj(v), w < v }     (uncolored w contributes 0)

All vertices update in parallel each sweep; vertices of dataflow level L hold
their final value after L sweeps (level = longest dependency path), so the
iteration converges in ``depth(DAG)`` sweeps to **exactly** the serial greedy
coloring in index order — the same invariant the XMT algorithm guarantees
(priority = vertex index, conceptually Jones-Plassmann). Deadlock-freedom is
structural: levels are computed by iteration, not discovered by blocking, so
DATAFLOWRECURSIVE's ``int_fetch_add`` recursion is unnecessary.

DATAFLOW is ITERATIVE's phase 1 in the fully-concurrent limit with
index-precedence (offset = vertex id): the sweep itself is the shared
:func:`repro.core.engine.fixpoint_sweep`, and the first-fit inner loop is
pluggable via ``engine=`` exactly as in iterative.py.

:func:`dataflow_levels` exposes the DAG depth / wavefront profile — the
"available parallelism" the XMT's 16K threads would have exploited.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .engine import (EngineSpec, SweepSpec, fixpoint_iterate, fixpoint_sweep)
from .graph import DeviceGraph


@dataclasses.dataclass
class DataflowResult:
    colors: jnp.ndarray  # [V] int32, >= 1 — identical to serial greedy
    sweeps: int          # fixpoint sweeps == dataflow DAG depth (+1 check)

    @property
    def num_colors(self) -> int:
        return int(self.colors.max())


@functools.partial(jax.jit,
                   static_argnames=("max_sweeps", "backend", "color_bound"))
def _dataflow_impl(g: DeviceGraph, *, max_sweeps: int, backend,
                   color_bound: int = 0):
    V = g.num_vertices
    max_colors = g.max_degree + 1
    if color_bound > 0:
        max_colors = min(max_colors, color_bound)
    mex = backend.bind(num_vertices=V, max_colors=max_colors,
                       ell_slot=g.ell_slot, ell_width=g.ell_width,
                       max_degree=g.max_degree)
    # dependency edges: only smaller-index neighbors forbid a color
    dep = g.dst < g.src  # padding (src == dst == V) excluded
    spec = SweepSpec(key_v=jnp.where(dep, g.src, V),
                     dyn_idx=g.dst, dyn=dep,
                     static_c=jnp.zeros_like(g.dst))
    colors, n, changed = fixpoint_sweep(
        mex, spec, jnp.zeros((V,), jnp.int32), jnp.ones((V,), jnp.bool_),
        max_sweeps=max_sweeps)
    return colors, n, changed


def color_dataflow(g, max_sweeps: int = 4096,
                   engine: EngineSpec = "sort",
                   color_bound: int = 0, model: str = "d1") -> DataflowResult:
    """``color_bound`` caps the table backends' capacity below Delta+1 —
    a caller-asserted bound, as in :func:`color_iterative`.

    ``model`` selects the coloring semantics ("d1" | "d2" | "pd2"), lowered
    exactly as in :func:`color_iterative`; under "d2"/"pd2" the fixpoint
    reproduces the *serial D2/PD2 greedy* in index order
    (:func:`repro.core.greedy_ref.greedy_color_d2` / ``greedy_color_pd2``),
    since the lowering is index-preserving.

    Back-compat shim over the registered ``"dataflow"``
    :class:`repro.core.api.ColoringStrategy` — same arguments, same
    bit-exact results, legacy :class:`DataflowResult` return. Prefer
    ``repro.core.color(g, strategy="dataflow", ...)`` or
    ``repro.core.compile_plan`` for compile-once reuse."""
    from .api import ColoringSpec, get_strategy  # lazy: api imports us
    spec = ColoringSpec(strategy="dataflow", model=model, engine=engine,
                        max_sweeps=max_sweeps, color_bound=int(color_bound))
    raw = get_strategy("dataflow").oneshot(spec, g)
    if bool(raw.unconverged):
        raise RuntimeError(f"DATAFLOW did not converge in {max_sweeps} sweeps")
    return DataflowResult(colors=raw.colors,
                          sweeps=int(raw.sweeps_per_round[0]))


@functools.partial(jax.jit, static_argnames=("num_vertices", "max_iters"))
def _levels_impl(src, dst, *, num_vertices: int, max_iters: int):
    V = num_vertices
    dep = dst < src

    def step(lv):
        lpad = jnp.concatenate([lv, jnp.zeros((1,), jnp.int32)])
        contrib = jnp.where(dep, lpad[dst], 0)
        seg = (
            jnp.zeros((V,), jnp.int32)
            .at[src].max(contrib, mode="drop")
        )
        return seg + 1

    lv, n, _ = fixpoint_iterate(step, jnp.ones((V,), jnp.int32),
                                max_iters=max_iters)
    return lv, n


def dataflow_levels(g: DeviceGraph, max_iters: int = 4096):
    """Dataflow level of each vertex (longest dependency chain ending at it).

    Returns (levels [V] int32 >= 1, depth). Wavefront L's vertices are
    pairwise independent — the paper's XMT threads resolve exactly this
    schedule through full/empty-bit blocking.
    """
    lv, _ = _levels_impl(g.src, g.dst, num_vertices=g.num_vertices, max_iters=max_iters)
    return lv, int(lv.max())
