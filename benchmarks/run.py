"""Benchmark harness — one function per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows plus per-benchmark detail blocks.
Scales are CPU-feasible reductions of the paper's scale-24..27 graphs (the
claims validated are structural/relative, not absolute wall-clock).

  table2_graph_properties   — paper Table 2 (+Table 4 columns) at scale S
  fig7_9_strong_scaling     — ITERATIVE runtime vs concurrency (proxy for
                              thread scaling: vectorized rounds on CPU)
  fig10_conflicts           — conflicts per round / total / iterations
  fig11_colors              — colors vs concurrency vs serial, all graphs
  dataflow_exactness        — DATAFLOW == serial greedy + sweep counts
  engine_compare            — sort vs bitmap (vs ell_pallas) mex backends on
                              all three graph families: us_per_call plus
                              per-round sweep/conflict counts
  kernel_firstfit           — Pallas firstfit engine vs sort engine timing
  comm_schedule             — coloring-scheduled all-to-all rounds
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

import jax

from repro.core import (rmat, greedy_color, color_iterative, color_dataflow,
                        dataflow_levels, validate_coloring, num_colors,
                        schedule_transfers)
from repro.core.comm_schedule import moe_all_to_all_transfers

GRAPHS = ["RMAT-ER", "RMAT-G", "RMAT-B"]
ROWS = []


def _row(name, us, derived):
    ROWS.append(f"{name},{us:.1f},{derived}")
    print(f"{name},{us:.1f},{derived}")


def _timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / repeat * 1e6


def table2_graph_properties(scale=16):
    print(f"\n== Table 2/4: graph structural properties (scale {scale}) ==")
    print(f"{'graph':8s} {'|V|':>9s} {'|E|':>10s} {'avgdeg':>7s} {'maxdeg':>7s} "
          f"{'var':>10s} {'%isol':>6s}")
    for name in GRAPHS:
        t0 = time.perf_counter()
        g = rmat.paper_graph(name, scale=scale, seed=0)
        us = (time.perf_counter() - t0) * 1e6
        s = g.stats()
        print(f"{name:8s} {s['num_vertices']:9d} {s['num_edges']:10d} "
              f"{s['avg_degree']:7.2f} {s['max_degree']:7d} "
              f"{s['degree_variance']:10.1f} {s['pct_isolated']:6.2f}")
        _row(f"table2/{name}", us,
             f"maxdeg={s['max_degree']};var={s['degree_variance']:.1f};"
             f"isol={s['pct_isolated']:.2f}%")


def fig7_9_strong_scaling(scale=15):
    """Runtime of ITERATIVE vs concurrency (the paper's thread axis).

    On one CPU device the SIMD work per round is constant; what scales is
    rounds x sweeps (the serialization the paper's Fig. 7-9 hides inside
    thread counts). We report device-time per run and the sweep counts.
    """
    print(f"\n== Fig 7/8/9 proxy: ITERATIVE cost vs concurrency (scale {scale}) ==")
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        dg = g.to_device()
        for p in [1, 16, 128, 1024, 16384]:
            res, us = _timed(color_iterative, dg, concurrency=p, repeat=1)
            _row(f"fig7/{name}/P{p}", us,
                 f"rounds={res.rounds};sweeps={res.sweeps};"
                 f"conflicts={res.total_conflicts};colors={res.num_colors}")


def fig10_conflicts(scale=16):
    print(f"\n== Fig 10: conflicts (RMAT-B, scale {scale}) ==")
    g = rmat.paper_graph("RMAT-B", scale=scale, seed=0)
    dg = g.to_device()
    # the XMT row uses the paper's thread:vertex RATIO (12800 : 2^24), not
    # the absolute thread count — at reduced scale that's what preserves the
    # conflict regime; the absolute-P row is kept for the stress reading
    xmt_ratio_p = max(2, int(12800 * g.num_vertices / (1 << 24)))
    for p, label in [(16, "nehalem-16T"), (128, "niagara-128T"),
                     (xmt_ratio_p, f"xmt-ratio-{xmt_ratio_p}T"),
                     (12800, "xmt-absolute-12800T")]:
        res, us = _timed(color_iterative, dg, concurrency=p, repeat=1)
        cpr = [int(c) for c in np.asarray(res.conflicts_per_round)[:res.rounds]]
        frac1 = cpr[0] / max(1, sum(cpr))
        _row(f"fig10/{label}", us,
             f"total={res.total_conflicts};iters={res.rounds};"
             f"frac_round1={frac1:.2f};conflicts_per_round={cpr[:12]}")
        if p < g.num_vertices:  # the paper's regime; at reduced --scale the
            # absolute-thread row can exceed |V| conflicts summed over rounds
            assert res.total_conflicts < g.num_vertices, "conflicts must be << |V|"


def fig11_colors(scale=15):
    print(f"\n== Fig 11: colors used vs serial (scale {scale}) ==")
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        serial = num_colors(greedy_color(g))
        dg = g.to_device()
        cols = {}
        for p in [16, 128, 12800]:
            res = color_iterative(dg, concurrency=p)
            assert validate_coloring(g, np.asarray(res.colors))
            cols[p] = res.num_colors
        df = color_dataflow(dg).num_colors
        _row(f"fig11/{name}", 0.0,
             f"serial={serial};iter16={cols[16]};iter128={cols[128]};"
             f"iter12800={cols[12800]};dataflow={df}")
        assert df == serial, "DATAFLOW must equal serial (C4)"


def dataflow_exactness(scale=15):
    print(f"\n== DATAFLOW: exactness + sweeps vs DAG depth (scale {scale}) ==")
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        dg = g.to_device()
        res, us = _timed(color_dataflow, dg, repeat=1)
        _, depth = dataflow_levels(dg)
        same = bool(np.array_equal(np.asarray(res.colors), greedy_color(g)))
        _row(f"dataflow/{name}", us,
             f"sweeps={res.sweeps};dag_depth={depth};equals_serial={same}")
        assert same


def engine_compare(scale=13, concurrency=256, with_ell=False):
    """Mex-backend shootout: the sort-based O(E log E) inner loop vs the
    O(E) scatter-or bitmap (vs the Pallas ELL kernel with --ell), on all
    three paper graph families. Same speculation driver, same semantics —
    the per-round sweep/conflict histories must match exactly; what differs
    is us_per_call of the first-fit formulation (Rokos arXiv:1505.04086:
    the inner loop dominates and rewards the cheaper per-sweep form)."""
    engines = ["sort", "bitmap"] + (["ell_pallas"] if with_ell else [])
    print(f"\n== engine compare: {'/'.join(engines)} "
          f"(scale {scale}, P={concurrency}) ==")
    for name in GRAPHS:
        g = rmat.paper_graph(name, scale=scale, seed=0)
        dg = g.to_device(layout=("edges", "ell") if with_ell else "edges")
        ref = None
        for eng in engines:
            res, us = _timed(color_iterative, dg, concurrency=concurrency,
                             engine=eng, repeat=1)
            assert validate_coloring(g, np.asarray(res.colors)), (name, eng)
            cpr = [int(c) for c in
                   np.asarray(res.conflicts_per_round)[:res.rounds]]
            spr = [int(s) for s in
                   np.asarray(res.sweeps_per_round)[:res.rounds]]
            _row(f"engine/{name}/{eng}", us,
                 f"colors={res.num_colors};rounds={res.rounds};"
                 f"sweeps_per_round={spr[:12]};conflicts_per_round={cpr[:12]}")
            if ref is None:
                ref = (cpr, spr)
            else:
                assert ref == (cpr, spr), \
                    f"backend divergence on {name}: {ref} != {(cpr, spr)}"


def kernel_firstfit(scale=13):
    print(f"\n== Pallas firstfit engine vs sort-mex engine (scale {scale}) ==")
    g = rmat.paper_graph("RMAT-G", scale=scale, seed=0)
    dg = g.to_device(layout=("edges", "ell"))
    res_s, us_s = _timed(color_iterative, dg, concurrency=256, repeat=1)
    res_k, us_k = _timed(color_iterative, dg, concurrency=256,
                         engine="ell_pallas", repeat=1)
    ok = validate_coloring(g, np.asarray(res_k.colors))
    _row("kernel/sort_engine", us_s, f"colors={res_s.num_colors}")
    _row("kernel/pallas_engine", us_k,
         f"colors={res_k.num_colors};valid={ok};interpret_mode=True")


def comm_schedule_bench():
    print("\n== Coloring-scheduled MoE all-to-all (framework application) ==")
    rng = np.random.default_rng(0)
    for d in [16, 64, 256]:
        counts = (rng.random((d, d)) < 0.3).astype(int)
        tr = moe_all_to_all_transfers(counts)
        sch, us = _timed(schedule_transfers, tr, repeat=1)
        _row(f"comm/{d}dev", us,
             f"transfers={len(tr)};rounds={sch.num_rounds};"
             f"lower_bound={sch.lower_bound};gap={sch.optimality_gap:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=None,
                    help="override graph scale for the heavy benchmarks")
    ap.add_argument("--ell", action="store_true",
                    help="include the ell_pallas backend in engine_compare "
                         "(slow off-TPU: kernels run in interpret mode)")
    args = ap.parse_args()
    s = args.scale
    print("name,us_per_call,derived")
    table2_graph_properties(scale=s or 16)
    fig7_9_strong_scaling(scale=s or 15)
    fig10_conflicts(scale=s or 16)
    fig11_colors(scale=s or 15)
    dataflow_exactness(scale=s or 15)
    engine_compare(scale=s or 13, with_ell=args.ell)
    kernel_firstfit(scale=s or 13)
    comm_schedule_bench()
    print("\n-- CSV --")
    print("name,us_per_call,derived")
    for r in ROWS:
        print(r)


if __name__ == "__main__":
    main()
