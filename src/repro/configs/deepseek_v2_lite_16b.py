"""deepseek-v2-lite-16b [moe+mla]: 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400, MLA kv_lora=512 (rope 64 / nope 128 / v 128), MoE 64 routed
top-6 + 2 shared, first layer dense (d_ff 10944). Assigned line says both
"64e" and "160 routed"; real V2-Lite has 64 routed — we implement 64 and
note the discrepancy in DESIGN.md. [arXiv:2405.04434]"""
from ..models.config import ModelConfig, MoEConfig, MLAConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", num_layers=27, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=102400,
        moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                      d_shared=1408, first_dense_layers=1,
                      first_dense_d_ff=10944, partition="expert"),
        mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                      v_head_dim=128))


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="moe", num_layers=3, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=512,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=64, num_shared=1,
                      d_shared=64, first_dense_layers=1, first_dense_d_ff=256,
                      partition="expert"),
        mla=MLAConfig(kv_lora_rank=32, rope_head_dim=16, nope_head_dim=32,
                      v_head_dim=32))
