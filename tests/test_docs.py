"""Docs-rot guard: the ``python`` code blocks of the front-door docs
(README.md and DESIGN.md) must run verbatim.

Thin pytest wrapper around tools/check_doc_snippets.py (the same entry the
CI docs lane uses), so the tier-1 gate catches a stale quickstart too.
Pseudocode fences are tagged ``python-norun`` and skipped.
"""
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

from check_doc_snippets import run_file  # noqa: E402


@pytest.mark.parametrize("doc", ["README.md", "DESIGN.md"])
def test_doc_snippets_run(doc):
    path = os.path.join(REPO, doc)
    assert os.path.exists(path), f"{doc} is missing"
    old = os.getcwd()
    os.chdir(REPO)
    try:
        assert run_file(path) == 0
    finally:
        os.chdir(old)
