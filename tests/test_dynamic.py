"""Streaming/dynamic-coloring tests (repro.core.dynamic + the ``"recolor"``
strategy): incremental repairs stay valid under every engine, same-envelope
delta batches never retrace, cold-start recolor is bit-identical to
ITERATIVE, palette counting survives deletion gaps (the ``num_colors``
distinct-count bugfix), degenerate (V=0 / E=0) graphs flow through every
strategy without phantom slabs, and a hypothesis property drives random
delta sequences against a fresh coloring of the final graph."""
import numpy as np
import pytest

from repro.core import (ColoringSpec, DynamicColoring, Graph, PlanShape,
                        color, compile_plan, num_colors, rmat,
                        validate_coloring)
from repro.core.frontier import frontier_capacities
from repro.core.graph import pad_bucket

ENGINES = ["sort", "bitmap"]
STRATEGIES = ["iterative", "dataflow", "distributed", "recolor"]


def _graph(name="RMAT-G", scale=8, seed=0):
    return rmat.paper_graph(name, scale=scale, seed=seed)


def _delta(rng, g, n_ins, n_del):
    V = g.num_vertices
    ins = np.stack([rng.integers(0, V, n_ins),
                    rng.integers(0, V, n_ins)], 1)
    cur = g.undirected_edges()
    dels = (cur[rng.integers(0, cur.shape[0], n_del)]
            if cur.shape[0] else None)
    return ins, dels


# ----------------------------------------------------------- graph deltas
def test_apply_delta_set_semantics():
    g = Graph.from_edges(6, np.array([[0, 1], [1, 2], [2, 3]]))
    # duplicate + reversed inserts, self loop, no-op delete, real delete
    g2 = g.apply_delta(inserts=[[3, 4], [4, 3], [5, 5], [0, 1]],
                       deletes=[[1, 2], [2, 1], [0, 5]])
    got = set(map(tuple, g2.undirected_edges()))
    assert got == {(0, 1), (2, 3), (3, 4)}
    # an edge in both lists ends present (deletes first, then inserts)
    g3 = g.apply_delta(inserts=[[0, 1]], deletes=[[0, 1]])
    assert (0, 1) in set(map(tuple, g3.undirected_edges()))
    with pytest.raises(ValueError):
        g.apply_delta(inserts=[[0, 6]])


def test_has_edges_membership():
    g = Graph.from_edges(5, np.array([[0, 1], [2, 3]]))
    mask = g.has_edges([[1, 0], [0, 2], [3, 2], [4, 4]])
    assert mask.tolist() == [True, False, True, False]
    assert g.has_edges(np.zeros((0, 2), np.int64)).shape == (0,)


# ------------------------------------------------------ recolor strategy
@pytest.mark.parametrize("engine", ENGINES)
def test_cold_recolor_equals_iterative(engine):
    """With no warm start the recolor strategy IS iterative — bit parity
    across the report."""
    g = _graph()
    a = color(g, ColoringSpec(strategy="iterative", engine=engine,
                              concurrency=16))
    b = color(g, ColoringSpec(strategy="recolor", engine=engine,
                              concurrency=16))
    np.testing.assert_array_equal(a.colors, b.colors)
    assert a.rounds == b.rounds
    np.testing.assert_array_equal(a.conflicts_per_round,
                                  b.conflicts_per_round)


def test_recolor_plan_state_validation():
    g = _graph()
    plan = compile_plan(ColoringSpec(strategy="recolor"), g)
    with pytest.raises(ValueError, match="colors shape"):
        plan(g, colors=np.zeros(3, np.int32))
    with pytest.raises(ValueError, match="seed shape"):
        plan(g, seed=np.zeros(3, bool))
    # stateless strategies reject runtime kwargs outright
    it_plan = compile_plan(ColoringSpec(strategy="iterative"), g)
    with pytest.raises(TypeError, match="no per-call state"):
        it_plan(g, colors=np.zeros(g.num_vertices, np.int32))
    # recolor repairs in place: a WARM start needs natural ordering, but a
    # cold start (no state) is ordering-invariant and must keep working
    ord_plan = compile_plan(
        ColoringSpec(strategy="recolor", ordering="largest_first"), g)
    with pytest.raises(ValueError, match="natural"):
        ord_plan(g, colors=np.ones(g.num_vertices, np.int32))
    assert validate_coloring(g, ord_plan(g).colors)


def test_recolor_repairs_only_the_seed():
    """A warm start with a valid coloring and a seeded subset recolors the
    seed and leaves everything else untouched."""
    g = _graph()
    base = color(g, ColoringSpec(strategy="iterative", concurrency=16))
    assert validate_coloring(g, base.colors)
    plan = compile_plan(ColoringSpec(strategy="recolor", concurrency=16), g)
    seed = np.zeros(g.num_vertices, bool)
    seed[:5] = True
    rep = plan(g, colors=base.colors, seed=seed)
    assert validate_coloring(g, rep.colors)
    np.testing.assert_array_equal(rep.colors[~seed], base.colors[~seed])
    # empty seed: nothing pending, colors pass through bit-identically
    rep0 = plan(g, colors=base.colors,
                seed=np.zeros(g.num_vertices, bool))
    np.testing.assert_array_equal(rep0.colors, base.colors)
    assert rep0.rounds == 0


# ------------------------------------------------------- dynamic coloring
@pytest.mark.parametrize("engine", ENGINES + ["ell_pallas"])
def test_dynamic_stream_valid_and_zero_retrace(engine):
    """The tentpole invariants: every delta batch leaves a valid coloring,
    within the provable palette bound, with plan.traces pinned at 1
    (same-envelope repairs never retrace)."""
    g = _graph(scale=8)
    dyn = DynamicColoring(g, ColoringSpec(strategy="recolor", engine=engine,
                                          concurrency=32))
    assert validate_coloring(dyn.graph, dyn.colors)
    rng = np.random.default_rng(1)
    for _ in range(6):
        ins, dels = _delta(rng, dyn.graph, 30, 25)
        dr = dyn.apply_batch(inserts=ins, deletes=dels)
        assert validate_coloring(dyn.graph, dyn.colors)
        # the bound holds on color VALUES, not just the distinct count
        assert int(dyn.colors.max()) <= dyn.color_bound
        assert dyn.num_colors <= dyn.color_bound
        assert dr.seed_size >= 0
    assert dyn.plan.traces == 1
    assert dyn.recompiles == 0


def test_dynamic_delete_only_keeps_colors():
    """Deletes only relax constraints: no repair runs, colors unchanged."""
    g = _graph(scale=8)
    dyn = DynamicColoring(g)
    before = dyn.colors.copy()
    cur = dyn.graph.undirected_edges()
    dr = dyn.apply_batch(deletes=cur[:40])
    assert not dr.repaired and dr.report is None
    assert dr.deleted == 40
    np.testing.assert_array_equal(dyn.colors, before)
    assert validate_coloring(dyn.graph, dyn.colors)


def test_dynamic_noop_and_duplicate_deltas():
    g = _graph(scale=8)
    dyn = DynamicColoring(g)
    before = dyn.colors.copy()
    e = dyn.graph.undirected_edges()[0]
    dr = dyn.apply_batch(inserts=[e, e, [e[1], e[0]], [0, 0]],
                         deletes=[[e[0], e[0]]])
    assert dr.inserted == 0 and dr.deleted == 0 and dr.seed_size == 0
    np.testing.assert_array_equal(dyn.colors, before)


def test_dynamic_envelope_growth_recompiles():
    """A batch that outgrows the plan envelope recompiles against a larger
    bucket and keeps streaming; a pinned envelope raises instead."""
    g = Graph.from_edges(64, np.array([[i, i + 1] for i in range(40)]))
    dyn = DynamicColoring(g, edge_headroom=1.05)
    st0 = dyn.plan.statics
    rng = np.random.default_rng(0)
    # grow a hub well past the degree bound (and the edge bucket floor
    # absorbs edge growth, so degree drives the recompile)
    hub = np.stack([np.zeros(40, np.int64), 8 + np.arange(40) % 56], 1)
    dyn.apply_batch(inserts=hub)
    extra = np.stack([rng.integers(0, 64, 600), rng.integers(0, 64, 600)], 1)
    dyn.apply_batch(inserts=extra)
    assert dyn.recompiles >= 1
    assert dyn.plan.statics != st0
    assert validate_coloring(dyn.graph, dyn.colors)

    pinned = DynamicColoring(g, plan_shape=PlanShape(
        num_vertices=64, padded_edges=pad_bucket(g.num_directed_edges),
        max_degree=g.max_degree() + 2))
    graph_before, colors_before = pinned.graph, pinned.colors.copy()
    with pytest.raises(ValueError, match="outgrew the pinned"):
        pinned.apply_batch(inserts=extra)
    # the raise leaves the state UNTOUCHED (graph and colors still agree),
    # so the caller can catch, resize and retry the same batch
    assert pinned.graph is graph_before
    np.testing.assert_array_equal(pinned.colors, colors_before)
    assert validate_coloring(pinned.graph, pinned.colors)


def test_dynamic_failed_repair_rolls_back():
    """A repair that raises (e.g. non-convergence inside the plan call)
    leaves the state UNTOUCHED — graph and colors still agree, so the
    caller can relax the spec and retry instead of streaming on with a
    silently invalid pair."""
    dyn = DynamicColoring(_graph(scale=7))
    graph_before, colors_before = dyn.graph, dyn.colors.copy()

    class BoomPlan:  # statics intact (the envelope check runs first),
        statics = dyn.plan.statics  # the repair call itself fails

        def __call__(self, *a, **k):
            raise RuntimeError("did not converge")

    dyn._plan = BoomPlan()
    # same color => non-adjacent (the coloring is valid), so inserting the
    # edge genuinely seeds a repair
    vals, counts = np.unique(colors_before, return_counts=True)
    u, v = np.where(colors_before == vals[np.argmax(counts)])[0][:2]
    with pytest.raises(RuntimeError, match="converge"):
        dyn.apply_batch(inserts=[[int(u), int(v)]])
    assert dyn.graph is graph_before
    np.testing.assert_array_equal(dyn.colors, colors_before)
    assert validate_coloring(dyn.graph, dyn.colors)


def test_degenerate_plan_preserves_warm_start_colors():
    """A recolor plan over an edgeless envelope must not clobber the
    caller's committed colors with the trivial all-ones report."""
    ge = Graph.from_edges(5, np.zeros((0, 2), np.int64))
    plan = compile_plan(ColoringSpec(strategy="recolor"), ge)
    prev = np.array([5, 7, 5, 2, 9], np.int32)
    rep = plan(ge, colors=prev, seed=np.zeros(5, bool))
    np.testing.assert_array_equal(rep.colors, prev)
    # uncolored slots still get the trivial color 1
    rep2 = plan(ge, colors=np.array([3, 0, 0, 0, 4], np.int32))
    np.testing.assert_array_equal(rep2.colors, [3, 1, 1, 1, 4])


def test_dynamic_from_empty_graph():
    """Streaming can start from an edgeless graph (regression: the old
    pad_bucket(0)=256 phantom slab came exactly from this shape)."""
    dyn = DynamicColoring(Graph.from_edges(16, np.zeros((0, 2), np.int64)))
    assert np.all(dyn.colors == 1)
    dr = dyn.apply_batch(inserts=[[0, 1], [1, 2], [0, 2]])
    assert dr.inserted == 3
    assert validate_coloring(dyn.graph, dyn.colors)
    assert dyn.num_colors == 3


def test_config_dynamic_spec():
    """ColoringConfig.to_dynamic_spec: a recolor spec for d1 configs, a
    hard error (not a silent d1 coercion) for d2/pd2 ones."""
    import dataclasses
    from repro.configs.rmat_coloring import get_smoke_config
    spec = get_smoke_config().to_dynamic_spec()
    assert spec.strategy == "recolor" and spec.model == "d1"
    with pytest.raises(ValueError, match="distance-1"):
        dataclasses.replace(get_smoke_config(), model="d2").to_dynamic_spec()


def test_dynamic_rejects_wrong_spec():
    g = _graph(scale=8)
    with pytest.raises(ValueError, match="recolor"):
        DynamicColoring(g, ColoringSpec(strategy="iterative"))
    with pytest.raises(ValueError, match="distance-1"):
        DynamicColoring(g, ColoringSpec(strategy="recolor", model="d2"))
    with pytest.raises(ValueError, match="natural"):
        DynamicColoring(g, ColoringSpec(strategy="recolor",
                                        ordering="random"))


# --------------------------------------------- num_colors distinct count
def test_num_colors_counts_distinct_not_max():
    """The metrics bugfix: a freed color leaves a palette gap; the count
    must be distinct positive colors, not colors.max()."""
    assert num_colors(np.array([1, 3, 3, 7])) == 3  # gaps at 2, 4-6
    assert num_colors(np.zeros(0, np.int32)) == 0
    assert num_colors(np.array([5])) == 1


def test_report_num_colors_distinct_under_recolor():
    """Pin: ColoringReport.num_colors == the distinct count under the
    recolor strategy, where deletes/repairs legitimately leave gaps."""
    g = _graph(scale=8)
    dyn = DynamicColoring(g, ColoringSpec(strategy="recolor",
                                          concurrency=32))
    rng = np.random.default_rng(3)
    last = None
    for _ in range(8):
        ins, dels = _delta(rng, dyn.graph, 40, 60)
        dr = dyn.apply_batch(inserts=ins, deletes=dels)
        if dr.report is not None:
            last = dr.report
    assert last is not None, "stream produced no repair — widen the deltas"
    distinct = int(np.unique(last.colors[last.colors > 0]).size)
    assert last.num_colors == distinct == num_colors(last.colors)
    assert dyn.num_colors == num_colors(dyn.colors)


# ------------------------------------------- degenerate graph regressions
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_degenerate_graphs_all_strategies(strategy):
    """Regression (pad_bucket(0) phantom slabs): V=0 and E=0 graphs flow
    through color() AND compile_plan() for every strategy — no crash,
    trivially-valid report, no phantom padding."""
    g0 = Graph.from_edges(0, np.zeros((0, 2), np.int64))
    ge = Graph.from_edges(7, np.zeros((0, 2), np.int64))
    spec = ColoringSpec(strategy=strategy, concurrency=4)
    for g in (g0, ge):
        for rep in (color(g, spec), compile_plan(spec, g)(g)):
            assert rep.colors.shape == (g.num_vertices,)
            assert np.all(rep.colors == 1)
            assert rep.rounds == 0
            assert validate_coloring(g, rep.colors) or g.num_vertices == 0
            assert rep.num_colors == (1 if g.num_vertices else 0)


def test_degenerate_pad_bucket_and_capacities():
    assert pad_bucket(0) == 0
    assert frontier_capacities(0, 0) == (0, 0)
    assert frontier_capacities(100, 0) == (0, 0)
    assert frontier_capacities(0, 100) == (0, 0)
    # a degenerate envelope never allocates edge padding
    ge = Graph.from_edges(7, np.zeros((0, 2), np.int64))
    plan = compile_plan(ColoringSpec(), ge)
    assert plan.statics.padded_edges == 0


def test_degenerate_plan_map():
    ge = Graph.from_edges(7, np.zeros((0, 2), np.int64))
    plan = compile_plan(ColoringSpec(strategy="dataflow"), ge)
    reps = plan.map([ge, ge])
    assert len(reps) == 2
    for rep in reps:
        assert np.all(rep.colors == 1)


# --------------------------------------------------- hypothesis property
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is in requirements.txt
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def delta_streams(draw, max_v=20, max_e=60, max_batches=4):
        n = draw(st.integers(2, max_v))
        m = draw(st.integers(0, max_e))
        edges = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        batches = []
        for _ in range(draw(st.integers(1, max_batches))):
            k_i = draw(st.integers(0, 25))
            k_d = draw(st.integers(0, 25))
            # deliberately includes self loops, duplicates, inserts of
            # present edges and deletes of absent ones — all no-ops
            ins = draw(st.lists(
                st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
                min_size=k_i, max_size=k_i))
            dels = draw(st.lists(
                st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
                min_size=k_d, max_size=k_d))
            batches.append((ins, dels))
        g = Graph.from_edges(n, np.array(edges or [[0, 0]], dtype=np.int64))
        return g, batches

    @settings(max_examples=25, deadline=None)
    @given(delta_streams(), st.sampled_from(ENGINES))
    def test_random_delta_streams_end_valid(stream, engine):
        """Property: any delta sequence (no-ops and duplicates included)
        leaves the dynamic coloring exactly as valid as a fresh color()
        of the final graph — and the final graphs themselves agree."""
        g, batches = stream
        dyn = DynamicColoring(
            g, ColoringSpec(strategy="recolor", engine=engine,
                            concurrency=4, max_rounds=256))
        ref = g
        for ins, dels in batches:
            ins = np.array(ins, np.int64).reshape(-1, 2)
            dels = np.array(dels, np.int64).reshape(-1, 2)
            dyn.apply_batch(inserts=ins, deletes=dels)
            ref = ref.apply_delta(inserts=ins, deletes=dels)
        # the maintained graph IS the replayed graph
        np.testing.assert_array_equal(dyn.graph.col_idx, ref.col_idx)
        np.testing.assert_array_equal(dyn.graph.row_ptr, ref.row_ptr)
        fresh = color(ref, ColoringSpec(strategy="iterative", engine=engine,
                                        concurrency=4, max_rounds=256))
        assert validate_coloring(ref, fresh.colors) \
            == validate_coloring(ref, dyn.colors) is True
        assert dyn.num_colors <= dyn.color_bound
