"""Pallas TPU kernel: blocked bitmask first-fit (the paper's Alg. 1 lines 5-6).

The paper's inner loop marks neighbor colors in a ``forbiddenColors`` array
and scans for the smallest free positive color. The TPU translation
(DESIGN.md §2): the irregular neighbor-color *gather* is hoisted outside the
kernel (XLA `take` — HBM-bandwidth bound, vectorized); the kernel consumes a
dense ELL slab of neighbor colors and does the compute-hot part in VMEM:

  * build a per-vertex forbidden **bitmask** (``W = C/32`` uint32 words) with
    VPU shift/or ops — the register-resident analogue of ``forbiddenColors``;
  * extract the minimum free bit by expanding words to bit lanes and
    min-reducing candidate color values.

Tiling: grid is (vertex tiles × neighbor-slot tiles). The forbidden mask
lives in VMEM scratch and accumulates across the neighbor-slot (innermost,
"arbitrary") grid dimension; the mex is computed and written on the last
slot tile. Block shapes are (BV, BD) with BV a multiple of 8 and the bit-lane
expansion a multiple of 128, matching VPU tiling.

Colors are assumed < 32*W (the greedy bound Δ+1 makes W = ceil((Δ+2)/32)
safe); the wrapper asserts this.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tpu_compat import TPUCompilerParams


def _firstfit_kernel(nbr_ref, out_ref, forb_ref, *, words: int, bd: int):
    """One (vertex-tile, slot-tile) grid step.

    nbr_ref:  [BV, BD] int32 neighbor colors (0 = no neighbor / uncolored)
    out_ref:  [BV]     int32 mex output (written on last slot tile)
    forb_ref: [BV, W]  uint32 VMEM scratch, persists across slot tiles
    """
    j = pl.program_id(1)
    nd = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        # color 0 ("uncolored") is always forbidden: bit 0 of word 0
        init = jnp.zeros(forb_ref.shape, jnp.uint32)
        forb_ref[...] = init.at[:, 0].set(jnp.uint32(1))

    colors = nbr_ref[...]                                  # [BV, BD] int32
    word_idx = (colors >> 5).astype(jnp.int32)             # [BV, BD]
    bit = (colors & 31).astype(jnp.uint32)
    bitval = (jnp.uint32(1) << bit)                        # single set bit

    # accumulate OR into each word: for word w, OR the bitvals whose
    # word_idx == w. Single-bit values OR along the slot axis via lax.reduce.
    acc = forb_ref[...]
    contrib = jnp.where(
        word_idx[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, words), 2),
        bitval[:, :, None],
        jnp.uint32(0),
    )                                                      # [BV, BD, W]
    orred = jax.lax.reduce(contrib, jnp.uint32(0), jax.lax.bitwise_or, (1,))
    forb_ref[...] = acc | orred

    @pl.when(j == nd - 1)
    def _finish():
        forb = forb_ref[...]                               # [BV, W]
        lanes = jax.lax.broadcasted_iota(jnp.uint32, (1, words, 32), 2)
        bits = (forb[:, :, None] >> lanes) & jnp.uint32(1)  # [BV, W, 32]
        value = (
            jax.lax.broadcasted_iota(jnp.int32, (1, words, 32), 1) * 32
            + jax.lax.broadcasted_iota(jnp.int32, (1, words, 32), 2)
        )
        cand = jnp.where(bits == 0, value, jnp.iinfo(jnp.int32).max)
        out_ref[...] = jnp.min(cand.reshape(cand.shape[0], -1), axis=1)


def vmem_estimate(*, words: int = 16, block_v: int = 512,
                  block_d: int = 128) -> int:
    """Per-grid-step VMEM footprint (bytes) of :func:`firstfit`'s launch
    geometry, for the analyzer's budget checker (repro.analysis.budgets):
    input + output blocks, the ``[BV, W]`` scratch bitset, and the larger
    of the two big intermediates — the ``[BV, BD, W]`` per-word contribution
    tensor and the ``[BV, W, 32]`` bit-lane expansion. ``words`` scales
    with the color bound (W = ceil(C/32) ~ max_degree/32), which is how a
    high-degree plan breaches the budget at default block shapes."""
    blocks = 4 * block_v * (block_d + 1)
    scratch = 4 * block_v * words
    intermediate = 4 * block_v * words * max(block_d, 32)
    return blocks + scratch + intermediate


@functools.partial(
    jax.jit, static_argnames=("words", "block_v", "block_d", "interpret")
)
def firstfit(
    nbr_colors: jnp.ndarray,
    *,
    words: int = 16,
    block_v: int = 512,
    block_d: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Minimum excluded positive color per row of an ELL neighbor-color slab.

    nbr_colors: [V, D] int32, entries in [0, 32*words); 0 = absent/uncolored.
    Returns mex [V] int32 >= 1. V and D are padded internally to the block
    shape (pad slots contribute color 0, which is always forbidden anyway).
    """
    v, d = nbr_colors.shape
    vp = -(-v // block_v) * block_v
    dp = -(-d // block_d) * block_d
    x = jnp.zeros((vp, dp), jnp.int32).at[:v, :d].set(nbr_colors)
    grid = (vp // block_v, dp // block_d)
    out = pl.pallas_call(
        functools.partial(_firstfit_kernel, words=words, bd=block_d),
        grid=grid,
        in_specs=[pl.BlockSpec((block_v, block_d), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_v,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((vp,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_v, words), jnp.uint32)],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x)
    return out[:v]
