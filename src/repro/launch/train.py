"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 300 --batch 8 --seq 512 --ckpt-dir /tmp/ckpt

Features exercised: deterministic data pipeline (skip-to-step on resume),
sharded train step (uses whatever devices exist; production meshes are
exercised by dryrun.py), NaN-step rejection, atomic+async checkpointing,
elastic restart (restore re-shards onto the current mesh).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..jax_compat import set_mesh
from .. import models
from ..train import (AdamWConfig, init_opt_state, make_train_step, checkpoint,
                     data)
from ..train.train_step import TrainStepConfig
from ..models.config import ShapeConfig
from ..parallel.sharding import rules_for_mesh, activation_rules
from . import specs as S


def build(cfg, opt_cfg, ts_cfg, mesh=None):
    params, axes = models.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg, ts_cfg)
    if mesh is None or len(jax.devices()) == 1:
        return params, opt_state, jax.jit(step), None
    rules = rules_for_mesh(mesh)
    p_sh = S.tree_shardings(jax.eval_shape(lambda: params), axes, rules, mesh)
    params = jax.tree.map(jax.device_put, params, p_sh)

    def fn(p, o, b):
        with activation_rules(rules):
            return step(p, o, b)

    with set_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=(p_sh, None, None),
                         donate_argnums=(0, 1))
    return params, opt_state, jitted, mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    ts_cfg = TrainStepConfig(microbatches=args.microbatches)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    dcfg = data.data_config_for(cfg, shape)

    params, opt_state, step_fn, _ = build(cfg, opt_cfg, ts_cfg)

    start = 0
    if args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
        tree, start = checkpoint.restore(
            args.ckpt_dir, {"params": params, "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        print(f"[train] resumed from step {start}")

    losses = []
    t0 = time.time()
    pending_ckpt = None
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 data.batch_for_step(dcfg, s).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
        if (s + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            print(f"[train] step {s+1}/{args.steps} loss={losses[-1]:.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} lr={float(m['lr']):.2e} "
                  f"skipped={int(m['skipped'])} {dt:.2f}s/step")
            t0 = time.time()
        if args.ckpt_dir and (s + 1) % args.ckpt_every == 0:
            if pending_ckpt is not None:
                pending_ckpt.join()
            pending_ckpt = checkpoint.save(
                args.ckpt_dir, s + 1, {"params": params, "opt": opt_state},
                async_write=True)
    if pending_ckpt is not None:
        pending_ckpt.join()
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps,
                        {"params": params, "opt": opt_state})
    print(f"[train] done. first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
