"""Committed findings baseline: the accepted-risk ledger.

``baseline.json`` (committed next to this module) lists every gating
finding (warning/error) the project has *accepted*, keyed by fingerprint
(``CODE@site``), each with a mandatory human-written ``reason`` string —
the benignity argument the analyzer could not make itself. Three outcomes
when comparing a run against it:

* **new violation** — a gating finding with no entry: CI fails. Fix the
  code or add an entry with a real argument (review will read it).
* **allowlisted** — matched entry; reported under ``-v`` but never gates.
* **stale entry** — an entry no current finding matches. Also a FAILURE
  (baseline drift): a stale entry is a risk-acceptance for code that no
  longer exists, and leaving it around would silently re-accept a future
  regression at the same site.

Info findings never consult the baseline.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Tuple

from .findings import Finding, gating

BASELINE_VERSION = 1


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(__file__), "baseline.json")


def load_baseline(path: str = None) -> Dict[str, str]:
    """fingerprint -> reason. Missing file = empty baseline."""
    path = default_baseline_path() if path is None else path
    if not os.path.isfile(path):
        return {}
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"baseline {path}: unsupported version {doc.get('version')!r}")
    out: Dict[str, str] = {}
    for entry in doc.get("entries", ()):
        fp, reason = entry["fingerprint"], entry.get("reason", "")
        if not reason.strip():
            raise ValueError(
                f"baseline {path}: entry {fp!r} has no reason string — "
                "every accepted finding needs its argument written down")
        out[fp] = reason
    return out


def save_baseline(entries: Dict[str, str], path: str = None) -> None:
    path = default_baseline_path() if path is None else path
    doc = {
        "version": BASELINE_VERSION,
        "entries": [{"fingerprint": fp, "reason": entries[fp]}
                    for fp in sorted(entries)],
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


def compare(findings: Iterable[Finding], baseline: Dict[str, str]
            ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """(new_violations, allowlisted, stale_fingerprints) — see module
    docstring. Only gating (warning/error) findings participate."""
    gate = gating(findings)
    new = [f for f in gate if f.fingerprint not in baseline]
    allowed = [f for f in gate if f.fingerprint in baseline]
    hit = {f.fingerprint for f in allowed}
    stale = sorted(fp for fp in baseline if fp not in hit)
    return new, allowed, stale
