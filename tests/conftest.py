import os
import sys

import pytest

# tests run on the single real CPU device; the dry-run (and only the
# dry-run) forces 512 host devices in its own process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# archs whose smoke train step / decode takes tens of seconds on CPU; their
# cases run under `-m slow`, keeping the default tier-1 suite fast. One
# shared set so the per-file slow selections can't drift apart.
SLOW_ARCHS = frozenset({
    "recurrentgemma-2b", "deepseek-v2-lite-16b", "llama-3.2-vision-11b",
    "whisper-medium", "grok-1-314b", "gemma2-2b",
})


def arch_params(arch_ids, slow_set=SLOW_ARCHS, extra_marks=None):
    """Parametrize ids, marking ``slow_set`` members slow (plus any
    per-arch ``extra_marks``: {arch: [marks]})."""
    out = []
    for a in arch_ids:
        marks = [pytest.mark.slow] if a in slow_set else []
        marks += (extra_marks or {}).get(a, [])
        out.append(pytest.param(a, marks=marks) if marks else a)
    return out
