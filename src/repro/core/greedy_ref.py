"""Serial greedy coloring oracles: distance-1 (paper Alg. 1), distance-2
and bipartite partial distance-2.

All three implement the exact first-fit formulation with the
*vertex-stamped* ``forbiddenColors`` array (no per-vertex
reinitialization; O(|V|+|E|) total for D1, O(sum of two-hop neighborhood
sizes) for D2/PD2), which is the foundation of the parallel algorithms.
numpy/host-side; these are the references the JAX implementations are
validated against — DATAFLOW under ``model="d2"``/``"pd2"`` must reproduce
:func:`greedy_color_d2` / :func:`greedy_color_pd2` exactly, as it
reproduces :func:`greedy_color` under distance-1.
"""
from __future__ import annotations

import numpy as np

from .graph import BipartiteGraph, Graph


def greedy_color(graph: Graph, order: np.ndarray | None = None) -> np.ndarray:
    """Color ``graph`` greedily visiting vertices in ``order``.

    Returns colors[V] (1-based; every vertex colored). With ``order=None``
    vertices are visited in natural index order — the order the parallel
    DATAFLOW algorithm reproduces exactly.
    """
    n = graph.num_vertices
    if order is None:
        order = np.arange(n, dtype=np.int64)
    colors = np.zeros(n, dtype=np.int32)
    # stamped with the vertex id being colored; init with a value not in V
    forbidden = np.full(graph.max_degree() + 2, -1, dtype=np.int64)
    row_ptr, col_idx = graph.row_ptr, graph.col_idx
    for v in order:
        nbrs = col_idx[row_ptr[v]:row_ptr[v + 1]]
        nc = colors[nbrs]
        forbidden[nc[nc > 0]] = v  # mark colors of colored neighbors
        # smallest positive index not stamped with v
        c = 1
        while forbidden[c] == v:
            c += 1
        colors[v] = c
    return colors


def greedy_color_d2(graph: Graph, order: np.ndarray | None = None) -> np.ndarray:
    """Serial greedy *distance-2* coloring: first-fit over the colors of
    every vertex within two hops (Gebremedhin et al.'s D2 model — the
    Jacobian/Hessian-compression constraint). Equivalent to
    :func:`greedy_color` on the square graph G², but computed directly from
    the CSR without materializing G²."""
    n = graph.num_vertices
    if order is None:
        order = np.arange(n, dtype=np.int64)
    colors = np.zeros(n, dtype=np.int32)
    deg = np.diff(graph.row_ptr)
    row_ptr, col_idx = graph.row_ptr, graph.col_idx
    # D2 degree <= sum of neighbor degrees; +2 for the 1-based scan past it
    bound = 2
    if deg.size and graph.num_directed_edges:
        src, dst = graph.directed_edges()
        bound = int(np.bincount(src, weights=deg[dst], minlength=n).max()) + 2
    forbidden = np.full(bound, -1, dtype=np.int64)
    for v in order:
        nbrs = col_idx[row_ptr[v]:row_ptr[v + 1]]
        if nbrs.size:
            two_hop = np.concatenate(
                [nbrs] + [col_idx[row_ptr[w]:row_ptr[w + 1]] for w in nbrs])
            nc = colors[two_hop[two_hop != v]]
            forbidden[nc[nc > 0]] = v
        c = 1
        while forbidden[c] == v:
            c += 1
        colors[v] = c
    return colors


def greedy_color_pd2(bg: BipartiteGraph, order: np.ndarray | None = None,
                     side: str = "left") -> np.ndarray:
    """Serial greedy *partial distance-2* coloring of one class of a
    bipartite graph (Taş et al., arXiv:1701.02628): first-fit over the
    colors of same-class vertices reachable through a shared neighbor.
    Returns colors for the ``side`` class only."""
    if side == "left":
        n, a_ptr, a_idx, b_ptr, b_idx = (bg.num_left, bg.l2r_ptr, bg.l2r_idx,
                                         bg.r2l_ptr, bg.r2l_idx)
    elif side == "right":
        n, a_ptr, a_idx, b_ptr, b_idx = (bg.num_right, bg.r2l_ptr, bg.r2l_idx,
                                         bg.l2r_ptr, bg.l2r_idx)
    else:
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    if order is None:
        order = np.arange(n, dtype=np.int64)
    colors = np.zeros(n, dtype=np.int32)
    other_deg = np.diff(b_ptr)
    bound = 2
    if n and bg.num_edges:
        deg = np.diff(a_ptr)
        src = np.repeat(np.arange(n), deg)
        bound = int(np.bincount(src, weights=other_deg[a_idx],
                                minlength=n).max()) + 2
    forbidden = np.full(bound, -1, dtype=np.int64)
    for v in order:
        nbrs = a_idx[a_ptr[v]:a_ptr[v + 1]]
        if nbrs.size:
            peers = np.concatenate(
                [b_idx[b_ptr[r]:b_ptr[r + 1]] for r in nbrs])
            nc = colors[peers[peers != v]]
            forbidden[nc[nc > 0]] = v
        c = 1
        while forbidden[c] == v:
            c += 1
        colors[v] = c
    return colors


def num_colors(colors: np.ndarray) -> int:
    return int(colors.max()) if colors.size else 0
