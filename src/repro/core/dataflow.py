"""DATAFLOW / DATAFLOWRECURSIVE — the paper's Algorithms 3-5, adapted to TPU.

The XMT version blocks each vertex's thread on ``readff(color[w])`` for every
smaller-index neighbor ``w`` — hardware dataflow over the dependency DAG
``w -> v  iff  (v,w) in E and w < v``. A TPU has no full/empty bits, so we
execute the *same DAG* as a chaotic fixpoint iteration of the dataflow
equations (DESIGN.md §2):

    c[v] <- mex{ c[w] : w in adj(v), w < v }     (uncolored w contributes 0)

All vertices update in parallel each sweep; vertices of dataflow level L hold
their final value after L sweeps (level = longest dependency path), so the
iteration converges in ``depth(DAG)`` sweeps to **exactly** the serial greedy
coloring in index order — the same invariant the XMT algorithm guarantees
(priority = vertex index, conceptually Jones-Plassmann). Deadlock-freedom is
structural: levels are computed by iteration, not discovered by blocking, so
DATAFLOWRECURSIVE's ``int_fetch_add`` recursion is unnecessary.

DATAFLOW is ITERATIVE's phase 1 in the fully-concurrent limit with
index-precedence (offset = vertex id): the sweep itself is the shared
:func:`repro.core.engine.fixpoint_sweep`, and the first-fit inner loop is
pluggable via ``engine=`` exactly as in iterative.py.

:func:`dataflow_levels` exposes the DAG depth / wavefront profile — the
"available parallelism" the XMT's 16K threads would have exploited.

Under the frontier layer (repro.core.frontier) the fixpoint runs
*active-set sweeps*: a vertex's iterate can change at sweep s only if one
of its dependencies changed at sweep s-1, so once the changed set fits the
static slab each sweep compacts ``dependents(changed)`` and re-evaluates
only those — same iterates, same sweep count, O(active) per sweep.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from jax import lax

from .engine import (EngineSpec, SweepSpec, fixpoint_iterate, fixpoint_sweep)
from .frontier import compact_frontier, frontier_counts
from .graph import DeviceGraph


@dataclasses.dataclass
class DataflowResult:
    colors: jnp.ndarray  # [V] int32, >= 1 — identical to serial greedy
    sweeps: int          # fixpoint sweeps == dataflow DAG depth (+1 check)

    @functools.cached_property
    def num_colors(self) -> int:
        from .metrics import num_colors as _distinct
        return _distinct(self.colors)


@functools.partial(jax.jit,
                   static_argnames=("max_sweeps", "backend", "color_bound",
                                    "frontier_cap_v", "frontier_cap_e"))
def _dataflow_impl(g: DeviceGraph, *, max_sweeps: int, backend,
                   color_bound: int = 0, frontier_cap_v: int = 0,
                   frontier_cap_e: int = 0):
    V = g.num_vertices
    max_colors = g.max_degree + 1
    if color_bound > 0:
        max_colors = min(max_colors, color_bound)
    mex = backend.bind(num_vertices=V, max_colors=max_colors,
                       ell_slot=g.ell_slot, ell_width=g.ell_width,
                       max_degree=g.max_degree)
    # dependency edges: only smaller-index neighbors forbid a color
    dep = g.dst < g.src  # padding (src == dst == V) excluded
    spec = SweepSpec(key_v=jnp.where(dep, g.src, V),
                     dyn_idx=g.dst, dyn=dep,
                     static_c=jnp.zeros_like(g.dst))
    use_frontier = frontier_cap_v > 0 and g.has_frontier
    if not use_frontier:
        colors, n, changed = fixpoint_sweep(
            mex, spec, jnp.zeros((V,), jnp.int32), jnp.ones((V,), jnp.bool_),
            max_sweeps=max_sweeps)
        return colors, n, changed, jnp.asarray(0, jnp.int32)

    # Frontier (active-set) sweeps. Vertex v's chaotic iterate can change at
    # sweep s only if one of its dependencies (smaller-index neighbors)
    # changed at sweep s-1, so the set that needs re-evaluating is exactly
    # dependents(changed) — everything else would recompute its own value.
    # Per sweep: compact the changed vertices' rows to find the dependents,
    # compact the dependents' rows, run the mex over that slab. Both sets
    # spill to the full sweep when they overflow the static capacities, so
    # iterates (and the sweep count) stay bit-identical to the full path.
    mex_slab = backend.bind_slab(
        capacity=frontier_cap_v, max_colors=max_colors,
        ell_width=g.max_degree, max_degree=g.max_degree)
    cap_v, cap_e = frontier_cap_v, frontier_cap_e

    def full_sweep(cpad):
        key_c = jnp.where(dep, cpad[spec.dyn_idx], spec.static_c)
        new = mex(spec.key_v, key_c)
        changed = new != cpad[:V]
        return cpad.at[:V].set(new), changed, jnp.asarray(0, jnp.int32)

    def active_sweep(args):
        cpad, chg = args
        # dependents of the changed set: one compaction of the changed rows
        dslab = compact_frontier(chg, g.inc_ptr, g.dst, cap_v, cap_e)
        dep_e = (dslab.src < V) & (dslab.dst > dslab.src)
        active = (jnp.zeros((V,), jnp.bool_)
                  .at[dslab.dst].max(dep_e, mode="drop"))
        nv, ne = frontier_counts(active, g.inc_ptr)

        def slab_sweep(cpad):
            slab = compact_frontier(active, g.inc_ptr, g.dst, cap_v, cap_e)
            forb = (slab.src < V) & (slab.dst < slab.src)
            key_c = jnp.where(forb, cpad[slab.dst], 0)
            mexv = mex_slab(jnp.where(forb, slab.owner, cap_v), key_c,
                            slab.slot)
            live = slab.vert < V
            old = cpad[jnp.minimum(slab.vert, V)]
            chg_new = (jnp.zeros((V,), jnp.bool_)
                       .at[jnp.minimum(slab.vert, V)]
                       .max(live & (mexv != old), mode="drop"))
            cpad = cpad.at[jnp.where(live, slab.vert, V + 1)].set(
                mexv, mode="drop")
            return cpad, chg_new, jnp.asarray(1, jnp.int32)

        return lax.cond((nv <= cap_v) & (ne <= cap_e),
                        slab_sweep, full_sweep, cpad)

    def body(state):
        cpad, chg, n, _, nslab = state
        nc, nce = frontier_counts(chg, g.inc_ptr)
        fits = (n > 0) & (nc <= cap_v) & (nce <= cap_e)
        cpad, chg, used = lax.cond(
            fits, active_sweep, lambda a: full_sweep(a[0]), (cpad, chg))
        still = jnp.any(chg)
        return cpad, chg, n + 1, still, nslab + used

    def cond(state):
        _, _, n, still, _ = state
        return jnp.logical_and(still, n < max_sweeps)

    init = (jnp.zeros((V + 1,), jnp.int32), jnp.ones((V,), jnp.bool_),
            jnp.asarray(0, jnp.int32), jnp.asarray(True),
            jnp.asarray(0, jnp.int32))
    cpad, _, n, still, nslab = lax.while_loop(cond, body, init)
    return cpad[:V], n, still, nslab


def color_dataflow(g, max_sweeps: int = 4096,
                   engine: EngineSpec = "sort",
                   color_bound: int = 0, model: str = "d1") -> DataflowResult:
    """``color_bound`` caps the table backends' capacity below Delta+1 —
    a caller-asserted bound, as in :func:`color_iterative`.

    ``model`` selects the coloring semantics ("d1" | "d2" | "pd2"), lowered
    exactly as in :func:`color_iterative`; under "d2"/"pd2" the fixpoint
    reproduces the *serial D2/PD2 greedy* in index order
    (:func:`repro.core.greedy_ref.greedy_color_d2` / ``greedy_color_pd2``),
    since the lowering is index-preserving.

    Back-compat shim over the registered ``"dataflow"``
    :class:`repro.core.api.ColoringStrategy` — same arguments, same
    bit-exact results, legacy :class:`DataflowResult` return. Prefer
    ``repro.core.color(g, strategy="dataflow", ...)`` or
    ``repro.core.compile_plan`` for compile-once reuse."""
    from .api import ColoringSpec, get_strategy  # lazy: api imports us
    spec = ColoringSpec(strategy="dataflow", model=model, engine=engine,
                        max_sweeps=max_sweeps, color_bound=int(color_bound))
    raw = get_strategy("dataflow").oneshot(spec, g)
    if bool(raw.unconverged):
        raise RuntimeError(f"DATAFLOW did not converge in {max_sweeps} sweeps")
    return DataflowResult(colors=raw.colors,
                          sweeps=int(raw.sweeps_per_round[0]))


@functools.partial(jax.jit, static_argnames=("num_vertices", "max_iters"))
def _levels_impl(src, dst, *, num_vertices: int, max_iters: int):
    V = num_vertices
    dep = dst < src

    def step(lv):
        lpad = jnp.concatenate([lv, jnp.zeros((1,), jnp.int32)])
        contrib = jnp.where(dep, lpad[dst], 0)
        seg = (
            jnp.zeros((V,), jnp.int32)
            .at[src].max(contrib, mode="drop")
        )
        return seg + 1

    lv, n, _ = fixpoint_iterate(step, jnp.ones((V,), jnp.int32),
                                max_iters=max_iters)
    return lv, n


def dataflow_levels(g: DeviceGraph, max_iters: int = 4096):
    """Dataflow level of each vertex (longest dependency chain ending at it).

    Returns (levels [V] int32 >= 1, depth). Wavefront L's vertices are
    pairwise independent — the paper's XMT threads resolve exactly this
    schedule through full/empty-bit blocking.
    """
    lv, _ = _levels_impl(g.src, g.dst, num_vertices=g.num_vertices, max_iters=max_iters)
    return lv, int(lv.max())
