"""Shared structure-walking for the SPMD verifier passes.

The distributed strategy's plan program is a ``shard_map`` mesh program
(one ``lax.while_loop`` of BSP rounds per device — core/distributed.py).
The three SPMD passes (:mod:`.collectives`, :mod:`.wirecost`,
:mod:`.halo`) all need the same two ingredients, which live here:

* :class:`SpmdGeometry` — the static mesh/envelope geometry the traced
  program was built for (``D``, ``Vl``, halo capacity, wire tier, packed
  color bound, frontier slab capacity). :func:`distributed_geometry`
  derives it from a spec/envelope exactly the way
  ``repro.analysis.trace_plan_program`` sizes the trace, so closed-form
  expectations and traced shapes are always about the *same* program;
* shard-program extraction — :func:`find_shard_jaxprs` locates every
  ``shard_map`` equation (the per-device program lives in its ``jaxpr``
  param) and :func:`collective_eqns` / :data:`COLLECTIVE_PRIMS` identify
  the cross-device communication points inside it.

Everything is pure jaxpr traversal: no execution, no compilation.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Tuple

from .jaxpr_walk import walk_eqns

# cross-device communication primitives (jax 0.4.x names). axis_index is
# shard-VARYING but communicates nothing, so it is a uniformity source for
# the collective-safety pass, not a collective.
COLLECTIVE_PRIMS = frozenset({
    "all_gather", "psum", "pmin", "pmax", "ppermute", "all_to_all",
    "reduce_scatter", "pgather", "pbroadcast",
})

# collectives that reduce over the named axes: their output is replicated
# (identical on every participating device), which is what makes a
# psum-derived vote a provably shard-uniform predicate.
REPLICATING_PRIMS = frozenset({
    "all_gather", "psum", "pmin", "pmax",
})


@dataclasses.dataclass(frozen=True)
class SpmdGeometry:
    """Static geometry of one traced distributed mesh program.

    ``wire`` is the *resolved* tier ("boundary" | "full" — a spec's
    "auto" traces the boundary program; the spill program is the
    ``wire="full"`` sweep cell). ``boundary_cap`` is the halo slab width
    the traced program pinned (including the analyzer's floor-2 rule for
    capless envelopes), ``wire_colors`` the uncapped provable Delta+1
    bound sizing the packed payload.
    """

    num_devices: int
    verts_local: int
    edges_local: int
    boundary_cap: int
    wire: str
    wire_colors: int
    max_colors: int
    frontier_cap_v: int
    frontier_cap_e: int
    axis_names: Tuple[str, ...]

    @property
    def verts_global(self) -> int:
        return self.verts_local * self.num_devices


def distributed_geometry(spec, statics) -> SpmdGeometry:
    """The :class:`SpmdGeometry` of the program ``trace_plan_program``
    traces for this spec/envelope — one derivation shared by the tracer
    and every closed-form expectation, so they can never disagree about
    which program is under analysis."""
    import numpy as np
    from ..core.api import DistributedStrategy
    from ..core.frontier import frontier_capacities
    from ..core.graph import pad_bucket

    mesh = DistributedStrategy._mesh(spec)
    D = int(np.prod(mesh.devices.shape))
    V = int(statics.num_vertices)
    Vl = -(-V // D)
    slab = pad_bucket(int(-(-int(statics.padded_edges) // D) * 1.35))
    max_colors = int(statics.max_degree) + 1
    if spec.color_bound > 0:
        max_colors = min(max_colors, int(spec.color_bound))
    use_boundary = spec.wire != "full"
    # floor-2 rule: see trace_plan_program — the boundary program is traced
    # with a non-degenerate halo slab even for capless envelopes
    bcap = max(2, min(Vl, int(statics.boundary_cap))) if use_boundary else 1
    fcv = fce = 0
    if spec.frontier != "off":
        fcv, fce = frontier_capacities(
            Vl, slab, int(statics.max_degree),
            capacity=int(spec.frontier_capacity))
    return SpmdGeometry(
        num_devices=D, verts_local=Vl, edges_local=slab,
        boundary_cap=(bcap if use_boundary else 0),
        wire=("boundary" if use_boundary else "full"),
        wire_colors=int(statics.max_degree) + 1, max_colors=max_colors,
        frontier_cap_v=fcv, frontier_cap_e=fce,
        axis_names=tuple(mesh.axis_names))


def find_shard_jaxprs(closed_jaxpr) -> List[Tuple[object, object]]:
    """Every ``(shard_map_eqn, shard_body_jaxpr)`` in the program,
    including shard_maps nested under pjit wrappers."""
    found: List[Tuple[object, object]] = []

    def visit(eqn, enclosing):
        if eqn.primitive.name != "shard_map":
            return
        body = eqn.params.get("jaxpr")
        if hasattr(body, "jaxpr"):  # ClosedJaxpr
            body = body.jaxpr
        if body is not None:
            found.append((eqn, body))

    walk_eqns(closed_jaxpr.jaxpr, visit)
    return found


def mesh_axis_names(shard_eqn) -> Tuple[str, ...]:
    mesh = shard_eqn.params.get("mesh")
    names = getattr(mesh, "axis_names", None)
    return tuple(names) if names else ()


def eqn_axis_names(eqn) -> Tuple[str, ...]:
    """The named axes a collective equation communicates over."""
    axes = eqn.params.get("axis_name", eqn.params.get("axes", ()))
    if axes is None:
        return ()
    if isinstance(axes, (tuple, list)):
        return tuple(a for a in axes if isinstance(a, str))
    return (axes,) if isinstance(axes, str) else ()


def is_full_axis(eqn, mesh_axes: Tuple[str, ...]) -> bool:
    """True when the collective spans every mesh axis (its output is
    replicated across the whole device set)."""
    if eqn.params.get("axis_index_groups") is not None:
        return False
    names = eqn_axis_names(eqn)
    return bool(mesh_axes) and set(names) == set(mesh_axes)


def collective_eqns(jaxpr) -> List[object]:
    """Depth-first ordered collectives of ``jaxpr`` including sub-jaxprs
    (pjit bodies, nested cond branches in branch order) — the "ordered
    collective sequence" the branch-parity check compares."""
    out: List[object] = []
    walk_eqns(jaxpr, lambda eqn, enc: out.append(eqn)
              if eqn.primitive.name in COLLECTIVE_PRIMS else None)
    return out


def collective_signature(eqn) -> Tuple:
    """What must match across cond branches for the sequence to be
    deadlock-free: primitive, named axes, operand/result shapes+dtypes."""
    def avals(vs):
        return tuple((tuple(v.aval.shape), str(v.aval.dtype)) for v in vs)
    return (eqn.primitive.name, eqn_axis_names(eqn),
            avals(eqn.invars), avals(eqn.outvars))


def sub_jaxpr(param) -> Optional[object]:
    """The raw Jaxpr behind a params entry (ClosedJaxpr or Jaxpr)."""
    if hasattr(param, "jaxpr") and hasattr(param.jaxpr, "eqns"):
        return param.jaxpr
    if hasattr(param, "eqns"):
        return param
    return None


def cond_branches(eqn) -> List[object]:
    """Branch jaxprs of a ``cond`` eqn in branch-index order (index 0 =
    predicate false for the two-way boolean form)."""
    return [b for b in (sub_jaxpr(p) for p in eqn.params.get("branches", ()))
            if b is not None]


def while_parts(eqn):
    """``(cond_jaxpr, body_jaxpr, cond_nconsts, body_nconsts)``."""
    return (sub_jaxpr(eqn.params["cond_jaxpr"]),
            sub_jaxpr(eqn.params["body_jaxpr"]),
            int(eqn.params.get("cond_nconsts", 0)),
            int(eqn.params.get("body_nconsts", 0)))


def aval_elems(v) -> int:
    import numpy as np
    try:
        return int(np.prod(v.aval.shape)) if v.aval.shape else 1
    except Exception:
        return 0


def aval_nbytes(v) -> int:
    import numpy as np
    try:
        return aval_elems(v) * np.dtype(v.aval.dtype).itemsize
    except Exception:
        return 0


def iter_round_loops(shard_body) -> Iterator[object]:
    """The top-level ``while`` equations of the shard body — the BSP round
    loop(s). Nested fixpoint sweeps live inside and are NOT yielded."""
    for eqn in shard_body.eqns:
        if eqn.primitive.name == "while":
            yield eqn
        elif eqn.primitive.name == "pjit":
            sub = sub_jaxpr(eqn.params.get("jaxpr"))
            if sub is not None:
                yield from iter_round_loops(sub)
