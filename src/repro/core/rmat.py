"""R-MAT graph generator (Chakrabarti & Faloutsos), vectorized.

Reproduces the paper's §4 test-graph methodology: recursive quadrant
subdivision with parameters (a, b, c, d); the three paper settings are
exported as :data:`RMAT_ER`, :data:`RMAT_G`, :data:`RMAT_B`. Duplicate edges
and self-loops are removed downstream in ``Graph.from_edges`` exactly as the
paper does ("the small variation in the number of edges is due to such
removals").

The paper additionally *randomly shuffles* vertex indices (§5.1 "Locality Not
Exploited") so that R-MAT's low-index/high-degree artifact does not help
caches; :func:`generate` exposes ``shuffle=True`` for the same reason.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .graph import Graph

# (a, b, c, d) — §4.1 of the paper.
RMAT_ER: Tuple[float, float, float, float] = (0.25, 0.25, 0.25, 0.25)
RMAT_G: Tuple[float, float, float, float] = (0.45, 0.15, 0.15, 0.25)
RMAT_B: Tuple[float, float, float, float] = (0.55, 0.15, 0.15, 0.15)

PAPER_PARAMS = {"RMAT-ER": RMAT_ER, "RMAT-G": RMAT_G, "RMAT-B": RMAT_B}


def rmat_edges(
    scale: int,
    edge_factor: int,
    params: Tuple[float, float, float, float],
    seed: int = 0,
) -> np.ndarray:
    """Sample ``edge_factor * 2**scale`` raw (src, dst) pairs.

    Vectorized over both edges and the ``scale`` recursion levels: each level
    independently picks one of four quadrants with probs (a, b, c, d); the
    row/col bits accumulate into the final coordinates.
    """
    a, b, c, d = params
    if not np.isclose(a + b + c + d, 1.0):
        raise ValueError("R-MAT parameters must sum to 1")
    n_edges = edge_factor << scale
    rng = np.random.default_rng(seed)
    u = rng.random((n_edges, scale))
    # quadrant: 0 -> (1,1)=a, 1 -> (1,2)=b, 2 -> (2,1)=c, 3 -> (2,2)=d
    quad = (u >= a).astype(np.int8) + (u >= a + b).astype(np.int8) \
        + (u >= a + b + c).astype(np.int8)
    row_bit = (quad >= 2).astype(np.int64)   # quadrants c, d
    col_bit = (quad % 2).astype(np.int64)    # quadrants b, d
    weights = (1 << np.arange(scale, dtype=np.int64))[::-1]
    src = row_bit @ weights
    dst = col_bit @ weights
    return np.stack([src, dst], axis=1)


def generate(
    scale: int,
    edge_factor: int = 8,
    params: Tuple[float, float, float, float] = RMAT_ER,
    seed: int = 0,
    shuffle: bool = True,
) -> Graph:
    """Generate an undirected R-MAT graph with ``2**scale`` vertices.

    ``edge_factor=8`` matches the paper (|E| = 8·|V| undirected edges before
    dedup, average degree ≈ 16).
    """
    n = 1 << scale
    edges = rmat_edges(scale, edge_factor, params, seed)
    g = Graph.from_edges(n, edges)
    if shuffle:
        rng = np.random.default_rng(seed + 0x5EED)
        perm = rng.permutation(n).astype(np.int64)
        g = g.relabel(perm)
    return g


def paper_graph(name: str, scale: int, seed: int = 0, shuffle: bool = True) -> Graph:
    """One of the paper's three graph families at a chosen scale."""
    return generate(scale, 8, PAPER_PARAMS[name], seed=seed, shuffle=shuffle)
