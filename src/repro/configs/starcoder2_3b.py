"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152, GQA + RoPE. [arXiv:2402.19173]"""
from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense", num_layers=30, d_model=3072,
        n_heads=24, n_kv_heads=2, head_dim=128, d_ff=12288, vocab_size=49152,
        act="gelu", rope_theta=100_000.0)


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense", num_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        act="gelu", rope_theta=100_000.0)
