"""Execute the fenced ``python`` code blocks of markdown docs so documented
snippets can never rot (the CI docs lane; also wrapped by
tests/test_docs.py).

Rules:
* only fences whose info string is exactly ``python`` run; ``bash``/other
  fences and fences tagged e.g. ``python-norun`` are skipped;
* all blocks of one file execute **in order in one shared namespace**, so a
  doc can build up a running example across prose;
* any exception (including a failed ``assert``) exits non-zero with the
  offending file, block index and source line.

Usage:
    PYTHONPATH=src python tools/check_doc_snippets.py README.md [more.md ...]
"""
from __future__ import annotations

import re
import sys
import traceback

FENCE = re.compile(r"^```(\S*)\s*$")


def python_blocks(text: str):
    """Yield (start_line, source) for each ```python fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m:
            info, start = m.group(1), i + 1
            block = []
            i += 1
            while i < len(lines) and not FENCE.match(lines[i]):
                block.append(lines[i])
                i += 1
            if info == "python":
                yield start + 1, "\n".join(block)
        i += 1


def run_file(path: str) -> int:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    ns = {"__name__": f"docsnippets:{path}"}
    n = 0
    for lineno, src in python_blocks(text):
        n += 1
        try:
            code = compile(src, f"{path}:block{n}(line {lineno})", "exec")
            exec(code, ns)  # noqa: S102 — executing our own docs is the point
        except Exception:
            print(f"FAIL {path} block {n} (markdown line {lineno}):",
                  file=sys.stderr)
            traceback.print_exc()
            return 1
        print(f"ok   {path} block {n} (markdown line {lineno})")
    if n == 0:
        print(f"WARN {path}: no ```python blocks found", file=sys.stderr)
    return 0


def main(argv) -> int:
    paths = argv[1:] or ["README.md"]
    rc = 0
    for p in paths:
        rc |= run_file(p)
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv))
