"""Vectorized segmented first-fit ("mex") — the TPU-native replacement for the
paper's ``forbiddenColors`` stamped array + linear scan (Alg. 1, lines 5-6).

This is the computational core of the ``"sort"`` :class:`~repro.core.engine.
MexBackend` (the registry's layout-free default); the other registered
backends (``"bitmap"``, ``"ell_pallas"``) compute the same function through
different formulations — see ``repro.core.engine`` for the registry and
DESIGN.md §Engine for the parity contract. Drivers never call this module
directly: they go through ``MexBackend.bind(...)``'s returned mex closure.

Given a multiset of (vertex, forbidden-color) pairs, compute per vertex the
minimum *positive* integer not present. The trick: lexicographically sort the
pairs (two-key ``lax.sort`` — no int64 composite keys, TPU-friendly) and emit
a candidate ``c+1`` wherever a "gap" occurs (next entry belongs to another
vertex, or skips past ``c+1``); the segment-min of candidates is the mex.

Callers must guarantee every live vertex contributes at least one entry; the
canonical way is to append a synthetic ``(v, 0)`` pair per vertex (color 0 ==
"uncolored" never collides with real colors >= 1 and seeds the candidate
``1``) — ``SortMexBackend.bind`` does exactly this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

_INT32_MAX = jnp.iinfo(jnp.int32).max


def segment_mex(vertex: jnp.ndarray, color: jnp.ndarray, num_vertices: int) -> jnp.ndarray:
    """Per-vertex minimum excluded positive color.

    vertex: [M] int32 ids in [0, num_vertices]; id == num_vertices is inert
        padding (its segment is computed then discarded).
    color:  [M] int32 >= 0 forbidden colors.
    Returns [num_vertices] int32 mex (>= 1) — garbage for vertices with no
    entries (callers append synthetic (v, 0) entries to avoid that).
    """
    v_s, c_s = lax.sort((vertex.astype(jnp.int32), color.astype(jnp.int32)), num_keys=2)
    next_v = jnp.concatenate([v_s[1:], jnp.full((1,), num_vertices + 1, jnp.int32)])
    next_c = jnp.concatenate([c_s[1:], jnp.zeros((1,), jnp.int32)])
    seg_end = next_v != v_s
    gap = seg_end | (next_c > c_s + 1)
    cand = jnp.where(gap, c_s + 1, _INT32_MAX)
    mex = jax.ops.segment_min(cand, v_s, num_segments=num_vertices + 1)
    return mex[:num_vertices]
