"""Logical-axis sharding rules (FSDP × TP × SP × EP), MaxText-style.

Params and activations are annotated with *logical* axis names; a ``Rules``
table maps each logical name to mesh axes. The defaults implement:

  batch       -> ("pod", "data")   data parallel (pod axis = DP by default)
  seq         -> "model"           sequence parallelism between blocks
  embed       -> "data"            ZeRO-3/FSDP shard of the non-TP param dim
  heads/mlp/vocab -> "model"       tensor parallelism
  experts     -> "model"           expert parallelism (deepseek; grok opts out
                                   via MoEConfig.partition="tensor")

Per-arch overrides: kv_heads stays replicated when the head count doesn't
divide the model axis (e.g. starcoder2 kv=2 on model=16).

``constrain`` applies ``with_sharding_constraint`` only when a rules context
is active, so the same model code runs un-annotated on a single CPU device
(smoke tests) and fully sharded under the production meshes (dry-run).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    table: Dict[str, MeshAxes]

    def resolve(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.table.get(logical, None)

    def override(self, **kw) -> "Rules":
        t = dict(self.table)
        t.update(kw)
        return Rules(t)

    def pruned(self, mesh_axis_names) -> "Rules":
        """Drop mesh axes absent from the target mesh (e.g. "pod" on the
        single-pod mesh)."""
        known = set(mesh_axis_names)

        def prune(v):
            if v is None:
                return None
            parts = (v,) if isinstance(v, str) else tuple(v)
            kept = tuple(p for p in parts if p in known)
            if not kept:
                return None
            return kept[0] if len(kept) == 1 else kept

        return Rules({k: prune(v) for k, v in self.table.items()})


def rules_for_mesh(mesh: "Mesh", base: "Rules" = None) -> "Rules":
    return (base or DEFAULT_RULES).pruned(mesh.axis_names)


DEFAULT_RULES = Rules({
    "batch": ("pod", "data"),
    "seq": "model",
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "kv_lora": None,
    "layers": None,
    "cache_seq": "model",   # decode KV caches: sequence-sharded (LSE combine)
    "cache_batch": ("pod", "data"),
    "rnn": "model",
    "state": None,
})


def _dedup(axes_tuple):
    """Drop mesh axes already used by an earlier dim (PartitionSpec must not
    repeat a mesh axis); later dims lose."""
    used = set()
    out = []
    for a in axes_tuple:
        if a is None:
            out.append(None)
            continue
        parts = (a,) if isinstance(a, str) else tuple(a)
        kept = tuple(p for p in parts if p not in used)
        used.update(kept)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(kept)
    return tuple(out)


def logical_to_spec(logical_axes: Sequence[Optional[str]], rules: Rules,
                    mesh: Optional[Mesh] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec.

    Mesh-aware divisibility: if the mesh is provided, any mapping that does
    not evenly divide is dropped for that dim (e.g. 8 kv heads on model=16
    -> replicated), applied per-dim at spec build time by the caller via
    ``shard_if_divisible`` since dim sizes live with the arrays.
    """
    resolved = tuple(rules.resolve(a) for a in logical_axes)
    return P(*_dedup(resolved))


def spec_for_array(shape: Tuple[int, ...], logical_axes, rules: Rules,
                   mesh: Mesh) -> P:
    """Like logical_to_spec but drops mappings whose mesh-axis product does
    not divide the dim size (replicate instead of erroring)."""
    resolved = list(rules.resolve(a) for a in logical_axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for i, r in enumerate(resolved):
        if r is None:
            continue
        parts = (r,) if isinstance(r, str) else tuple(r)
        prod = 1
        for pp in parts:
            prod *= sizes.get(pp, 1)
        if prod == 0 or shape[i] % prod != 0:
            resolved[i] = None
    return P(*_dedup(tuple(resolved)))


# ---------------------------------------------------------------- context
_ctx = threading.local()


def current_rules():
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def activation_rules(rules: Optional[Rules]):
    """Enable ``constrain`` inside model code. No-op context when None."""
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield
    finally:
        _ctx.rules = prev


def constrain(x, logical_axes):
    """with_sharding_constraint via the active rules (identity when absent).

    Divisibility-aware: a mapping whose mesh-axis product doesn't divide the
    dim size is dropped (replicated) instead of forcing XLA into padded
    reshards — e.g. kv_heads=8 on model=16 (measured pathological: §Perf
    H-A2 first attempt). Must run inside jit with a mesh context."""
    rules = current_rules()
    if rules is None:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        sizes = {}
    resolved = list(rules.resolve(a) for a in logical_axes)
    if sizes:
        for i, r in enumerate(resolved):
            if r is None:
                continue
            parts = (r,) if isinstance(r, str) else tuple(r)
            prod = 1
            for pp in parts:
                prod *= sizes.get(pp, 1)
            if prod == 0 or x.shape[i] % prod != 0:
                resolved[i] = None
    spec = P(*_dedup(tuple(resolved)))
    return jax.lax.with_sharding_constraint(x, spec)
