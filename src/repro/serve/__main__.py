"""``python -m repro.serve`` — the coloring-service CLI smoke
(repro.serve.coloring.main)."""
from .coloring import main

if __name__ == "__main__":
    main()
