"""Optimizer, checkpoint, data pipeline, compression — substrate tests."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train import (AdamWConfig, init_opt_state, adamw_update,
                         make_train_step, checkpoint, data)
from repro.train.optimizer import schedule, global_norm
from repro.configs import get_smoke_config
from repro import models


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params, cfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(cfg, grads, params, state)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adamw_skips_nonfinite():
    cfg = AdamWConfig(lr=0.1)
    params = {"w": jnp.ones(3)}
    state = init_opt_state(params, cfg)
    grads = {"w": jnp.asarray([jnp.nan, 1.0, 1.0])}
    p2, s2, m = adamw_update(cfg, grads, params, state)
    assert int(m["skipped"]) == 1
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.ones(3))
    assert int(s2["step"]) == 0  # step not consumed


def test_schedule_warmup_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in [0, 9, 55, 99, 200]]
    assert lrs[0] < 0.2
    assert abs(lrs[1] - 1.0) < 0.01
    assert 0.1 <= lrs[3] < 0.2
    assert abs(lrs[4] - 0.1) < 1e-6


def test_bf16_moments():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    state = init_opt_state(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    p2, s2, _ = adamw_update(cfg, {"w": jnp.ones((4, 4))}, params, state)
    assert s2["v"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == params["w"].dtype


def test_grad_accumulation_equivalence():
    """microbatches=4 == full batch (same grads up to fp tolerance)."""
    from repro.train import TrainStepConfig
    cfg = get_smoke_config("starcoder2-3b")
    params, _ = models.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=0.0, weight_decay=0.0)  # lr 0: compare metrics only
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 17)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    s1 = make_train_step(cfg, opt_cfg, TrainStepConfig(microbatches=1))
    s4 = make_train_step(cfg, opt_cfg, TrainStepConfig(microbatches=4))
    opt = init_opt_state(params, opt_cfg)
    _, _, m1 = jax.jit(s1)(params, opt, batch)
    _, _, m4 = jax.jit(s4)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    assert abs(float(m1["grad_norm"]) - float(m4["grad_norm"])) / float(m1["grad_norm"]) < 0.05


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                                         "d": jnp.asarray(7)}}
    checkpoint.save(str(tmp_path), 5, tree)
    got, step = checkpoint.restore(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10.0))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_latest_and_prune(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in [1, 2, 3, 4, 5]:
        checkpoint.save(str(tmp_path), s, tree, keep=2)
    assert checkpoint.latest_step(str(tmp_path)) == 5
    assert checkpoint.all_steps(str(tmp_path)) == [4, 5]


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"x": jnp.arange(4.0)}
    checkpoint.save(str(tmp_path), 1, tree)
    # corrupt the array file
    d = checkpoint.step_dir(str(tmp_path), 1)
    path = os.path.join(d, "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(-3, 2)
        f.write(b"zzz")
    with pytest.raises(Exception):
        checkpoint.restore(str(tmp_path), tree)


def test_checkpoint_async(tmp_path):
    tree = {"x": jnp.arange(100.0)}
    t = checkpoint.save(str(tmp_path), 9, tree, async_write=True)
    t.join(timeout=30)
    got, step = checkpoint.restore(str(tmp_path), tree)
    assert step == 9


def test_checkpoint_reshard_on_restore(tmp_path):
    """Elastic restart: restore with explicit shardings places leaves."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    checkpoint.save(str(tmp_path), 2, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = checkpoint.restore(str(tmp_path), tree, shardings=sh)
    assert got["w"].sharding == sh["w"]


# ------------------------------------------------------------------ data
def test_data_deterministic_and_skewed():
    cfg = data.DataConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    b1 = data.batch_for_step(cfg, 7)
    b2 = data.batch_for_step(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = data.batch_for_step(cfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted views of the same stream
    assert b1["tokens"].shape == (8, 64)
    # zipf skew: low token ids dominate
    assert (b1["tokens"] < 100).mean() > 0.5


def test_data_host_sharding_partition():
    cfg = data.DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    full = [data.batch_for_step(cfg, 1, host=h, hosts=4)["tokens"] for h in range(4)]
    assert all(f.shape == (2, 16) for f in full)
    # hosts see different data
    assert not np.array_equal(full[0], full[1])


def test_prefetch_waves_conflict_free():
    src = [0, 0, 0, 1, 1, 2, 3, 3, 3, 3]
    waves = data.plan_prefetch_waves(src)
    seen = []
    for w in waves:
        wave_srcs = [src[i] for i in w]
        assert len(set(wave_srcs)) == len(wave_srcs), "source contention"
        seen += w
    assert sorted(seen) == list(range(len(src)))
    assert len(waves) == 4  # max source multiplicity


# ------------------------------------------------------------ compression
def test_compressed_psum_close_to_exact():
    from repro.jax_compat import shard_map
    from repro.parallel.compression import compressed_psum
    import jax
    # single-device psum via shard_map over a trivial mesh
    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(128,)), jnp.float32)

    def f(x):
        return compressed_psum(x, "d", jax.random.PRNGKey(0))

    y = jax.jit(shard_map(f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                          out_specs=jax.sharding.PartitionSpec()))(x)
    err = np.abs(np.asarray(y) - np.asarray(x)).max()
    scale = np.abs(np.asarray(x)).max() / 127
    assert err <= 1.01 * scale  # one quantization step
