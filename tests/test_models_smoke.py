"""Per-arch smoke tests: reduced config (same family switches), one forward
+ one train step on CPU; output shapes + finiteness (assignment deliverable
f). Decode-vs-full consistency is covered in test_decode.py."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro import models
from repro.train import AdamWConfig, init_opt_state, make_train_step


def _batch(cfg, b=2, t=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t + 1)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vlm.num_image_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encdec.enc_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params, _ = models.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux, _ = models.forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    loss, metrics = models.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    assert abs(float(metrics["nll"]) - np.log(cfg.vocab_size)) < 1.5


from conftest import arch_params


@pytest.mark.parametrize("arch", arch_params(ARCH_IDS))
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    params, _ = models.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg)
    batch = _batch(cfg)
    l0 = float(models.loss_fn(cfg, params, batch)[0])
    params, opt, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(m["skipped"]) == 0
    assert float(m["grad_norm"]) > 0
    # same batch again: one step of adam should reduce the loss
    l1 = float(models.loss_fn(cfg, params, batch)[0])
    assert l1 < l0, (arch, l0, l1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_dims(arch):
    """The FULL configs carry the exact assigned dimensions (exercised via
    the dry-run; here we only check the metadata — no allocation)."""
    cfg = get_config(arch)
    expected = {
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "mamba2-130m": (24, 768, 1, 1, 0, 50280),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_param_counts_plausible():
    """Full-config param counts in the advertised ballpark (abstract trees,
    no allocation)."""
    expect = {
        "mistral-nemo-12b": (11e9, 14e9),
        "qwen3-4b": (3.5e9, 5.5e9),
        "starcoder2-3b": (2.5e9, 3.8e9),
        "gemma2-2b": (2.0e9, 3.5e9),
        "mamba2-130m": (0.10e9, 0.16e9),
        "whisper-medium": (0.6e9, 0.9e9),   # whisper-medium is 769M
        "recurrentgemma-2b": (2.2e9, 3.5e9),
        "llama-3.2-vision-11b": (8.5e9, 11.5e9),
        "grok-1-314b": (290e9, 340e9),
        "deepseek-v2-lite-16b": (13e9, 18e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}")


def test_moe_capacity_dropping_is_bounded():
    """Capacity dropping (dropped tokens ride the residual) keeps outputs
    finite and bounded even under tiny capacity."""
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    params, _ = models.init_params(cfg, jax.random.PRNGKey(0))
    logits, aux, _ = models.forward(cfg, params, _batch(cfg))
    assert bool(jnp.isfinite(logits).all())
    assert float(aux) >= 0
