"""Boundary-only distributed wire tests (ISSUE 9).

Covers the shard-local CSR + halo layout: interior/boundary classification
against a brute-force oracle (both partitioning schemes), the lossless
halo codec, boundary-vs-full wire bit parity across engine x model x
frontier on real multi-device meshes (subprocess, like
tests/test_distributed.py), plan halo-capacity spill behavior, and — as a
property — that interior vertices are structurally unreferencable by
remote shards. Degenerate graphs (V=0, E=0) ride the 2-shard subprocess.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import ColoringSpec, Graph, color
from repro.core.distributed import partition_graph
from repro.parallel.compression import (halo_bits, halo_words, pack_halo,
                                        unpack_halo)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str, devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def _random_graph(rng, n, m):
    edges = np.stack([rng.integers(0, n, m), rng.integers(0, n, m)], 1)
    return Graph.from_edges(n, edges)


def _owner_map(num_vertices, num_devices, scheme):
    """original vertex id -> owning shard, mirroring partition_graph."""
    ids = np.arange(num_vertices, dtype=np.int64)
    Vl = -(-num_vertices // num_devices) if num_vertices else 0
    if scheme == "1d":
        return ids // max(1, Vl)
    from repro.core.distributed import _grid_shape
    Pr, Pc = _grid_shape(num_devices)
    return (ids % Pr) * Pc + (ids // Pr) % Pc


# --------------------------------------------------------------------------
# classification: layout.bnd vs the brute-force boundary oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", ["1d", "2d"])
@pytest.mark.parametrize("num_devices", [2, 3, 4])
def test_boundary_classification_matches_oracle(num_devices, scheme):
    rng = np.random.default_rng(7 * num_devices)
    for n, m in [(17, 40), (64, 200), (40, 0)]:
        g = _random_graph(rng, n, m)
        lay = partition_graph(g, num_devices, scheme=scheme)
        Vl = lay.verts_local
        owner = _owner_map(n, num_devices, scheme)
        # oracle: boundary iff any neighbor lives on another shard
        boundary = set()
        for v in range(n):
            nbrs = g.col_idx[g.row_ptr[v]:g.row_ptr[v + 1]]
            if any(owner[u] != owner[v] for u in nbrs):
                boundary.add(v)
        # layout.bnd holds local ids (pad = Vl); map back to original ids
        if lay.perm is not None:
            inv = {int(p): v for v, p in enumerate(lay.perm)}
        else:
            inv = {v: v for v in range(n)}
        got = set()
        for d in range(num_devices):
            row = lay.bnd[d]
            live = row[row < Vl]
            assert len(set(live.tolist())) == len(live), "dup halo slots"
            for l in live:
                got.add(inv[d * Vl + int(l)])
        assert got == boundary
        assert (np.asarray(lay.boundary_counts) <= lay.interior_counts
                + np.asarray(lay.boundary_counts)).all()


def test_shard_layout_legacy_triple_and_padding():
    g = _random_graph(np.random.default_rng(0), 32, 100)
    lay = partition_graph(g, 4)
    lsrc, ldst, vl = lay  # legacy tuple protocol
    assert lsrc.shape == lay.lsrc.shape and vl == lay.verts_local
    assert ldst.shape == lay.ldst.shape
    wide = lay.padded_boundary(lay.boundary_local + 5)
    assert wide.shape == (4, lay.boundary_local + 5)
    assert (wide[:, lay.boundary_local:] == lay.verts_local).all()
    if lay.boundary_local > 1:
        with pytest.raises(ValueError, match="halo capacity"):
            lay.padded_boundary(lay.boundary_local - 1)


def test_spec_validates_wire_and_partition():
    with pytest.raises(ValueError, match="wire"):
        ColoringSpec(wire="bogus")
    with pytest.raises(ValueError, match="partition"):
        ColoringSpec(partition="3d")


# --------------------------------------------------------------------------
# halo codec: exact round-trip at every field width
# --------------------------------------------------------------------------
@pytest.mark.parametrize("bound", [1, 2, 17, 143, 16383, 70000])
@pytest.mark.parametrize("n", [0, 1, 5, 64, 100])
def test_halo_pack_unpack_roundtrip(bound, n):
    rng = np.random.default_rng(bound + n)
    colors = rng.integers(0, bound + 1, n).astype(np.int32)
    pending = rng.integers(0, 2, n).astype(bool)
    words = np.asarray(pack_halo(colors, pending, bound))
    assert words.shape == (halo_words(n, bound),)
    k = max(1, 32 // halo_bits(bound))
    assert words.shape[0] == -(-n // k) if n else words.shape[0] == 0
    c2, p2 = unpack_halo(words, n, bound)
    np.testing.assert_array_equal(np.asarray(c2), colors)
    np.testing.assert_array_equal(np.asarray(p2), pending)


def test_halo_pack_batched_leading_dims():
    rng = np.random.default_rng(3)
    colors = rng.integers(0, 100, (4, 30)).astype(np.int32)
    pending = rng.integers(0, 2, (4, 30)).astype(bool)
    words = pack_halo(colors, pending, 100)
    c2, p2 = unpack_halo(words, 30, 100)
    np.testing.assert_array_equal(np.asarray(c2), colors)
    np.testing.assert_array_equal(np.asarray(p2), pending)


# --------------------------------------------------------------------------
# wire parity on real meshes (subprocess, as in test_distributed.py)
# --------------------------------------------------------------------------
_PARITY_CODE = """
    import json, numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import (rmat, color, ColoringSpec, BipartiteGraph,
                            validate_coloring, validate_d2_coloring,
                            validate_pd2_coloring)
    D = {devices}
    mesh = Mesh(np.array(jax.devices()[:D]), ("x",))
    g = rmat.paper_graph("RMAT-G", scale=7, seed=1)
    rng = np.random.default_rng(0)
    bg = BipartiteGraph.from_edges(
        48, 32, np.stack([rng.integers(0, 48, 192),
                          rng.integers(0, 32, 192)], 1))

    def pair(graph, **kw):
        reps = {{}}
        for wire in ("boundary", "full"):
            spec = ColoringSpec(strategy="distributed", mesh=mesh,
                                max_rounds=256, wire=wire, **kw)
            reps[wire] = color(graph, spec)
        b, f = reps["boundary"], reps["full"]
        same = (np.array_equal(b.colors, f.colors)
                and b.rounds == f.rounds
                and np.array_equal(
                    np.asarray(b.conflicts_per_round)[:b.rounds],
                    np.asarray(f.conflicts_per_round)[:f.rounds]))
        return b, bool(same)

    cells = []
    for eng, fr, part in [("sort", "off", "1d"), ("sort", "off", "2d"),
                          ("sort", "on", "1d"), ("bitmap", "off", "1d"),
                          ("bitmap", "on", "1d")]:
        rep, same = pair(g, engine=eng, frontier=fr, partition=part)
        cells.append(dict(cell=f"d1/{{eng}}/{{fr}}/{{part}}", same=same,
                          valid=bool(validate_coloring(g, rep.colors))))
    rep, same = pair(g, model="d2", engine="sort")
    cells.append(dict(cell="d2/sort", same=same,
                      valid=bool(validate_d2_coloring(g, rep.colors))))
    rep, same = pair(bg, model="pd2", engine="sort")
    cells.append(dict(cell="pd2/sort", same=same,
                      valid=bool(validate_pd2_coloring(bg, rep.colors))))
    {extra}
    print(json.dumps(dict(cells=cells)))
"""

_DEGENERATE = """
    from repro.core import Graph
    for tag, graph in [("V0", Graph.from_edges(0, np.empty((0, 2), np.int64))),
                       ("E0", Graph.from_edges(9, np.empty((0, 2), np.int64)))]:
        rep, same = pair(graph)
        cells.append(dict(cell=tag, same=same,
                          valid=bool(validate_coloring(graph, rep.colors))))
"""

_PLAN_SPILL = """
    from repro.core import compile_plan, PlanShape
    from repro.core.graph import pad_bucket
    shape = PlanShape(num_vertices=g.num_vertices,
                      padded_edges=pad_bucket(g.num_directed_edges),
                      max_degree=g.max_degree(), boundary_cap=2)
    auto = compile_plan(ColoringSpec(strategy="distributed", mesh=mesh,
                                     wire="auto"), shape)
    spilled = auto(g)  # Bl > 2 on every shard: must spill, not truncate
    ref = color(g, ColoringSpec(strategy="distributed", mesh=mesh,
                                wire="full"))
    cells.append(dict(cell="plan-spill",
                      same=bool(np.array_equal(spilled.colors, ref.colors)),
                      valid=bool(validate_coloring(g, spilled.colors))))
    strict = compile_plan(ColoringSpec(strategy="distributed", mesh=mesh,
                                       wire="boundary"), shape)
    try:
        strict(g)
        raised = False
    except ValueError:
        raised = True
    cells.append(dict(cell="plan-strict-raises", same=raised, valid=raised))
"""


@pytest.mark.parametrize("devices,extra", [(2, _DEGENERATE),
                                           (4, _PLAN_SPILL)])
def test_boundary_full_wire_parity(devices, extra):
    """The boundary wire must be bit-identical to the full gather —
    colors, rounds, conflict history — across engine x model x frontier
    and both partitioning schemes; degenerate graphs ride the 2-shard
    mesh and plan halo-spill behavior the 4-shard mesh."""
    code = textwrap.dedent(_PARITY_CODE).format(
        devices=devices, extra=textwrap.dedent(extra))
    res = _run_subprocess(code, devices=devices)
    bad = [c for c in res["cells"] if not (c["same"] and c["valid"])]
    assert not bad, bad


def test_wire_spec_is_inert_for_device_strategies():
    """wire/partition are distributed-strategy knobs; device strategies
    accept them and ignore them (same colors either way) — including a
    recolor warm start."""
    from repro.core import DynamicColoring
    g = _random_graph(np.random.default_rng(5), 48, 160)
    for strategy in ("iterative", "dataflow"):
        reps = [color(g, ColoringSpec(strategy=strategy, wire=w))
                for w in ("boundary", "full")]
        assert np.array_equal(reps[0].colors, reps[1].colors), strategy
    dyns = [DynamicColoring(g, ColoringSpec(strategy="recolor", wire=w,
                                            max_rounds=256))
            for w in ("boundary", "full")]
    ins = [[0, 1], [1, 2], [2, 0]]
    for dyn in dyns:
        dyn.apply_batch(inserts=ins)
    assert np.array_equal(dyns[0].colors, dyns[1].colors)


def test_single_device_mesh_boundary_wire_is_full_local():
    """On a 1-device mesh every vertex is interior (Bl = 0): the boundary
    wire runs with an empty halo slab and must still match the full wire."""
    g = _random_graph(np.random.default_rng(11), 60, 240)
    lay = partition_graph(g, 1)
    assert lay.boundary_local == 0
    reps = [color(g, ColoringSpec(strategy="distributed", wire=w))
            for w in ("boundary", "full")]
    assert np.array_equal(reps[0].colors, reps[1].colors)
    assert reps[0].rounds == reps[1].rounds


# --------------------------------------------------------------------------
# property: interior vertices are structurally unreferencable remotely
# --------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a dev dependency
    _HAVE_HYPOTHESIS = False


def _check_interior_unreferencable(n, m, num_devices, scheme, seed):
    g = _random_graph(np.random.default_rng(seed), n, m)
    lay = partition_graph(g, num_devices, scheme=scheme)
    Vl, Vp = lay.verts_local, lay.padded_vertices
    bnd_gids = {d * Vl + int(l) for d in range(num_devices)
                for l in lay.bnd[d] if l < Vl}
    for d in range(num_devices):
        owned = set(range(d * Vl, (d + 1) * Vl))
        interior = owned - bnd_gids
        # no other shard's edge list may read an interior vertex, and no
        # halo slab may carry it: its color cannot leave the shard
        for e in range(num_devices):
            if e == d:
                continue
            remote_reads = set(lay.ldst[e][lay.ldst[e] < Vp].tolist())
            assert not (interior & remote_reads), (d, e, scheme)
        assert not (interior & bnd_gids)


if _HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(4, 48), st.integers(0, 160), st.integers(2, 5),
           st.sampled_from(["1d", "2d"]), st.integers(0, 10 ** 6))
    def test_interior_vertices_unreferencable(n, m, num_devices, scheme,
                                              seed):
        _check_interior_unreferencable(n, m, num_devices, scheme, seed)
else:  # deterministic fallback sweep when hypothesis is absent
    @pytest.mark.parametrize("scheme", ["1d", "2d"])
    def test_interior_vertices_unreferencable(scheme):
        for n, m, D, seed in [(4, 0, 2, 0), (17, 40, 3, 1), (48, 160, 5, 2),
                              (33, 90, 4, 3)]:
            _check_interior_unreferencable(n, m, D, scheme, seed)
