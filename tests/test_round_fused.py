"""Fused round kernel tests (repro.kernels.round_fused + the
``"fused_pallas"`` engine): kernel-vs-oracle equivalence, the packed-entry
bit layout, and THE ISSUE-6 guarantee — ``fused_pallas`` is bit-identical
to ``bitmap``/``ell_pallas`` across the strategy x model x frontier parity
matrix, including recolor warm starts, the distributed driver, and V=0 /
E=0 degenerates — plus the interpret-default regression pin and a
hypothesis validity property."""
import numpy as np
import pytest

from repro.core import (BipartiteGraph, ColoringSpec, Graph, color,
                        compile_plan, rmat, validate_coloring,
                        validate_d2_coloring, validate_pd2_coloring)
from repro.core.engine import get_backend, num_color_words
from repro.kernels import (CONFLICT_BIT, COLOR_MASK, FORBID_BIT, firstfit,
                           pack_entries, round_fused, round_fused_ref,
                           tile_conflict_counts)

STRATEGIES = ["iterative", "dataflow"]
MODELS = ["d1", "d2", "pd2"]
FRONTIERS = ["off", "on"]


def _graph(name="RMAT-G", scale=8, seed=1):
    return rmat.paper_graph(name, scale=scale, seed=seed)


def _bipartite(seed=0, L=120, R=80, m=600):
    rng = np.random.default_rng(seed)
    return BipartiteGraph.from_edges(
        L, R, np.stack([rng.integers(0, L, m), rng.integers(0, R, m)], 1))


def _assert_same_report(a, b, ctx=""):
    np.testing.assert_array_equal(a.colors, b.colors, err_msg=ctx)
    assert a.rounds == b.rounds, ctx
    np.testing.assert_array_equal(a.conflicts_per_round,
                                  b.conflicts_per_round, err_msg=ctx)
    np.testing.assert_array_equal(a.sweeps_per_round, b.sweeps_per_round,
                                  err_msg=ctx)


# ----------------------------------------------------------- kernel level
def test_pack_entries_bit_layout():
    import jax.numpy as jnp
    c = jnp.asarray([0, 7, COLOR_MASK], jnp.int32)
    ent = np.asarray(pack_entries(c, jnp.asarray([True, False, True]),
                                  jnp.asarray([False, True, True])))
    assert list(ent & COLOR_MASK) == [0, 7, COLOR_MASK]
    assert [bool(e & FORBID_BIT) for e in ent] == [True, False, True]
    assert [bool(e & CONFLICT_BIT) for e in ent] == [False, True, True]


def test_round_fused_matches_reference():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    for v, d, words in [(1, 1, 1), (37, 9, 2), (70, 17, 3)]:
        colors = rng.integers(0, 32 * words + 9, size=(v, d)).astype(np.int32)
        forbid = rng.random((v, d)) < 0.6
        elig = rng.random((v, d)) < 0.3
        own = rng.integers(0, 32 * words, size=(v,)).astype(np.int32)
        ent = pack_entries(jnp.asarray(colors), jnp.asarray(forbid),
                           jnp.asarray(elig))
        mex, conf = round_fused(ent, jnp.asarray(own), words=words,
                                block_v=16, block_d=8, interpret=True)
        rmex, rconf = round_fused_ref(ent, jnp.asarray(own), words=words)
        np.testing.assert_array_equal(np.asarray(mex), np.asarray(rmex))
        np.testing.assert_array_equal(np.asarray(conf), np.asarray(rconf))


def test_round_fused_mex_equals_firstfit():
    """With every entry FORBID and in range, the fused mex IS the firstfit
    mex — the bit-parity root of the engine guarantee."""
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    words = 2
    colors = rng.integers(0, 32 * words, size=(41, 11)).astype(np.int32)
    ent = pack_entries(jnp.asarray(colors), True, False)
    mex, conf = round_fused(ent, jnp.zeros((41,), jnp.int32), words=words,
                            block_v=16, block_d=8, interpret=True)
    ff = firstfit(jnp.asarray(colors), words=words, block_v=16, block_d=8,
                  interpret=True)
    np.testing.assert_array_equal(np.asarray(mex), np.asarray(ff))
    assert int(np.asarray(conf).sum()) == 0  # no CONFLICT bits packed


def test_round_fused_conflict_predicate():
    """Alg. 2 line 13 semantics: a row conflicts iff an ELIGIBLE entry
    matches its own nonzero color; uncolored rows and FORBID-only ties
    never conflict."""
    import jax.numpy as jnp
    colors = jnp.asarray([[3, 5], [3, 5], [3, 5], [0, 2]], jnp.int32)
    elig = jnp.asarray([[1, 0], [0, 0], [1, 1], [1, 1]], bool)
    ent = pack_entries(colors, True, elig)
    own = jnp.asarray([3, 3, 9, 0], jnp.int32)
    _, conf = round_fused(ent, own, words=1, block_v=8, block_d=8,
                          interpret=True)
    # row 0: eligible tie on 3 -> conflict; row 1: tie not eligible;
    # row 2: no color match; row 3: own == 0 (uncolored) never conflicts
    assert list(np.asarray(conf)) == [1, 0, 0, 0]


def test_tile_conflict_counts():
    import jax.numpy as jnp
    conf = jnp.asarray([1, 0, 1, 1, 0, 1, 0, 0, 1], jnp.int32)
    counts = np.asarray(tile_conflict_counts(conf, block_v=4))
    assert list(counts) == [3, 1, 1]
    assert counts.sum() == int(np.asarray(conf).sum())


# ------------------------------------------------- interpret default pin
def test_resolve_interpret_follows_module_default(monkeypatch):
    """Regression pin (ISSUE 6 satellite): ``interpret=None`` must resolve
    against the CURRENT ``ops.INTERPRET`` at call time — never bake the
    trace-time value into a jit cache keyed on ``None``."""
    from repro.kernels import ops
    assert ops.resolve_interpret(None) == ops.INTERPRET
    assert ops.resolve_interpret(True) is True
    assert ops.resolve_interpret(False) is False
    monkeypatch.setattr(ops, "INTERPRET", not ops.INTERPRET)
    assert ops.resolve_interpret(None) == ops.INTERPRET


# ------------------------------------------------------ the parity matrix
@pytest.mark.parametrize("frontier", FRONTIERS)
@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_fused_parity_matrix(strategy, model, frontier):
    """THE tentpole guarantee: ``fused_pallas`` is bit-identical to
    ``bitmap`` — colors, rounds, conflict and sweep histories — on every
    strategy x model x frontier cell."""
    g = _bipartite() if model == "pd2" else _graph(scale=8)
    base = dict(strategy=strategy, model=model, frontier=frontier,
                lowering="square", concurrency=8, max_rounds=256)
    ref = color(g, ColoringSpec(engine="bitmap", **base))
    fused = color(g, ColoringSpec(engine="fused_pallas", **base))
    _assert_same_report(ref, fused, f"{strategy}/{model}/{frontier}")
    valid = {"d1": validate_coloring, "d2": validate_d2_coloring,
             "pd2": validate_pd2_coloring}[model]
    assert valid(g, fused.colors)


def test_fused_vs_ell_pallas_same_bitset():
    """fused_pallas and ell_pallas build the same forbidden bitset, so the
    full reports match across all three table backends."""
    g = _graph(scale=8, seed=3)
    base = dict(strategy="iterative", concurrency=16, max_rounds=256)
    reports = [color(g, ColoringSpec(engine=e, **base))
               for e in ("bitmap", "ell_pallas", "fused_pallas")]
    _assert_same_report(reports[0], reports[1])
    _assert_same_report(reports[0], reports[2])


def test_fused_distributed_parity():
    g = _graph(scale=8, seed=2)
    base = dict(strategy="distributed", concurrency=8, max_rounds=64)
    ref = color(g, ColoringSpec(engine="bitmap", **base))
    fused = color(g, ColoringSpec(engine="fused_pallas", **base))
    np.testing.assert_array_equal(ref.colors, fused.colors)
    assert ref.rounds == fused.rounds
    assert validate_coloring(g, fused.colors)


@pytest.mark.parametrize("frontier", FRONTIERS)
def test_fused_recolor_warm_parity(frontier):
    """Warm-start repair through the recolor strategy: fused and bitmap
    plans repair a seeded subset identically."""
    g = _graph(scale=8)
    base = color(g, ColoringSpec(strategy="iterative", concurrency=16))
    seed = np.zeros(g.num_vertices, bool)
    seed[:40] = True
    reps = {}
    for eng in ("bitmap", "fused_pallas"):
        plan = compile_plan(ColoringSpec(strategy="recolor", engine=eng,
                                         concurrency=16, max_rounds=64,
                                         frontier=frontier), g)
        reps[eng] = plan(g, colors=base.colors, seed=seed)
    np.testing.assert_array_equal(reps["bitmap"].colors,
                                  reps["fused_pallas"].colors)
    assert validate_coloring(g, reps["fused_pallas"].colors)


def test_fused_degenerate_graphs():
    """V=0 and E=0 graphs pass through the fused engine untouched."""
    empty = Graph.from_edges(0, np.zeros((0, 2), np.int64))
    r0 = color(empty, ColoringSpec(engine="fused_pallas"))
    assert r0.colors.shape == (0,) and r0.rounds == 0
    edgeless = Graph.from_edges(7, np.zeros((0, 2), np.int64))
    r1 = color(edgeless, ColoringSpec(engine="fused_pallas"))
    np.testing.assert_array_equal(np.asarray(r1.colors), np.ones(7))


# --------------------------------------------------------- bind contracts
def test_fused_bind_requires_ell_layout():
    backend = get_backend("fused_pallas")
    with pytest.raises(ValueError, match="ELL layout"):
        backend.bind(num_vertices=8, max_colors=4, ell_slot=None,
                     ell_width=0, max_degree=3)


def test_fused_bind_rejects_truncated_slab():
    import jax.numpy as jnp
    backend = get_backend("fused_pallas")
    with pytest.raises(ValueError, match="below the graph's max degree"):
        backend.bind(num_vertices=8, max_colors=9,
                     ell_slot=jnp.zeros((8,), jnp.int32), ell_width=2,
                     max_degree=8)
    with pytest.raises(ValueError, match="below the graph's max degree"):
        backend.bind_slab(capacity=8, max_colors=9, ell_width=2,
                          max_degree=8)


def test_fused_words_capacity_contract():
    backend = get_backend("fused_pallas")
    with pytest.raises(ValueError, match="static color bound"):
        backend.bind_slab(capacity=4, max_colors=0, ell_width=4,
                          max_degree=4)
    assert num_color_words(40) == 2  # sanity on the shared derivation


# --------------------------------------------------- hypothesis property
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is in requirements.txt
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def random_graphs(draw, max_v=24, max_e=60):
        n = draw(st.integers(2, max_v))
        m = draw(st.integers(0, max_e))
        edges = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        return Graph.from_edges(n, np.array(edges or [[0, 0]],
                                            dtype=np.int64))

    @settings(max_examples=15, deadline=None)
    @given(random_graphs(), st.sampled_from([1, 4, 16]))
    def test_fused_engine_always_valid_and_bitmap_identical(g, p):
        """Property: on arbitrary small graphs the fused engine yields a
        VALID coloring bit-identical to the bitmap engine."""
        base = dict(strategy="iterative", concurrency=p, max_rounds=256)
        ref = color(g, ColoringSpec(engine="bitmap", **base))
        fused = color(g, ColoringSpec(engine="fused_pallas", **base))
        np.testing.assert_array_equal(ref.colors, fused.colors)
        assert validate_coloring(g, fused.colors)
