"""Front-door API tests (repro.core.api): ColoringSpec resolution, the
strategy registry, spec-vs-legacy bit parity across the full
strategy x engine x model matrix, ordering correctness in *original* vertex
ids, and ColoringPlan reuse/batching with ZERO recompilation (pinned via the
plan's trace counter).
"""
import dataclasses
import warnings

import numpy as np
import pytest

import jax

from repro.core import (BipartiteGraph, ColoringPlan, ColoringReport,
                        ColoringSpec, Graph, PlanShape, available_strategies,
                        color, color_dataflow, color_distributed,
                        color_iterative, compile_plan, get_strategy,
                        greedy_color, greedy_color_d2, greedy_color_pd2,
                        register_strategy, rmat, validate_coloring,
                        validate_d2_coloring, validate_pd2_coloring)
from repro.core import api as api_mod
from repro.core.api import IterativeStrategy
from repro.core.graph import pad_bucket
from repro.core.ordering import ORDERINGS

GRAPHS = ["RMAT-ER", "RMAT-G", "RMAT-B"]
STRATEGIES = ["iterative", "dataflow"]
ENGINES = ["sort", "bitmap", "ell_pallas"]
MODELS = ["d1", "d2", "pd2"]


def _graph(name="RMAT-G", scale=8, seed=1):
    return rmat.paper_graph(name, scale=scale, seed=seed)


def _bipartite(seed=0, L=120, R=80, m=600):
    rng = np.random.default_rng(seed)
    return BipartiteGraph.from_edges(
        L, R, np.stack([rng.integers(0, L, m), rng.integers(0, R, m)], 1))


# ----------------------------------------------------------------- registry
def test_strategies_registered():
    assert set(STRATEGIES + ["distributed"]) <= set(available_strategies())


def test_get_strategy_by_name_and_instance():
    assert get_strategy("iterative") is get_strategy("iterative")
    inst = IterativeStrategy()
    assert get_strategy(inst) is inst
    with pytest.raises(ValueError, match="unknown coloring strategy"):
        get_strategy("no-such-strategy")
    with pytest.raises(ValueError, match="already registered"):
        register_strategy(IterativeStrategy())


def test_register_custom_strategy_is_one_subclass_plus_one_call():
    """The tentpole claim: a new algorithm = subclass + register_strategy,
    and every spec/plan/report feature (ordering, report, plan) works."""

    class Alias(IterativeStrategy):
        name = "iterative-alias"

    register_strategy(Alias())
    try:
        g = _graph()
        rep = color(g, strategy="iterative-alias", concurrency=8,
                    ordering="largest_first")
        assert isinstance(rep, ColoringReport)
        assert validate_coloring(g, rep.colors)
        plan = compile_plan(ColoringSpec(strategy="iterative-alias",
                                         concurrency=8), g)
        assert validate_coloring(g, plan(g).colors)
    finally:
        api_mod._REGISTRY.pop("iterative-alias", None)


def test_spec_validation():
    with pytest.raises(ValueError, match="unknown coloring model"):
        ColoringSpec(model="d3")
    with pytest.raises(ValueError, match="unknown lowering"):
        ColoringSpec(lowering="wedges")
    with pytest.raises(ValueError, match="unknown ordering"):
        color(_graph(), ordering="no-such-ordering")
    with pytest.raises(ValueError, match="unknown ordering"):
        compile_plan(ColoringSpec(ordering="degree"), _graph())


# ------------------------------------------------- spec vs legacy bit parity
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("model", MODELS)
def test_spec_matches_legacy_driver(strategy, engine, model):
    """color(g, spec) is bit-identical to the legacy per-driver call for
    every strategy x engine x model cell."""
    g = _bipartite() if model == "pd2" else _graph(scale=8)
    spec = ColoringSpec(strategy=strategy, model=model, engine=engine,
                        concurrency=8, max_rounds=256)
    rep = color(g, spec)
    if strategy == "iterative":
        legacy = color_iterative(g, concurrency=8, max_rounds=256,
                                 engine=engine, model=model)
        assert rep.rounds == legacy.rounds
        np.testing.assert_array_equal(
            rep.conflicts_per_round,
            np.asarray(legacy.conflicts_per_round)[:legacy.rounds])
    else:
        legacy = color_dataflow(g, engine=engine, model=model)
        assert rep.sweeps == legacy.sweeps
    np.testing.assert_array_equal(rep.colors, np.asarray(legacy.colors))
    valid = {"d1": validate_coloring, "d2": validate_d2_coloring,
             "pd2": validate_pd2_coloring}[model]
    assert valid(g, rep.colors)


def test_spec_matches_legacy_distributed():
    g = _graph("RMAT-ER", scale=8)
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("x",))
    colors, rounds, conf = color_distributed(g, mesh)
    rep = color(g, strategy="distributed", mesh=mesh, max_sweeps=16384)
    np.testing.assert_array_equal(rep.colors, colors)
    assert rep.rounds == rounds
    np.testing.assert_array_equal(rep.conflicts_per_round, conf[:rounds])
    assert rep.sweeps > 0  # the unified report gains the sweep histogram


def test_report_fields_and_oracle_identity():
    g = _graph()
    rep = color(g, strategy="dataflow")
    np.testing.assert_array_equal(rep.colors, greedy_color(g))
    assert rep.rounds == 1
    assert rep.conflicts_per_round.shape == (1,)
    assert rep.sweeps_per_round.shape == (1,)
    assert rep.total_conflicts == 0
    assert rep.wall_time_s > 0
    assert "dataflow" in repr(rep)
    assert rep.num_colors == int(greedy_color(g).max())


def test_shims_are_deprecationwarning_clean():
    """The legacy entry points route through the registry without emitting
    DeprecationWarning — the CI warnings lane runs the core suite under
    ``-W error::DeprecationWarning``."""
    g = _graph(scale=7)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        color_iterative(g, concurrency=8)
        color_dataflow(g)
        color(g)


# ------------------------------------------------------ ordering correctness
@pytest.mark.parametrize("ordering", sorted(ORDERINGS))
@pytest.mark.parametrize("model", ["d1", "d2"])
def test_ordering_reports_in_original_ids(ordering, model):
    """Orderings relabel internally; the report must come back valid in the
    ORIGINAL vertex ids for every registered ordering and model."""
    g = _graph("RMAT-B", scale=8)
    rep = color(g, strategy="iterative", model=model, ordering=ordering,
                concurrency=8, max_rounds=256, ordering_seed=3)
    valid = validate_coloring if model == "d1" else validate_d2_coloring
    assert valid(g, rep.colors)


def test_ordering_dataflow_equals_serial_greedy_in_that_order():
    """DATAFLOW + ordering == serial greedy visited in that order (the
    un-relabeling is exact, not merely validity-preserving)."""
    from repro.core import ordering as ordering_mod
    g = _graph("RMAT-G", scale=8)
    for name in ["largest_first", "smallest_last", "random"]:
        rep = color(g, strategy="dataflow", ordering=name, ordering_seed=5)
        order = ORDERINGS[name](g, 5)
        perm = np.empty_like(order)
        perm[order] = np.arange(order.shape[0])
        want = greedy_color(ordering_mod.apply(g, order))[perm]
        np.testing.assert_array_equal(rep.colors, want)
        assert validate_coloring(g, rep.colors)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is in requirements.txt
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def random_graphs(draw, max_v=32, max_e=90):
        n = draw(st.integers(2, max_v))
        m = draw(st.integers(0, max_e))
        edges = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        return Graph.from_edges(n, np.array(edges or [[0, 0]],
                                            dtype=np.int64))

    @settings(max_examples=12, deadline=None)
    @given(random_graphs(), st.sampled_from(sorted(ORDERINGS)),
           st.integers(0, 4))
    def test_plan_ordering_property(g, ordering, seed):
        """Property: a PLAN with any registered ordering returns a valid
        coloring in original vertex ids, bounded by degeneracy-style color
        counts (<= Delta+1)."""
        spec = ColoringSpec(strategy="dataflow", ordering=ordering,
                            ordering_seed=seed)
        rep = compile_plan(spec, g)(g)
        assert validate_coloring(g, rep.colors)
        assert rep.num_colors <= g.max_degree() + 1


# ------------------------------------------------------- plans: reuse, map
def test_pad_bucket_grid():
    assert pad_bucket(0) == 0   # degenerate: no phantom minimum bucket
    assert pad_bucket(1) == 256
    assert pad_bucket(256) == 256
    assert pad_bucket(257) == 320  # step 2^6 inside the (256, 512] octave
    for n in [300, 1000, 5000, 123456]:
        b = pad_bucket(n)
        assert b >= n
        assert b <= n * 1.25 + 1
        assert pad_bucket(b) == b  # buckets are fixed points


def test_plan_zero_retrace_across_same_bucket_graphs():
    """THE plan guarantee: a second same-bucket graph triggers zero
    recompilation (the trace counter stays at one)."""
    spec = ColoringSpec(strategy="iterative", engine="bitmap", concurrency=8)
    gs = [_graph("RMAT-G", scale=8, seed=s) for s in range(4)]
    shape = PlanShape(
        num_vertices=gs[0].num_vertices,
        padded_edges=pad_bucket(max(g.num_directed_edges for g in gs)),
        max_degree=max(g.max_degree() for g in gs))
    plan = compile_plan(spec, shape)
    assert plan.traces == 0
    reports = [plan(g) for g in gs]
    assert plan.traces == 1
    for g, rep in zip(gs, reports):
        assert validate_coloring(g, rep.colors)
        legacy = color_iterative(g, concurrency=8, engine="bitmap")
        np.testing.assert_array_equal(rep.colors, np.asarray(legacy.colors))
        assert rep.rounds == legacy.rounds


def test_plan_map_matches_python_loop():
    """plan.map (one vmapped program) == the per-graph python loop, and
    both stay on the compiled-once path."""
    spec = ColoringSpec(strategy="iterative", engine="sort", concurrency=8)
    gs = [_graph("RMAT-ER", scale=8, seed=s) for s in range(3)]
    shape = PlanShape(
        num_vertices=gs[0].num_vertices,
        padded_edges=pad_bucket(max(g.num_directed_edges for g in gs)),
        max_degree=max(g.max_degree() for g in gs))
    plan = compile_plan(spec, shape)
    looped = [plan(g) for g in gs]
    mapped = plan.map(gs)
    assert plan.traces == 2  # one per-graph trace + one vmapped trace
    for one, many in zip(looped, mapped):
        np.testing.assert_array_equal(one.colors, many.colors)
        assert one.rounds == many.rounds
        np.testing.assert_array_equal(one.conflicts_per_round,
                                      many.conflicts_per_round)
        np.testing.assert_array_equal(one.sweeps_per_round,
                                      many.sweeps_per_round)
    # a second same-size batch reuses the vmapped program too
    plan.map(list(reversed(gs)))
    assert plan.traces == 2
    assert plan.map([]) == []


def test_plan_map_with_ordering_unrelabels_per_graph():
    spec = ColoringSpec(strategy="dataflow", ordering="largest_first")
    gs = [_graph("RMAT-B", scale=7, seed=s) for s in range(2)]
    shape = PlanShape(
        num_vertices=gs[0].num_vertices,
        padded_edges=pad_bucket(max(g.num_directed_edges for g in gs)),
        max_degree=max(g.max_degree() for g in gs))
    mapped = compile_plan(spec, shape).map(gs)
    for g, rep in zip(gs, mapped):
        assert validate_coloring(g, rep.colors)


def test_plan_d2_model_and_oracle():
    g = _graph("RMAT-ER", scale=7)
    plan = compile_plan(ColoringSpec(strategy="dataflow", model="d2"), g)
    rep = plan(g)
    np.testing.assert_array_equal(rep.colors, greedy_color_d2(g))
    assert plan.traces == 1


def test_plan_pd2_model_and_oracle():
    bg = _bipartite()
    plan = compile_plan(ColoringSpec(strategy="dataflow", model="pd2"), bg)
    rep = plan(bg)
    np.testing.assert_array_equal(rep.colors, greedy_color_pd2(bg))
    assert validate_pd2_coloring(bg, rep.colors)


def test_plan_ell_pallas_zero_retrace():
    spec = ColoringSpec(strategy="iterative", engine="ell_pallas",
                        concurrency=8)
    g0, g1 = (_graph("RMAT-ER", scale=7, seed=s) for s in (0, 1))
    shape = PlanShape(num_vertices=g0.num_vertices,
                      padded_edges=pad_bucket(max(g0.num_directed_edges,
                                                  g1.num_directed_edges)),
                      max_degree=max(g0.max_degree(), g1.max_degree()))
    plan = compile_plan(spec, shape)
    for g in (g0, g1):
        assert validate_coloring(g, plan(g).colors)
    assert plan.traces == 1


def test_distributed_plan_reuse():
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("x",))
    spec = ColoringSpec(strategy="distributed", mesh=mesh, max_sweeps=16384)
    gs = [_graph("RMAT-ER", scale=8, seed=s) for s in (3, 4)]
    plan = compile_plan(spec, gs[0])
    for g in gs:
        rep = plan(g)
        assert validate_coloring(g, rep.colors)
        colors, rounds, _ = color_distributed(g, mesh)
        np.testing.assert_array_equal(rep.colors, colors)
        assert rep.rounds == rounds
    assert plan.traces == 1
    with pytest.raises(NotImplementedError, match="plan.map"):
        plan.map(gs)


def test_plan_shape_rejections():
    spec = ColoringSpec(strategy="iterative", concurrency=8)
    n = 300
    ring = Graph.from_edges(
        n, np.stack([np.arange(n), (np.arange(n) + 1) % n], 1))
    plan = compile_plan(spec, ring)
    # wrong vertex count
    with pytest.raises(ValueError, match="compile a new plan"):
        plan(_graph(scale=8))
    # same V, too many constraint edges for the bucket
    rng = np.random.default_rng(0)
    dense = Graph.from_edges(
        n, np.stack([rng.integers(0, n, 4000), rng.integers(0, n, 4000)], 1))
    with pytest.raises(ValueError, match="above the plan bucket"):
        plan(dense)
    # same V, edges within bucket, but a hub above the degree bound
    star = Graph.from_edges(
        n, np.stack([np.zeros(n - 1, np.int64), np.arange(1, n)], 1))
    with pytest.raises(ValueError, match="exceeds the plan bound"):
        plan(star)
    # plans want host graphs (they relabel/pad on host)
    with pytest.raises(TypeError, match="host Graph"):
        compile_plan(spec, ring.to_device())
    with pytest.raises(ValueError, match="relabels on host"):
        color(ring.to_device(), ordering="random")
