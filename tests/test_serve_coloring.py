"""Coloring-service tests (repro.serve.coloring): LRU plan-cache behavior
keyed on the (spec, PlanShape) bucket envelope, vmapped micro-batching of
same-bucket requests with in-order results, stats accounting, and the CLI
smoke mode."""
import numpy as np
import pytest

from repro.core import ColoringSpec, color, rmat, validate_coloring
from repro.serve.coloring import ColoringService, main as serve_main


def _graphs(n=4, scale=8, name="RMAT-G"):
    return [rmat.paper_graph(name, scale=scale, seed=s) for s in range(n)]


def test_single_requests_share_a_cached_plan():
    svc = ColoringService(default_spec=ColoringSpec(strategy="dataflow"))
    gs = _graphs(3)
    # same family + scale: envelopes quantize onto the bucket ladder, so
    # same-bucket graphs MUST share one plan (and its single jit trace)
    keys = {svc.envelope(svc.default_spec, g) for g in gs}
    served = [svc.color(g) for g in gs]
    st = svc.stats()
    assert st["requests"] == 3
    assert st["cache_misses"] == len(keys)
    assert st["cache_hits"] == 3 - len(keys)
    assert st["resident_plans"] == len(keys)
    for g, s in zip(gs, served):
        assert validate_coloring(g, s.report.colors)
    # served colors == the front-door one-shot result
    ref = color(gs[0], svc.default_spec)
    np.testing.assert_array_equal(ref.colors, served[0].report.colors)


def test_micro_batching_matches_sequential_and_keeps_order():
    spec = ColoringSpec(strategy="dataflow", engine="bitmap")
    svc = ColoringService(default_spec=spec)
    gs = _graphs(4)
    served = svc.color_batch(gs)
    assert [s.report.colors.shape for s in served] \
        == [(g.num_vertices,) for g in gs]
    for g, s in zip(gs, served):
        assert validate_coloring(g, s.report.colors)
        np.testing.assert_array_equal(color(g, spec).colors,
                                      s.report.colors)
    st = svc.stats()
    assert st["requests"] == 4
    assert st["micro_batches"] >= 1
    assert st["batched_requests"] >= 2
    assert any(s.batched for s in served)


def test_mixed_spec_batch_groups_by_key():
    g = _graphs(1)[0]
    s1 = ColoringSpec(strategy="dataflow")
    s2 = ColoringSpec(strategy="iterative", concurrency=16)
    svc = ColoringService()
    served = svc.color_batch([(g, s1), (g, s2), (g, s1)])
    assert [s.key[0] for s in served] == [s1, s2, s1]
    for s in served:
        assert validate_coloring(g, s.report.colors)
    assert svc.stats()["resident_plans"] == 2


def test_lru_eviction():
    svc = ColoringService(cache_size=1,
                          default_spec=ColoringSpec(strategy="dataflow"))
    a = rmat.paper_graph("RMAT-G", scale=7, seed=0)
    b = rmat.paper_graph("RMAT-G", scale=8, seed=0)  # different V: new key
    svc.color(a)
    svc.color(b)
    svc.color(a)  # evicted by b, recompiled
    st = svc.stats()
    assert st["resident_plans"] == 1
    assert st["evictions"] == 2
    assert st["cache_misses"] == 3 and st["cache_hits"] == 0


def test_recolor_runtime_state_flows_through_service():
    g = _graphs(1)[0]
    spec = ColoringSpec(strategy="recolor", concurrency=16)
    svc = ColoringService(default_spec=spec)
    base = svc.color(g).report
    seed = np.zeros(g.num_vertices, bool)
    seed[:4] = True
    rep = svc.color(g, colors=base.colors, seed=seed).report
    assert validate_coloring(g, rep.colors)
    np.testing.assert_array_equal(rep.colors[~seed], base.colors[~seed])
    assert svc.stats()["cache_hits"] == 1  # warm start reused the plan


def test_stats_shape():
    svc = ColoringService()
    st = svc.stats()
    assert st["requests"] == 0 and st["latency"] == {"count": 0}
    svc.color(_graphs(1)[0])
    st = svc.stats()
    assert st["latency"]["count"] == 1
    assert st["throughput_gps"] > 0
    for k in ("mean_ms", "p50_ms", "p95_ms", "max_ms"):
        assert st["latency"][k] >= 0


def test_cli_smoke(capsys):
    svc = serve_main(["--smoke", "--requests", "4", "--batch", "2",
                      "--scale", "7", "--stream-batches", "1"])
    out = capsys.readouterr().out
    assert "[serve] served 4 requests" in out
    assert "bit-identical colors=True" in out
    assert "streaming done" in out
    cum = svc.metrics.snapshot()["cumulative"]
    assert cum["requests"] == 4 + 1  # 4 coloring requests + 1 stream delta
    assert cum["stream_deltas"] == 1


def test_cache_size_validation():
    with pytest.raises(ValueError):
        ColoringService(cache_size=0)


def test_envelope_degree_quantizes_to_octaves():
    """The cache key's degree bound rounds up to full powers of two:
    family-level degree jitter (R-MAT hubs) must not fragment the cache
    into one plan per graph."""
    svc = ColoringService()
    spec = svc.default_spec
    shapes = {svc.envelope(spec, g) for g in _graphs(4)}
    for sh in shapes:
        assert sh.max_degree & (sh.max_degree - 1) == 0  # power of two
    # far fewer keys than graphs (the whole point of the quantization)
    assert len(shapes) <= 2


def test_latency_window_is_bounded():
    """Long-lived services must not grow a float per request forever: the
    latency deque is a sliding window, the counters stay lifetime-exact."""
    svc = ColoringService(latency_window=3)
    g = _graphs(1)[0]
    for _ in range(5):
        svc.color(g)
    st = svc.stats()
    assert st["requests"] == 5          # lifetime counter
    assert st["latency"]["count"] == 3  # window-bounded percentiles


def test_stats_commit_is_per_flush_not_per_enqueue(fake_clock):
    """The atomicity pin (deterministic, no threads): stats used to mutate
    per request inside color_batch, so a reader racing the flush saw
    half-updated counters (requests ahead of latencies, a micro-batch
    counted before its members). Now every counter for a flush commits in
    ONE _commit call — probed here by snapshotting stats() from *inside*
    the flush via the injected clock: no probe may ever observe counters
    that moved mid-flush."""
    probes = []
    box = []

    def clock():
        if box:
            st = box[0].stats()
            probes.append((st["requests"], st["latency"]["count"],
                           st["micro_batches"]))
        fake_clock.tick(0.001)
        return fake_clock.t

    # recolor doesn't support plan.map -> the loop path, which calls the
    # clock between every request in the flush (max probe coverage)
    svc = ColoringService(default_spec=ColoringSpec(strategy="recolor",
                                                    concurrency=16),
                          clock=clock)
    box.append(svc)
    gs = _graphs(4, scale=7)
    served = svc.color_batch(gs)
    # every in-flight probe saw the PRE-flush state: nothing moves until
    # the single commit at flush end
    assert probes and all(p == (0, 0, 0) for p in probes)
    st = svc.stats()
    assert st["requests"] == 4 and st["latency"]["count"] == 4
    # and the injected clock makes latencies exact: first request carries
    # the plan lookup (2 ticks), the rest one tick each
    lats = [s.latency_s for s in served]
    assert lats[0] == pytest.approx(0.002)
    assert lats[1:] == pytest.approx([0.001] * 3)
