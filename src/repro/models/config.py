"""Unified model configuration for all assigned architectures.

One dataclass drives the composable stack: family switches select the block
types, optional sub-configs (moe/mla/ssm/rglru/encdec/vlm) activate features.
Every field maps to a line of the assignment table; reduced ("smoke") configs
reuse the same switches with small dims.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # expert FFN hidden size
    num_shared: int = 0           # always-on shared experts (deepseek)
    d_shared: int = 0             # shared-expert hidden size
    first_dense_layers: int = 0   # leading dense layers (deepseek layer 0)
    first_dense_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # sharding: "expert" (EP: experts over model axis) or "tensor" (TP on
    # d_expert — used when num_experts doesn't divide the model axis, grok)
    partition: str = "expert"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int = 0                # lru width (0 -> d_model)
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    tail_pattern: Tuple[str, ...] = ("rec", "rec")  # leftover layers


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 24
    enc_seq: int = 1500           # whisper mel-frame count (conv stub output)
    enc_pos: str = "sinusoid"


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    cross_every: int = 5          # one cross-attn block per 5 layers
    num_image_tokens: int = 1601  # ViT-H/14 @ 448px + cls, pre-projected stub


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    act: str = "swiglu"           # swiglu | gelu
    rope_theta: float = 10_000.0
    qk_norm: bool = False         # qwen3
    attn_softcap: float = 0.0     # gemma2: 50.0
    logit_softcap: float = 0.0    # gemma2: 30.0
    post_norms: bool = False      # gemma2 post-attn/post-ffn norms
    local_window: int = 0         # window for "local" layers
    layer_pattern: str = "global"  # global | local_global | griffin
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    emb_scale: bool = False       # gemma-style sqrt(d) embedding scaling

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None

    # numerics / training
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Total parameters (used for 6·N·D model-FLOPs accounting)."""
        from . import counting
        return counting.param_count(self)

    def active_param_count(self) -> int:
        from . import counting
        return counting.active_param_count(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
