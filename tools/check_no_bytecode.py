"""Fail if compiled-python artifacts are tracked in git.

The repo once carried 79 committed ``__pycache__`` ``.pyc`` files; this
guard (a CI step in ``.github/workflows/ci.yml``) keeps them from coming
back: it exits non-zero, listing the offenders, whenever ``git ls-files``
reports any ``__pycache__`` directory entry or compiled-python suffix.

Usage:
    python tools/check_no_bytecode.py [repo_root]
"""
from __future__ import annotations

import subprocess
import sys

BAD_SUFFIXES = (".pyc", ".pyo", ".pyd")


def tracked_bytecode(repo_root: str = ".") -> list:
    out = subprocess.run(
        ["git", "ls-files", "-z"], cwd=repo_root,
        capture_output=True, check=True)
    files = [f for f in out.stdout.decode("utf-8", "replace").split("\0") if f]
    return [
        f for f in files
        if f.endswith(BAD_SUFFIXES) or "__pycache__" in f.split("/")
    ]


def main(argv) -> int:
    repo_root = argv[1] if len(argv) > 1 else "."
    bad = tracked_bytecode(repo_root)
    if bad:
        print("ERROR: compiled-python artifacts are tracked in git "
              "(add them to .gitignore and `git rm --cached`):",
              file=sys.stderr)
        for f in bad:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("ok: no tracked __pycache__/.pyc artifacts")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
