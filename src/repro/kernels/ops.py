"""Jit'd wrappers wiring the Pallas kernels into the coloring engine.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python for correctness); on TPU the same calls
compile to Mosaic. ``INTERPRET`` flips automatically based on the backend.

The speculative drivers no longer thread kernel closures through here: the
``"ell_pallas"`` entry in :mod:`repro.core.engine` binds the firstfit kernel
to a graph's ELL layout directly (``engine="ell_pallas"``). What remains are
the standalone kernel wrappers (serial-style mex over a slab, conflict
counting) used by benchmarks and tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .firstfit import firstfit
from .conflict import conflict_mask

INTERPRET = jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Resolve an ``interpret=`` override against the module-level
    ``INTERPRET`` default.

    Every kernel entry point that accepts ``interpret=None`` must call this
    OUTSIDE its jit boundary: ``interpret`` is a static argument, so a
    fallback read inside a jitted body is frozen at first trace — the cache
    key stays ``None`` and a later flip of ``ops.INTERPRET`` (tests, TPU
    attach) silently keeps serving the stale trace.

    This is exactly the hazard class the static analyzer lints for as
    RETRACE001 (:mod:`repro.analysis.retrace`): a static jit arg that
    defaults to ``None`` or is tested ``is None`` inside the jitted body.
    The CI lint lane keeps the package free of new instances; this function
    is the sanctioned fix pattern.
    """
    return INTERPRET if interpret is None else bool(interpret)


def ell_gather_colors(colors: jnp.ndarray, ell: jnp.ndarray) -> jnp.ndarray:
    """Gather neighbor colors for an ELL adjacency slab.

    colors: [V] int32 (0 = uncolored); ell: [V, D] int32 neighbor ids with
    pad = V. Returns [V, D] int32 (pad slots -> 0). The gather stays outside
    the kernel (DESIGN.md §2: regularize, then go fast).
    """
    cpad = jnp.concatenate([colors, jnp.zeros((1,), jnp.int32)])
    return cpad[jnp.minimum(ell, colors.shape[0])]


@functools.partial(jax.jit, static_argnames=("words", "interpret"))
def _ell_mex(colors: jnp.ndarray, ell: jnp.ndarray, *, words: int,
             interpret: bool) -> jnp.ndarray:
    nbr = ell_gather_colors(colors, ell)
    return firstfit(nbr, words=words, interpret=interpret)


def ell_mex(colors: jnp.ndarray, ell: jnp.ndarray, *, words: int = 16,
            interpret: bool | None = None) -> jnp.ndarray:
    """mex per vertex from an ELL slab — kernel-powered Alg. 1 inner loop."""
    return _ell_mex(colors, ell, words=words,
                    interpret=resolve_interpret(interpret))


