"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
8 experts top-2. Experts TP-shard d_ff (8 experts don't divide model=16;
MoEConfig.partition="tensor" — see DESIGN.md §Arch-applicability).
[hf:xai-org/grok-1]"""
from ..models.config import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe", num_layers=64, d_model=6144,
        n_heads=48, n_kv_heads=8, head_dim=128, d_ff=32768, vocab_size=131072,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=32768,
                      partition="tensor"))


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok1-smoke", family="moe", num_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=256,
                      partition="tensor"))
