"""RG-LRU recurrent blocks (RecurrentGemma / Griffin).

The temporal mix is a gated linear recurrence
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(Lambda) * r_t),
executed with ``lax.associative_scan`` — the parallel-scan primitive is the
TPU-native substitute for a sequential RNN loop (log-depth, full VPU
utilization). Decode is the O(1) single-step update; combined with the local
attention layers' bounded window this gives the sub-quadratic ``long_500k``
path for recurrentgemma.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig, RGLRUConfig

_C = 8.0


def rglru_init(b, cfg: ModelConfig, r: RGLRUConfig):
    d = cfg.d_model
    dr = r.d_rnn or d
    b.dense("w_x", (d, dr), ("embed", "rnn"))
    b.dense("w_gate_branch", (d, dr), ("embed", "rnn"))
    b.dense("conv_w", (r.conv_width, dr), (None, "rnn"), scale=r.conv_width ** -0.5)
    b.zeros("conv_b", (dr,), ("rnn",))
    b.dense("w_r", (dr, dr), ("rnn", "rnn"))
    b.zeros("b_r", (dr,), ("rnn",))
    b.dense("w_i", (dr, dr), ("rnn", "rnn"))
    b.zeros("b_i", (dr,), ("rnn",))
    b.zeros("lambda_p", (dr,), ("rnn",))
    b.dense("w_out", (dr, d), ("rnn", "embed"))
    return b


def _gates(p, u):
    dt = u.dtype
    r_g = jax.nn.sigmoid(u @ p["w_r"].astype(dt) + p["b_r"].astype(dt))
    i_g = jax.nn.sigmoid(u @ p["w_i"].astype(dt) + p["b_i"].astype(dt))
    log_a = (-_C * jax.nn.softplus(p["lambda_p"].astype(jnp.float32))
             * r_g.astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i_g.astype(jnp.float32) * u.astype(jnp.float32))


def _conv(p, u):
    w = p["conv_w"].astype(u.dtype)
    kw = w.shape[0]
    out = u * w[kw - 1]
    for i in range(1, kw):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[kw - 1 - i]
    return out + p["conv_b"].astype(u.dtype)


def rglru_forward(p, x, cfg: ModelConfig, r: RGLRUConfig):
    """Full-sequence Griffin recurrent block.
    x [B,T,d] -> ([B,T,d], h_T, conv_tail)."""
    dt = x.dtype
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(dt))
    ux = x @ p["w_x"].astype(dt)
    w = r.conv_width
    conv_tail = jnp.pad(ux, ((0, 0), (w - 1, 0), (0, 0)))[:, -(w - 1):]
    u = _conv(p, ux)
    a, b = _gates(p, u)                                         # [B,T,dr] fp32

    def combine(l, r_):
        a1, b1 = l
        a2, b2 = r_
        return a1 * a2, b1 * a2 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(dt) * gate) @ p["w_out"].astype(dt)
    return y, h[:, -1], conv_tail


def rglru_decode(p, x, state, conv_tail, cfg: ModelConfig, r: RGLRUConfig):
    """One-token step. x [B,1,d]; state [B,dr]; conv_tail [B,W-1,dr]."""
    dt = x.dtype
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(dt))        # [B,1,dr]
    ux = x @ p["w_x"].astype(dt)                                 # [B,1,dr]
    window = jnp.concatenate([conv_tail, ux], axis=1)
    w = p["conv_w"].astype(dt)
    u = (jnp.einsum("bwc,wc->bc", window, w) + p["conv_b"].astype(dt))[:, None]
    new_tail = window[:, 1:]
    a, b = _gates(p, u)                                          # [B,1,dr]
    h = state.astype(jnp.float32) * a[:, 0] + b[:, 0]            # [B,dr]
    y = (h[:, None].astype(dt) * gate) @ p["w_out"].astype(dt)
    return y, h, new_tail
