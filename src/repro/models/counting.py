"""Parameter counting from the abstract (never-allocated) param tree.

MODEL_FLOPS accounting for §Roofline: 6·N·D for dense training steps,
6·N_active·D for MoE (N_active = non-expert params + top_k/E of routed
expert params + shared experts).
"""
from __future__ import annotations

import jax
import numpy as np


def _abstract(cfg):
    if cfg.family == "encdec":
        from .whisper import init_encdec
        params, _ = init_encdec(cfg, None)
    else:
        from .transformer import init_lm
        params, _ = init_lm(cfg, None)
    return params


def _leaves_with_path(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))[0]


def param_count(cfg) -> int:
    return int(sum(int(np.prod(leaf.shape))
                   for _, leaf in _leaves_with_path(_abstract(cfg))))


def expert_param_count(cfg) -> int:
    """Routed-expert params only (w_gate/w_up/w_down with an experts dim)."""
    if cfg.moe is None:
        return 0
    total = 0
    for path, leaf in _leaves_with_path(_abstract(cfg)):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if keys and keys[-1] in ("w_gate", "w_up", "w_down"):
            # routed experts have a num_experts dim
            if cfg.moe.num_experts in leaf.shape:
                total += int(np.prod(leaf.shape))
    return total


def active_param_count(cfg) -> int:
    n = param_count(cfg)
    if cfg.moe is None:
        return n
    routed = expert_param_count(cfg)
    active_routed = routed * cfg.moe.top_k / cfg.moe.num_experts
    return int(n - routed + active_routed)


def model_flops(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (forward-only prefill) / 2·N per token (decode),
    using active params for MoE."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
