"""Quickstart: the paper in ~50 lines.

Generates the paper's three R-MAT graph families, colors each with the
serial oracle (Alg. 1), the speculative ITERATIVE algorithm (Alg. 2) and the
dataflow fixpoint (Alg. 3-5, TPU adaptation), and validates the results.

The first-fit inner loop is pluggable (``--engine sort|bitmap|ell_pallas``,
see repro.core.engine); the ELL kernel path just needs the graph built in
the ELL layout — no hand-wired kernel closures.

    PYTHONPATH=src python examples/quickstart.py [--scale 12] [--engine bitmap]
"""
import argparse

import numpy as np

from repro.core import (rmat, greedy_color, color_iterative, color_dataflow,
                        validate_coloring, num_colors, available_backends,
                        get_backend)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--concurrency", type=int, default=128)
    ap.add_argument("--engine", default="sort", choices=available_backends(),
                    help="first-fit mex backend for ITERATIVE/DATAFLOW")
    args = ap.parse_args()

    layout = ("edges", "ell") if get_backend(args.engine).needs_ell else "edges"
    for name in ["RMAT-ER", "RMAT-G", "RMAT-B"]:
        g = rmat.paper_graph(name, scale=args.scale, seed=0)
        dg = g.to_device(layout=layout)

        serial = greedy_color(g)
        it = color_iterative(dg, concurrency=args.concurrency,
                             engine=args.engine)
        df = color_dataflow(dg, engine=args.engine)

        assert validate_coloring(g, serial)
        assert validate_coloring(g, np.asarray(it.colors))
        assert validate_coloring(g, np.asarray(df.colors))
        exact = np.array_equal(np.asarray(df.colors), serial)

        s = g.stats()
        print(f"{name}: |V|={s['num_vertices']} |E|={s['num_edges']} "
              f"maxdeg={s['max_degree']} engine={args.engine}")
        print(f"  serial greedy : {num_colors(serial):3d} colors")
        print(f"  ITERATIVE(P={args.concurrency}): {it.num_colors:3d} colors, "
              f"{it.rounds} rounds, {it.total_conflicts} conflicts")
        print(f"  DATAFLOW      : {df.num_colors:3d} colors, "
              f"{df.sweeps} sweeps, identical to serial: {exact}")


if __name__ == "__main__":
    main()
