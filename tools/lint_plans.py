"""CI lint lane: run the repro.analysis static analyzer and fail on drift.

Thin wrapper over ``python -m repro.analysis`` (the full registry sweep
plus the source-level passes) so CI has one entry point with the policy
spelled out:

* a gating finding (warning/error) with no ``baseline.json`` entry fails —
  fix the code, or allowlist the fingerprint WITH a reason string;
* a baseline entry no current finding matches also fails (stale drift:
  a risk-acceptance for code that no longer exists must not linger);
* info findings never gate.

Exit codes are stable and CI keys off them:

* ``0`` — clean: every gating finding allowlisted, no stale entries;
* ``1`` — new violations (possibly alongside stale entries);
* ``2`` — baseline drift only: stale entries match nothing — delete
  them (a risk-acceptance for vanished code must not linger).

Run locally before pushing::

  PYTHONPATH=src python tools/lint_plans.py --distributed [-v]

Extra arguments pass straight through to the analyzer CLI
(``--strategies``, ``--distributed``, ``--vmem-ceiling``, ...).
``--json PATH`` writes the machine-readable report object — the findings
list, the per-cell wire-cost tables (``--distributed``), and a summary —
which the CI lane uploads as an artifact. The CI lane runs this under
``-W error::DeprecationWarning`` so the analyzer itself — which traces
every registry program — also proves the coloring stack deprecation-clean
end to end.
"""
from __future__ import annotations

import sys

from repro.analysis.__main__ import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
