"""mamba2-130m [ssm]: 24L d_model=768, attn-free SSD, ssm_state=128,
headdim=64, expand=2. [arXiv:2405.21060]"""
from ..models.config import ModelConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=50280, head_dim=64,
        tie_embeddings=True,  # as the released model
        ssm=SSMConfig(d_state=128, headdim=64, expand=2, chunk=256))


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", num_layers=4, d_model=128,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=512, head_dim=32,
        ssm=SSMConfig(d_state=16, headdim=32, expand=2, chunk=32))
