"""The paper's own workload as a first-class config: distributed BSP
speculative coloring (core/distributed.py) of the paper's R-MAT graphs,
lowered onto the production meshes by launch/dryrun.py alongside the LM
architectures.

Scales follow the paper's Table 4 (scale-24..27, edge factor 8); the dry-run
lowers the scale given by ``dryrun_scale`` (default 24: 16.7M vertices,
~134M undirected edges -> ~268M directed, ~1M directed edges per device slab
at 512 devices with padding).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ColoringConfig:
    name: str = "rmat-coloring"
    family: str = "coloring"
    dryrun_scale: int = 24
    edge_factor: int = 8
    params: tuple = (0.55, 0.15, 0.15, 0.15)   # RMAT-B, the hostile one
    max_rounds: int = 64
    local_concurrency: int = 1
    # first-fit mex backend for the local solve: a name registered with
    # repro.core.engine. The dry-run lowers "sort" and "bitmap";
    # "ell_pallas" needs a real host graph (for the ELL width) and is only
    # reachable through color_distributed.
    engine: str = "sort"
    # coloring model ("d1" | "d2" | "pd2" — repro.core.distance2). At
    # dry-run time the model only changes the constraint-slab width (D2
    # edges ~ avg_degree x the D1 count) and the color-bound headroom; the
    # lowered BSP program is otherwise identical.
    model: str = "d1"
    # static color-capacity bound for the bitmap backend at dry-run time
    # (no host graph to read max_degree from; greedy on the paper's graphs
    # stays <= 143 colors, so 512 leaves ample headroom; D2 colorings use
    # up to ~avg_degree x more — still far below 512 at edge factor 8)
    color_bound: int = 512
    # vertex-visit ordering (repro.core.ordering.ORDERINGS). Purely a
    # runtime (host-relabel) knob: the dry-run lowering is ordering-
    # invariant, since a relabeled graph has identical slab shapes.
    ordering: str = "natural"
    # frontier execution (repro.core.frontier): "auto"/"on" compact
    # rounds >= 1 into per-shard active-set slabs and shrink the wire to
    # the frontier halo when every device's pending set fits; "off" sweeps
    # the full slab every round. Capacities ride the pad_bucket ladder off
    # the per-device slab shape, so the lowered program stays static.
    frontier: str = "auto"
    frontier_capacity: int = 0
    # distributed per-round exchange (repro.core.distributed): "auto"/
    # "boundary" exchange only the bit-packed boundary payload (the
    # default three-tier wire), "full" the legacy [Vp] gather. The dry-run
    # lowers the boundary program with a conservative halo slab (Bl = Vl:
    # every vertex boundary — shapes only, no host graph to classify with).
    wire: str = "auto"
    # vertex ownership: "1d" contiguous blocks, "2d" block-cyclic over a
    # device grid — spreads R-MAT hub regions so one shard doesn't carry
    # both the widest edge slab and the densest boundary. Shape-invariant
    # at dry-run time (ownership only permutes ids).
    partition: str = "1d"

    def to_dynamic_spec(self):
        """This config as the streaming-lane :class:`ColoringSpec`: the
        registered ``"recolor"`` strategy with this config's engine /
        bounds / frontier knobs — what a
        :class:`repro.core.dynamic.DynamicColoring` over the paper's
        workload runs when the R-MAT graph arrives as edge-delta batches
        instead of one static snapshot. Distance-1 only — the streaming
        layer's endpoint seeding under-repairs richer models, so a
        d2/pd2 config raises here instead of silently coercing."""
        if self.model != "d1":
            raise ValueError(
                f"streaming (recolor) is distance-1 only; config has "
                f"model={self.model!r}")
        from repro.core.api import ColoringSpec
        return ColoringSpec(strategy="recolor", engine=self.engine,
                            ordering="natural",  # recolor repairs in place
                            max_rounds=self.max_rounds,
                            color_bound=self.color_bound,
                            frontier=self.frontier,
                            frontier_capacity=self.frontier_capacity)

    def to_spec(self, mesh=None):
        """This config as a :class:`repro.core.api.ColoringSpec` for the
        registered ``"distributed"`` strategy — the runtime counterpart of
        the program the dry-run lowers (same engine/model/bounds), usable
        with ``repro.core.color`` / ``compile_plan`` directly."""
        from repro.core.api import ColoringSpec
        return ColoringSpec(strategy="distributed", model=self.model,
                            engine=self.engine, ordering=self.ordering,
                            max_rounds=self.max_rounds,
                            # the BSP local solve's sweep cap (not a config
                            # knob): match build_distributed_coloring's
                            # default so this spec compiles the SAME program
                            # the dry-run lowers and the legacy shim runs
                            max_sweeps=16384,
                            local_concurrency=self.local_concurrency,
                            color_bound=self.color_bound, mesh=mesh,
                            frontier=self.frontier,
                            frontier_capacity=self.frontier_capacity,
                            wire=self.wire, partition=self.partition)


def get_config() -> ColoringConfig:
    return ColoringConfig()


def get_smoke_config() -> ColoringConfig:
    return ColoringConfig(name="rmat-coloring-smoke", dryrun_scale=10)
