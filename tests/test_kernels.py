"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import rmat, greedy_color, color_iterative, validate_coloring
from repro.kernels import (firstfit, firstfit_ref, conflict_mask,
                           conflict_mask_ref, ell_mex)


@pytest.mark.parametrize("v,d", [(1, 1), (7, 3), (100, 17), (512, 16),
                                 (777, 33), (1024, 128)])
@pytest.mark.parametrize("cmax", [5, 200, 500])
def test_firstfit_shape_sweep(v, d, cmax):
    rng = np.random.default_rng(v * 1000 + d + cmax)
    nbr = rng.integers(0, cmax, size=(v, d)).astype(np.int32)
    nbr[rng.random((v, d)) < 0.3] = 0
    got = firstfit(jnp.asarray(nbr), words=16, interpret=True)
    want = firstfit_ref(jnp.asarray(nbr), 512)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("blocks", [(64, 32), (256, 64), (512, 128)])
def test_firstfit_block_shapes(blocks):
    bv, bd = blocks
    rng = np.random.default_rng(bv)
    nbr = rng.integers(0, 300, size=(300, 50)).astype(np.int32)
    got = firstfit(jnp.asarray(nbr), words=16, block_v=bv, block_d=bd,
                   interpret=True)
    want = firstfit_ref(jnp.asarray(nbr), 512)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_firstfit_dense_rows():
    """Rows forbidding exactly 1..k force mex = k+1."""
    v, d = 64, 40
    nbr = np.zeros((v, d), np.int32)
    for i in range(v):
        k = i % 33
        nbr[i, :k] = np.arange(1, k + 1)
    got = np.asarray(firstfit(jnp.asarray(nbr), words=16, interpret=True))
    for i in range(v):
        assert got[i] == (i % 33) + 1


@pytest.mark.parametrize("e", [1, 100, 1024, 5000])
def test_conflict_kernel(e):
    rng = np.random.default_rng(e)
    cs = rng.integers(0, 10, e).astype(np.int32)
    cd = rng.integers(0, 10, e).astype(np.int32)
    s = rng.integers(0, 100, e).astype(np.int32)
    t = rng.integers(0, 100, e).astype(np.int32)
    got = conflict_mask(*(jnp.asarray(x) for x in (cs, cd, s, t)), interpret=True)
    want = conflict_mask_ref(*(jnp.asarray(x) for x in (cs, cd, s, t)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ell_mex_against_graph():
    g = rmat.paper_graph("RMAT-G", scale=9, seed=7)
    colors = greedy_color(g).astype(np.int32)
    ell, _ = g.to_ell()
    mex = np.asarray(ell_mex(jnp.asarray(colors), jnp.asarray(ell),
                             interpret=True))
    nbrc = np.where(ell < g.num_vertices,
                    colors[np.minimum(ell, g.num_vertices - 1)], 0)
    assert not np.any(mex[:, None] == np.where(nbrc > 0, nbrc, -1))
    assert np.all(mex <= colors)


def test_iterative_with_kernel_mex_engine():
    """ITERATIVE with the Pallas firstfit engine (engine="ell_pallas", bound
    to the graph's ELL layout) == valid coloring with the same round
    structure as the sort engine."""
    g = rmat.paper_graph("RMAT-ER", scale=8, seed=3)
    dg = g.to_device(layout=("edges", "ell"))
    res_k = color_iterative(dg, concurrency=g.num_vertices,
                            engine="ell_pallas")
    res_s = color_iterative(dg, concurrency=g.num_vertices)
    assert validate_coloring(g, np.asarray(res_k.colors))
    assert res_k.rounds == res_s.rounds
    np.testing.assert_array_equal(np.asarray(res_k.conflicts_per_round),
                                  np.asarray(res_s.conflicts_per_round))
