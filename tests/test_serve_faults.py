"""Fault injection for the serving layer: kill the service at EVERY stream
batch boundary, restore from its checkpoint, and prove the restored run is
indistinguishable from an unkilled one — bit-identical colors AND
restart-invariant metrics counters — across all four engine backends
(including fused_pallas).

Determinism chain under test: ``Graph.undirected_edges`` round-trips
through ``Graph.from_edges`` to the SAME CSR (both lexsort-canonical), the
checkpointed plan envelope recompiles to the same static shapes, and the
recolor repair is a deterministic function of (CSR, colors, seed mask,
envelope) — so every delta batch after the restore must reproduce the
unkilled run's colors exactly. A fake clock with ``max_delay_s=0`` makes
every flush reason deterministic (``deadline``), so the whole metrics
flush histogram is restart-invariant too.
"""
import numpy as np
import pytest

from conftest import FakeClock
from repro.core import ColoringSpec, rmat, validate_coloring
from repro.serve.coloring import AsyncColoringService
from repro.serve.metrics import RESTART_INVARIANT

# engine -> (rmat scale, stream batches). The pallas engines run in
# interpret mode on CPU, so they stream a smaller graph over fewer
# boundaries; every engine still gets killed at EVERY boundary.
CASES = {
    "sort": (8, 3),
    "bitmap": (8, 3),
    "ell_pallas": (6, 2),
    "fused_pallas": (6, 2),
}

_SETUP_CACHE = {}  # engine -> (graph, deltas, reference (colors, edges, cum))


def _deltas(graph, k, m, seed=1):
    """k precomputed delta batches — both the reference and the killed run
    must apply byte-identical payloads. Deletes sample the ORIGINAL edge
    set (set semantics make re-deletes no-ops), so payloads don't depend
    on run state."""
    rng = np.random.default_rng(seed)
    base = graph.undirected_edges()
    V = graph.num_vertices
    out = []
    for _ in range(k):
        ins = np.stack([rng.integers(0, V, m), rng.integers(0, V, m)], 1)
        dels = base[rng.integers(0, base.shape[0], m)]
        out.append((ins, dels))
    return out


def _fresh_service(engine):
    # max_delay_s=0 + fake clock: every flush reason is "deadline",
    # deterministically, in both the reference and the restored run
    return AsyncColoringService(max_batch=4, max_delay_s=0.0,
                                clock=FakeClock())


def _run(engine, graph, deltas, *, kill_at=None, ckpt_root=None):
    """Stream all deltas through a service; with ``kill_at=i``, checkpoint
    after batch i, throw the service away, and continue on a restored one.
    Returns (stream, cumulative metrics)."""
    spec = ColoringSpec(strategy="recolor", engine=engine, concurrency=32)
    svc = _fresh_service(engine)
    svc.open_stream("t0", graph, spec)
    for i in range(len(deltas) + 1):
        if kill_at is not None and i == kill_at:
            step = svc.checkpoint(ckpt_root)
            svc = None  # the kill: only the checkpoint dir survives
            svc = AsyncColoringService.restore(
                ckpt_root, step=step, max_batch=4, max_delay_s=0.0,
                clock=FakeClock())
        if i == len(deltas):
            break
        ins, dels = deltas[i]
        h = svc.submit_delta("t0", inserts=ins, deletes=dels)
        svc.drain()
        assert h.result().kind == "delta"
    return svc.stream("t0"), svc.metrics.snapshot()["cumulative"]


def _setup(engine):
    if engine not in _SETUP_CACHE:
        scale, k = CASES[engine]
        graph = rmat.paper_graph("RMAT-G", scale=scale, seed=0)
        deltas = _deltas(graph, k, m=max(4, graph.num_edges // 50))
        dyn, cum = _run(engine, graph, deltas)
        assert validate_coloring(dyn.graph, dyn.colors)
        _SETUP_CACHE[engine] = (graph, deltas,
                                (np.asarray(dyn.colors).copy(),
                                 dyn.graph.undirected_edges().copy(), cum))
    return _SETUP_CACHE[engine]


@pytest.mark.parametrize(
    "engine,kill_at",
    [(e, k) for e, (_, nk) in CASES.items() for k in range(nk + 1)])
def test_kill_restore_is_bit_identical(engine, kill_at, tmp_path):
    """Kill + restore at boundary ``kill_at`` (0 = before any delta,
    K = after the last): final colors, final graph, and every
    restart-invariant metrics counter must equal the unkilled run's."""
    graph, deltas, (ref_colors, ref_edges, ref_cum) = _setup(engine)
    dyn, cum = _run(engine, graph, deltas, kill_at=kill_at,
                    ckpt_root=str(tmp_path))
    assert validate_coloring(dyn.graph, dyn.colors)
    np.testing.assert_array_equal(dyn.graph.undirected_edges(), ref_edges)
    np.testing.assert_array_equal(np.asarray(dyn.colors), ref_colors)
    # metrics survive the kill: the deterministic what-was-served counters
    # continue exactly (retraces/cache/latency are process-local — the
    # restored process legitimately recompiles once)
    for key in RESTART_INVARIANT:
        assert cum[key] == ref_cum[key], key
    assert cum["flush_reasons"] == ref_cum["flush_reasons"]


def test_checkpoint_refuses_inflight_requests(tmp_path):
    svc = AsyncColoringService(max_delay_s=10.0, clock=FakeClock())
    g = rmat.paper_graph("RMAT-G", scale=7, seed=0)
    svc.open_stream("t0", g, ColoringSpec(strategy="recolor"))
    svc.submit_delta("t0", inserts=[[0, 1]])
    with pytest.raises(RuntimeError, match="in flight"):
        svc.checkpoint(str(tmp_path))
    svc.drain()
    svc.checkpoint(str(tmp_path))  # quiescent: fine


def test_multi_tenant_checkpoint_restores_every_stream(tmp_path):
    """Two tenants with independent streams (different engines) checkpoint
    into ONE pytree and restore together, each bit-identical."""
    svc = AsyncColoringService(max_delay_s=0.0, clock=FakeClock())
    gA = rmat.paper_graph("RMAT-G", scale=7, seed=0)
    gB = rmat.paper_graph("RMAT-ER", scale=7, seed=1)
    svc.open_stream("tA", gA, ColoringSpec(strategy="recolor",
                                           engine="sort"))
    svc.open_stream("tB", gB, ColoringSpec(strategy="recolor",
                                           engine="bitmap"))
    for t, g in (("tA", gA), ("tB", gB)):
        svc.submit_delta(t, inserts=_deltas(g, 1, 8)[0][0])
    svc.drain()
    step = svc.checkpoint(str(tmp_path))
    svc2 = AsyncColoringService.restore(str(tmp_path), step=step,
                                        clock=FakeClock())
    assert svc2.stream_tenants == ("tA", "tB")
    for t in ("tA", "tB"):
        a, b = svc.stream(t), svc2.stream(t)
        assert b.spec.engine == a.spec.engine  # specs ride the manifest
        np.testing.assert_array_equal(a.colors, b.colors)
        np.testing.assert_array_equal(a.graph.undirected_edges(),
                                      b.graph.undirected_edges())
        assert validate_coloring(b.graph, b.colors)


def test_restore_rejects_unknown_schema(tmp_path):
    from repro.train import checkpoint as ckpt
    ckpt.save(str(tmp_path), 0, {"streams": {}},
              meta={"schema": 99, "stream_specs": {}})
    with pytest.raises(ValueError, match="schema"):
        AsyncColoringService.restore(str(tmp_path))
