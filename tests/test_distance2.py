"""Coloring-model layer tests: distance-2 and bipartite partial distance-2
through the engine (repro.core.distance2).

The invariants mirror the distance-1 suite one level up the model stack:
validity against the serial D2/PD2 oracles, DATAFLOW == serial oracle
exactly, backend parity (sort == bitmap bit-identically) under
``model="d2"``, and wedge/square lowering-strategy parity.
"""
import numpy as np
import pytest

from repro.core import (BipartiteGraph, Graph, rmat, greedy_color,
                        greedy_color_d2, greedy_color_pd2, color_iterative,
                        color_dataflow, validate_coloring,
                        validate_d2_coloring, validate_pd2_coloring,
                        count_d2_conflicts, count_pd2_conflicts,
                        square, partial_square)
from repro.core.distance2 import (as_constraint_graph, d2_device_graph,
                                  d2_pairs, pd2_device_graph, wedge_count)

GRAPHS = ["RMAT-ER", "RMAT-G", "RMAT-B"]


def _graph(name, scale=8, seed=1):
    return rmat.paper_graph(name, scale=scale, seed=seed)


def _bipartite(L=96, R=64, m=500, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, L, m), rng.integers(0, R, m)], 1)
    return BipartiteGraph.from_edges(L, R, edges)


# ----------------------------------------------------------------- lowering
@pytest.mark.parametrize("name", GRAPHS)
def test_square_is_distance2_closure(name):
    """G2's edges are exactly the distance-1 and distance-2 pairs."""
    g = _graph(name, scale=7)
    g2 = square(g)
    # dense oracle: A + A^2 (off-diagonal, boolean)
    V = g.num_vertices
    A = np.zeros((V, V), bool)
    src, dst = g.directed_edges()
    A[src, dst] = True
    want = A | (A.astype(np.int64) @ A.astype(np.int64) > 0)
    np.fill_diagonal(want, False)
    got = np.zeros_like(want)
    s2, d2 = g2.directed_edges()
    got[s2, d2] = True
    np.testing.assert_array_equal(got, want)


def test_d2_pairs_matches_square_pair_set():
    """The wedge multiset covers exactly G2's directed pair set (duplicates
    and inert-masked self wedges aside)."""
    g = _graph("RMAT-G", scale=7)
    fsrc, fdst, live = d2_pairs(g)
    keep = fsrc < g.num_vertices
    assert int(keep.sum()) == live
    got = set(zip(fsrc[keep].tolist(), fdst[keep].tolist()))
    s2, d2 = square(g).directed_edges()
    assert got == set(zip(s2.tolist(), d2.tolist()))


def test_wedge_count_matches_multiset():
    g = _graph("RMAT-B", scale=7)
    _fsrc, _fdst, _live = d2_pairs(g)
    # total emitted = 2E (distance-1 heads) + W (wedges, incl. masked)
    assert _fsrc.shape[0] == g.num_directed_edges + wedge_count(g)


def test_as_constraint_graph_input_validation():
    g = _graph("RMAT-ER", scale=7)
    bg = _bipartite()
    with pytest.raises(ValueError, match="needs the host graph"):
        as_constraint_graph(g.to_device(), "d2")
    with pytest.raises(ValueError, match="BipartiteGraph"):
        as_constraint_graph(g, "pd2")
    with pytest.raises(ValueError, match="pd2"):
        as_constraint_graph(bg, "d1")
    with pytest.raises(ValueError, match="unknown coloring model"):
        as_constraint_graph(g, "d3")
    with pytest.raises(ValueError, match="wedge"):
        d2_device_graph(g, strategy="wedge", layout=("edges", "ell"))


# ------------------------------------------------------------ D2 validity
@pytest.mark.parametrize("name", GRAPHS)
def test_d2_oracle_valid(name):
    g = _graph(name)
    colors = greedy_color_d2(g)
    assert validate_d2_coloring(g, colors)
    assert count_d2_conflicts(g, colors) == 0
    # D2 coloring is a (usually much) finer partition than D1
    assert colors.max() >= greedy_color(g).max()


@pytest.mark.parametrize("name", GRAPHS)
def test_d2_oracle_equals_d1_greedy_on_square(name):
    """greedy_color_d2(G) == greedy_color(G2): the model layer's core
    identity."""
    g = _graph(name)
    np.testing.assert_array_equal(greedy_color_d2(g), greedy_color(square(g)))


@pytest.mark.parametrize("name", GRAPHS)
@pytest.mark.parametrize("engine", ["sort", "bitmap"])
def test_iterative_d2_valid(name, engine):
    g = _graph(name)
    res = color_iterative(g, concurrency=16, engine=engine, model="d2",
                          max_rounds=256)
    assert validate_d2_coloring(g, np.asarray(res.colors))
    # a D2 coloring is in particular a valid D1 coloring
    assert validate_coloring(g, np.asarray(res.colors))


@pytest.mark.parametrize("name", GRAPHS)
def test_dataflow_d2_equals_serial_oracle(name):
    g = _graph(name)
    res = color_dataflow(g, model="d2")
    np.testing.assert_array_equal(np.asarray(res.colors), greedy_color_d2(g))


@pytest.mark.parametrize("name", GRAPHS)
def test_d2_backend_parity(name):
    """sort and bitmap are bit-identical under model="d2": same colors,
    rounds, and per-round conflict/sweep histories."""
    g = _graph(name)
    a = color_iterative(g, concurrency=16, engine="sort", model="d2",
                        max_rounds=256)
    b = color_iterative(g, concurrency=16, engine="bitmap", model="d2",
                        max_rounds=256)
    np.testing.assert_array_equal(np.asarray(a.colors), np.asarray(b.colors))
    assert a.rounds == b.rounds
    np.testing.assert_array_equal(np.asarray(a.conflicts_per_round),
                                  np.asarray(b.conflicts_per_round))
    np.testing.assert_array_equal(np.asarray(a.sweeps_per_round),
                                  np.asarray(b.sweeps_per_round))


def test_d2_strategy_parity():
    """wedge and square lowerings carry the same constraint set, so the
    driver produces bit-identical results under either."""
    g = _graph("RMAT-G")
    a = color_iterative(d2_device_graph(g, strategy="wedge"), concurrency=16,
                        max_rounds=256)
    b = color_iterative(d2_device_graph(g, strategy="square"), concurrency=16,
                        max_rounds=256)
    np.testing.assert_array_equal(np.asarray(a.colors), np.asarray(b.colors))
    assert a.rounds == b.rounds
    np.testing.assert_array_equal(np.asarray(a.conflicts_per_round),
                                  np.asarray(b.conflicts_per_round))


def test_d2_ell_backend():
    """model="d2" with the Pallas ELL backend: the auto strategy routes
    through the square lowering (deduped rows) and stays valid."""
    g = _graph("RMAT-ER", scale=7)
    res = color_iterative(g, concurrency=8, engine="ell_pallas", model="d2")
    assert validate_d2_coloring(g, np.asarray(res.colors))


# ----------------------------------------------------------------- PD2
def test_bipartite_graph_construction():
    bg = BipartiteGraph.from_edges(4, 3, np.array([[0, 0], [0, 0], [1, 0],
                                                   [3, 2], [1, 1]]))
    assert bg.num_edges == 4  # duplicate (0,0) dropped
    assert bg.left_degrees().tolist() == [1, 2, 0, 1]
    assert bg.right_degrees().tolist() == [2, 1, 1]
    with pytest.raises(ValueError, match="out of range"):
        BipartiteGraph.from_edges(2, 2, np.array([[0, 5]]))


def test_pd2_oracle_valid():
    bg = _bipartite()
    colors = greedy_color_pd2(bg)
    assert validate_pd2_coloring(bg, colors)
    assert count_pd2_conflicts(bg, colors) == 0
    # and the identity: PD2 == D1 greedy on the one-mode projection
    np.testing.assert_array_equal(colors, greedy_color(partial_square(bg)))


@pytest.mark.parametrize("engine", ["sort", "bitmap"])
def test_iterative_pd2_valid(engine):
    bg = _bipartite()
    res = color_iterative(bg, concurrency=16, engine=engine, model="pd2",
                          max_rounds=256)
    assert validate_pd2_coloring(bg, np.asarray(res.colors))


def test_dataflow_pd2_equals_serial_oracle():
    bg = _bipartite()
    res = color_dataflow(bg, model="pd2")
    np.testing.assert_array_equal(np.asarray(res.colors),
                                  greedy_color_pd2(bg))


def test_pd2_right_side():
    """side="right" colors the other class (column- vs row-compression)."""
    bg = _bipartite()
    res = color_iterative(pd2_device_graph(bg, side="right"), concurrency=8,
                          max_rounds=256)
    colors = np.asarray(res.colors)
    assert colors.shape[0] == bg.num_right
    assert validate_pd2_coloring(bg, colors, side="right")


def test_pd2_isolated_and_empty():
    bg = BipartiteGraph.from_edges(5, 3, np.zeros((0, 2), np.int64))
    assert np.all(greedy_color_pd2(bg) == 1)
    res = color_iterative(bg, concurrency=4, model="pd2")
    assert np.all(np.asarray(res.colors) == 1)


# hypothesis property tests live in tests/test_property.py (they skip as a
# module when hypothesis is absent, so they can't share this file)
