"""Budget checker: packed-entry bit fields, int32 index arithmetic, and
per-BlockSpec VMEM footprints.

Three resource envelopes that fail *silently* when exceeded — no exception,
just corrupt colorings or a Mosaic OOM at launch:

* **bit budget** (BIT001/BIT002) — the fused-round packed entry
  (:mod:`repro.kernels.round_fused`) holds the color in bits 0..27;
  ``FORBID_BIT`` is bit 28 and ``CONFLICT_BIT`` bit 29. A caller-asserted
  ``color_bound >= 2^28`` (or a ``words=`` override providing that many
  color slots) lets a legal color value alias the predicate bits: a color
  equal to ``FORBID_BIT`` would forbid nothing and conflict with
  everything. :func:`repro.core.engine._resolve_words` now rejects this at
  bind time (the PR-8 satellite); this pass reports it statically, before
  any program runs.
* **index width** (IDX001/IDX002) — ELL slab addressing computes
  ``row * D + slot`` in int32; ``(V+1) * max_degree >= 2^31`` wraps
  negative and scatters corrupt. Same for edge-list capacities.
* **VMEM footprint** (VMEM001) — per grid step, a Pallas kernel holds its
  BlockSpec blocks, scratch buffers, and the largest traced intermediate
  in VMEM (~16 MiB/core on current TPUs). The estimate reads the REAL
  geometry from the traced ``pallas_call`` equations (block shapes from
  ``grid_mapping``, scratch from the kernel jaxpr's trailing invars,
  intermediates from the kernel body's avals); the kernels also declare a
  closed-form model (``firstfit.vmem_estimate`` / ``round_fused.
  vmem_estimate``) used for spec-level checks before anything is traced —
  the forbidden-bitset scratch scales with ``words`` ~ ``max_degree/32``,
  so a high-degree plan can breach the ceiling with default block shapes.

The ceiling is configurable: ``vmem_ceiling_bytes=`` on the entry points,
or the ``REPRO_ANALYSIS_VMEM_CEILING`` environment variable.
"""
from __future__ import annotations

import os
from typing import List, Optional

import numpy as np

from .findings import Finding
from .jaxpr_walk import aval_bytes, site_of, walk_eqns

INT32_MAX = np.iinfo(np.int32).max

DEFAULT_VMEM_CEILING = int(os.environ.get(
    "REPRO_ANALYSIS_VMEM_CEILING", 16 * 1024 * 1024))


def _packed_color_capacity() -> int:
    """Highest color value the packed entry can represent (2^28 - 1)."""
    from ..kernels.round_fused import COLOR_MASK
    return int(COLOR_MASK)


def check_spec_budgets(spec, statics, *, backend=None,
                       vmem_ceiling: Optional[int] = None,
                       context: str = "") -> List[Finding]:
    """Spec/shape-level budget audit — needs no tracing, so it runs even
    for plans that would fail to compile.

    ``spec`` is a :class:`repro.core.api.ColoringSpec`; ``statics`` a
    :class:`repro.core.api.PlanShape` (constraint-graph space); ``backend``
    the resolved :class:`repro.core.engine.MexBackend` (resolved from the
    spec when omitted).
    """
    from ..core.engine import get_backend, num_color_words

    findings: List[Finding] = []
    ceiling = DEFAULT_VMEM_CEILING if vmem_ceiling is None else vmem_ceiling
    backend = get_backend(spec.engine) if backend is None else backend
    cap = _packed_color_capacity()

    V = int(statics.num_vertices)
    D = max(1, int(statics.max_degree))
    eff_colors = D + 1
    if int(spec.color_bound) > 0:
        eff_colors = min(eff_colors, int(spec.color_bound))

    # --- bit budget --------------------------------------------------------
    if int(spec.color_bound) > cap:
        findings.append(Finding(
            "BIT001", "core/api.py:ColoringSpec",
            f"color_bound={spec.color_bound} exceeds the packed-entry "
            f"color field (bits 0..27, max {cap}): a color at "
            f"{cap + 1} IS the FORBID bit — table backends reject this "
            "at bind time, and no engine can represent it", context))
    elif statics.max_degree + 1 > cap:
        findings.append(Finding(
            "BIT001", "core/api.py:PlanShape",
            f"max_degree={statics.max_degree} admits colors above the "
            f"packed-entry color field (max {cap})", context))
    words_override = getattr(backend, "words", None)
    if words_override and 32 * int(words_override) - 1 > cap \
            and getattr(backend, "needs_ell", False):
        findings.append(Finding(
            "BIT002", f"core/engine.py:{type(backend).__name__}",
            f"words={words_override} provides {32 * int(words_override)} "
            f"color slots, beyond the packed-entry field (max {cap})",
            context))

    # --- int32 index arithmetic -------------------------------------------
    if getattr(backend, "needs_ell", False) and (V + 1) * D > INT32_MAX:
        findings.append(Finding(
            "IDX001", "core/engine.py:bind",
            f"ELL slab (V+1)*D = {(V + 1) * D} overflows int32 "
            "(row*width+slot addressing wraps negative)", context))
    if int(statics.padded_edges) > INT32_MAX:
        findings.append(Finding(
            "IDX002", "core/api.py:PlanShape",
            f"padded_edges={statics.padded_edges} overflows int32 edge "
            "indexing", context))

    # --- declared-geometry VMEM model -------------------------------------
    if getattr(backend, "needs_ell", False):
        words = int(words_override) if words_override else \
            num_color_words(eff_colors)
        est, site = _declared_estimate(backend, words)
        if est > ceiling:
            findings.append(Finding(
                "VMEM001", site,
                f"declared per-grid-step VMEM estimate {est} B "
                f"(words={words} from {eff_colors} colors, default blocks) "
                f"exceeds the {ceiling} B ceiling — shrink the color bound "
                "or the block shape", context))
    return findings


def _declared_estimate(backend, words: int):
    """(bytes, site) from the kernel's own closed-form VMEM model."""
    if backend.name == "fused_pallas":
        from ..kernels.round_fused import vmem_estimate
        return vmem_estimate(words=words), "kernels/round_fused.py:round_fused"
    from ..kernels.firstfit import vmem_estimate
    return vmem_estimate(words=words), "kernels/firstfit.py:firstfit"


# --------------------------------------------------------------------------
# traced-geometry VMEM pass
# --------------------------------------------------------------------------
def check_pallas_vmem(closed_jaxpr, *, vmem_ceiling: Optional[int] = None,
                      context: str = "") -> List[Finding]:
    """VMEM audit of every ``pallas_call`` in a traced program, from the
    REAL lowered geometry (see module docstring)."""
    ceiling = DEFAULT_VMEM_CEILING if vmem_ceiling is None else vmem_ceiling
    findings: List[Finding] = []
    seen = set()

    def visit(eqn, enclosing):
        if eqn.primitive.name != "pallas_call":
            return
        gm = eqn.params.get("grid_mapping")
        kernel_jx = eqn.params.get("jaxpr")
        if gm is None or kernel_jx is None:
            return
        block_bytes = 0
        for bm in getattr(gm, "block_mappings", ()):
            sd = getattr(bm, "array_shape_dtype", None)
            if sd is not None:
                block_bytes += int(np.prod(sd.shape) if sd.shape else 1) \
                    * np.dtype(sd.dtype).itemsize
        n_scratch = int(getattr(gm, "num_scratch_operands", 0))
        scratch_bytes = sum(aval_bytes(v.aval)
                            for v in kernel_jx.invars[len(kernel_jx.invars)
                                                      - n_scratch:]) \
            if n_scratch else 0
        interm_bytes = 0
        for keqn in kernel_jx.eqns:
            for o in keqn.outvars:
                interm_bytes = max(interm_bytes, aval_bytes(o.aval))
        total = block_bytes + scratch_bytes + interm_bytes
        name = getattr(eqn.params.get("name_and_src_info"), "name",
                       "pallas_call")
        site = site_of(eqn)
        key = (site, name, total)
        if key in seen:
            return
        seen.add(key)
        if total > ceiling:
            findings.append(Finding(
                "VMEM001", site,
                f"kernel {name!r} per-grid-step VMEM estimate {total} B "
                f"(blocks {block_bytes} + scratch {scratch_bytes} + "
                f"largest intermediate {interm_bytes}) exceeds the "
                f"{ceiling} B ceiling", context))

    walk_eqns(closed_jaxpr.jaxpr, visit)
    return findings
