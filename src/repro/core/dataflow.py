"""DATAFLOW / DATAFLOWRECURSIVE — the paper's Algorithms 3-5, adapted to TPU.

The XMT version blocks each vertex's thread on ``readff(color[w])`` for every
smaller-index neighbor ``w`` — hardware dataflow over the dependency DAG
``w -> v  iff  (v,w) in E and w < v``. A TPU has no full/empty bits, so we
execute the *same DAG* as a chaotic fixpoint iteration of the dataflow
equations (DESIGN.md §2):

    c[v] <- mex{ c[w] : w in adj(v), w < v }     (uncolored w contributes 0)

All vertices update in parallel each sweep; vertices of dataflow level L hold
their final value after L sweeps (level = longest dependency path), so the
iteration converges in ``depth(DAG)`` sweeps to **exactly** the serial greedy
coloring in index order — the same invariant the XMT algorithm guarantees
(priority = vertex index, conceptually Jones-Plassmann). Deadlock-freedom is
structural: levels are computed by iteration, not discovered by blocking, so
DATAFLOWRECURSIVE's ``int_fetch_add`` recursion is unnecessary.

:func:`dataflow_levels` exposes the DAG depth / wavefront profile — the
"available parallelism" the XMT's 16K threads would have exploited.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from .graph import DeviceGraph
from .mex import segment_mex


@dataclasses.dataclass
class DataflowResult:
    colors: jnp.ndarray  # [V] int32, >= 1 — identical to serial greedy
    sweeps: int          # fixpoint sweeps == dataflow DAG depth (+1 check)

    @property
    def num_colors(self) -> int:
        return int(self.colors.max())


@functools.partial(jax.jit, static_argnames=("num_vertices", "max_sweeps"))
def _dataflow_impl(src, dst, *, num_vertices: int, max_sweeps: int):
    V = num_vertices
    syn_v = jnp.arange(V, dtype=jnp.int32)
    syn_c = jnp.zeros((V,), jnp.int32)
    # dependency edges: only smaller-index neighbors forbid a color
    dep = dst < src  # padding (src == dst == V) excluded

    def sweep(state):
        colors, changed, n = state
        cpad = jnp.concatenate([colors, jnp.zeros((1,), jnp.int32)])
        key_v = jnp.where(dep, src, V)
        key_c = jnp.where(dep, cpad[dst], 0)
        mex = segment_mex(
            jnp.concatenate([key_v, syn_v]),
            jnp.concatenate([key_c, syn_c]),
            V,
        )
        return mex, jnp.any(mex != colors), n + 1

    def cond(state):
        _, changed, n = state
        return jnp.logical_and(changed, n < max_sweeps)

    colors, changed, n = lax.while_loop(
        cond, sweep,
        (jnp.zeros((V,), jnp.int32), jnp.asarray(True), jnp.asarray(0, jnp.int32)),
    )
    return colors, n, changed


def color_dataflow(g: DeviceGraph, max_sweeps: int = 4096) -> DataflowResult:
    colors, sweeps, pending = _dataflow_impl(
        g.src, g.dst, num_vertices=g.num_vertices, max_sweeps=max_sweeps
    )
    if bool(pending):
        raise RuntimeError(f"DATAFLOW did not converge in {max_sweeps} sweeps")
    return DataflowResult(colors=colors, sweeps=int(sweeps))


@functools.partial(jax.jit, static_argnames=("num_vertices", "max_iters"))
def _levels_impl(src, dst, *, num_vertices: int, max_iters: int):
    V = num_vertices
    dep = dst < src

    def body(state):
        lv, changed, n = state
        lpad = jnp.concatenate([lv, jnp.zeros((1,), jnp.int32)])
        contrib = jnp.where(dep, lpad[dst], 0)
        seg = (
            jnp.zeros((V,), jnp.int32)
            .at[src].max(contrib, mode="drop")
        )
        new = seg + 1
        return new, jnp.any(new != lv), n + 1

    def cond(state):
        _, changed, n = state
        return jnp.logical_and(changed, n < max_iters)

    lv, _, n = lax.while_loop(
        cond, body,
        (jnp.ones((V,), jnp.int32), jnp.asarray(True), jnp.asarray(0, jnp.int32)),
    )
    return lv, n


def dataflow_levels(g: DeviceGraph, max_iters: int = 4096):
    """Dataflow level of each vertex (longest dependency chain ending at it).

    Returns (levels [V] int32 >= 1, depth). Wavefront L's vertices are
    pairwise independent — the paper's XMT threads resolve exactly this
    schedule through full/empty-bit blocking.
    """
    lv, _ = _levels_impl(g.src, g.dst, num_vertices=g.num_vertices, max_iters=max_iters)
    return lv, int(lv.max())
