"""Coloring validity / quality metrics (host + device variants)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .graph import Graph, DeviceGraph


def validate_coloring(graph: Graph, colors: np.ndarray) -> bool:
    """True iff every vertex is colored (>0) and no edge is monochromatic."""
    colors = np.asarray(colors)
    if colors.shape[0] < graph.num_vertices or (colors[: graph.num_vertices] <= 0).any():
        return False
    src, dst = graph.directed_edges()
    return not bool((colors[src] == colors[dst]).any())


def count_conflicts(graph: Graph, colors: np.ndarray) -> int:
    """Number of undirected monochromatic edges."""
    src, dst = graph.directed_edges()
    return int(((colors[src] == colors[dst]) & (src > dst)).sum())


def num_colors(colors) -> int:
    colors = np.asarray(colors)
    return int(colors.max()) if colors.size else 0


def device_conflict_edges(g: DeviceGraph, colors: jnp.ndarray) -> jnp.ndarray:
    """Boolean mask over the directed edge list: monochromatic, src>dst."""
    cpad = jnp.concatenate([colors, jnp.array([0], colors.dtype)])
    cs = cpad[g.src]
    cd = cpad[g.dst]
    return (cs == cd) & (cs > 0) & (g.src > g.dst)
