"""Shims for jax API drift around 0.4.37 vs current releases.

Three renames bit this repo (the same genus as the Pallas
``TPUCompilerParams`` shim in kernels/tpu_compat.py):

* ``jax.shard_map``   — lived at ``jax.experimental.shard_map.shard_map``;
* ``jax.set_mesh``    — absent; the ``Mesh`` object itself is the context
  manager on 0.4.x;
* ``jax.lax.pvary``   — absent; 0.4.x shard_map has no varying-manual-axes
  tracking, so the tag is a no-op there.

Import from here instead of jax directly wherever one of these is needed.
"""
from __future__ import annotations

import jax
from jax import lax

try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, **kw):  # type: ignore[no-redef]
        # 0.4.x shard_map has no replication rule for while_loop; the new
        # varying-manual-axes tracking (pvary) replaces check_rep entirely,
        # so disabling it here loses nothing we rely on.
        kw.setdefault("check_rep", False)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)


def set_mesh(mesh):
    """Context manager activating ``mesh`` (jax.set_mesh or Mesh-as-context)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def pvary(x, axis_names):
    """Tag ``x`` device-varying over ``axis_names`` where jax tracks that."""
    if hasattr(lax, "pvary"):
        return lax.pvary(x, axis_names)
    return x
