"""HLO analyzer unit tests — the roofline's measurement instrument."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.launch.hlo_analysis import analyze_hlo, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]") == 128
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[2], s8[3])") == 11
    assert _shape_bytes("pred[]") == 1  # scalar has empty dims -> 1 elem


def test_scan_trip_count_and_dot_flops():
    w = jnp.zeros((16, 64, 64), jnp.float32)
    x = jnp.ones((4, 64), jnp.float32)

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = lax.scan(body, x, w)
        return y.sum()

    c = jax.jit(f).lower(x, w).compile()
    st = analyze_hlo(c.as_text())
    assert 16 in st.while_trip_counts
    expect = 16 * 2 * 4 * 64 * 64
    assert abs(st.dot_flops - expect) / expect < 1e-6


def test_nested_scan_multiplier():
    w = jnp.zeros((4, 3, 32, 32), jnp.float32)
    x = jnp.ones((2, 32), jnp.float32)

    def f(x, w):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = lax.scan(inner, c, wo)
            return c2, None
        y, _ = lax.scan(outer, x, w)
        return y.sum()

    c = jax.jit(f).lower(x, w).compile()
    st = analyze_hlo(c.as_text())
    expect = 4 * 3 * 2 * 2 * 32 * 32
    assert abs(st.dot_flops - expect) / expect < 1e-6


def test_unrolled_matmul_counted_once():
    a = jnp.ones((8, 128), jnp.float32)
    b = jnp.ones((128, 16), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    st = analyze_hlo(c.as_text())
    expect = 2 * 8 * 128 * 16
    assert abs(st.dot_flops - expect) / expect < 1e-6
    # boundary bytes at least inputs+outputs
    assert st.boundary_bytes >= (8 * 128 + 128 * 16 + 8 * 16) * 4
