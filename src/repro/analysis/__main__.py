"""``python -m repro.analysis`` — sweep the full registry against the
committed baseline.

Runs every strategy x engine x model combination through the plan-level
passes (races, envelope leaks, budgets) plus the source-level passes
(retrace AST lint, dead-export scan), dedupes by fingerprint, and compares
against ``repro/analysis/baseline.json``:

* exit 0 — every gating finding is allowlisted and no baseline entry is
  stale;
* exit 1 — new violations (fix the code or extend the baseline with a
  reason string) and/or stale entries (baseline drift: remove them).

``--write-baseline`` regenerates the entry list from the current run,
preserving reason strings for fingerprints that already have one and
stamping ``TODO: justify`` on new ones — the file is meant to be
hand-annotated before committing, and the loader rejects empty reasons.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import (AnalysisConfig, SWEEP_ENGINES, SWEEP_MODELS, SWEEP_STRATEGIES,
               dedupe, lint_tree, load_baseline, save_baseline,
               split_by_severity, sweep_registry, compare)


def _csv(text):
    return tuple(s.strip() for s in text.split(",") if s.strip())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis sweep over the coloring registry")
    ap.add_argument("--strategies", type=_csv, default=SWEEP_STRATEGIES,
                    help="comma list (default: all registered)")
    ap.add_argument("--engines", type=_csv, default=SWEEP_ENGINES)
    ap.add_argument("--models", type=_csv, default=SWEEP_MODELS)
    ap.add_argument("--no-source", action="store_true",
                    help="skip the source-level passes (AST lint, dead "
                         "exports); plan sweep only")
    ap.add_argument("--vmem-ceiling", type=int, default=None,
                    help="per-grid-step VMEM budget in bytes "
                         "(default 16 MiB)")
    ap.add_argument("--baseline", default=None,
                    help="allowlist path (default: the committed "
                         "repro/analysis/baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from this run "
                         "(hand-annotate reasons before committing)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="dump every finding (pre-baseline) as JSON")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print info-grade and allowlisted findings")
    args = ap.parse_args(argv)

    config = AnalysisConfig(vmem_ceiling_bytes=args.vmem_ceiling,
                            baseline_path=args.baseline)
    findings = sweep_registry(
        strategies=args.strategies, engines=args.engines, models=args.models,
        config=config,
        progress=lambda ctx: print(f"  analyzing {ctx}", file=sys.stderr))
    if not args.no_source:
        findings = dedupe(findings + lint_tree())

    if args.json_path:
        with open(args.json_path, "w", encoding="utf-8") as f:
            json.dump([{"code": x.code, "site": x.site,
                        "severity": x.severity, "message": x.message,
                        "context": x.context} for x in findings], f, indent=2)

    errors, warnings_, infos = split_by_severity(findings)
    print(f"{len(findings)} finding(s): {len(errors)} error, "
          f"{len(warnings_)} warning, {len(infos)} info")

    if args.write_baseline:
        old = {}
        try:
            old = load_baseline(args.baseline)
        except ValueError:
            pass  # regenerating a malformed baseline is the point
        entries = {f.fingerprint: old.get(f.fingerprint, "TODO: justify")
                   for f in errors + warnings_}
        save_baseline(entries, args.baseline)
        print(f"wrote {len(entries)} baseline entr(ies); annotate any "
              "'TODO: justify' reasons before committing")
        return 0

    baseline = load_baseline(args.baseline)
    new, allowed, stale = compare(findings, baseline)
    if args.verbose:
        for f in infos:
            print(f.format())
        for f in allowed:
            print(f"allowed {f.format()}")
    for f in new:
        print(f"NEW     {f.format()}")
    for fp in stale:
        print(f"STALE   baseline entry {fp} matches nothing — remove it")
    if new or stale:
        print(f"FAIL: {len(new)} new violation(s), {len(stale)} stale "
              "baseline entr(ies)")
        return 1
    print(f"clean: {len(allowed)} allowlisted, {len(infos)} info")
    return 0


if __name__ == "__main__":
    sys.exit(main())
