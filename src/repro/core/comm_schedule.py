"""Coloring-driven collective scheduling — the framework-side application of
the paper's technique (DESIGN.md §3).

A set of point-to-point transfers (e.g. MoE expert all-to-all traffic, or
elastic re-shard moves) must be packed into *rounds* such that no two
transfers in a round share a source or a destination chip (port/link
conflicts). Transfers = vertices; port sharing = edges; rounds = colors:
exactly the distance-1 coloring abstraction of §1 of the paper, solved with
the paper's ITERATIVE algorithm.

The lower bound on rounds is the maximum port degree (max #transfers touching
one chip); greedy coloring of the conflict graph is at most 2x that and in
practice ~= it (the conflict graph is a union of cliques, which greedy colors
optimally per clique).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from .graph import Graph
from .greedy_ref import greedy_color
from .iterative import color_iterative


@dataclasses.dataclass
class CommSchedule:
    rounds: List[List[int]]          # transfer indices per round
    num_rounds: int
    lower_bound: int                 # max port degree

    @property
    def optimality_gap(self) -> float:
        return self.num_rounds / max(1, self.lower_bound)


def _clique_edges(groups: np.ndarray) -> np.ndarray:
    """Edges of the union-of-cliques graph induced by equal group labels."""
    order = np.argsort(groups, kind="stable")
    sorted_groups = groups[order]
    edges = []
    start = 0
    for i in range(1, len(order) + 1):
        if i == len(order) or sorted_groups[i] != sorted_groups[start]:
            members = order[start:i]
            if len(members) > 1:
                ii, jj = np.triu_indices(len(members), k=1)
                edges.append(np.stack([members[ii], members[jj]], 1))
            start = i
    if not edges:
        return np.zeros((0, 2), np.int64)
    return np.concatenate(edges, 0)


def schedule_transfers(
    transfers: Sequence[Tuple[int, int]],
    use_device: bool = False,
    max_rounds: int = 64,
) -> CommSchedule:
    """Pack (src_chip, dst_chip) transfers into conflict-free rounds.

    ``use_device=True`` runs the JAX ITERATIVE algorithm (what would execute
    on the TPU runtime); otherwise the serial oracle (host scheduling path).
    """
    t = np.asarray(transfers, dtype=np.int64)
    n = t.shape[0]
    if n == 0:
        return CommSchedule([], 0, 0)
    # conflict graph: same-src cliques + same-dst cliques; offset dst labels
    src_e = _clique_edges(t[:, 0])
    dst_e = _clique_edges(t[:, 1] + (t[:, 0].max() + 1))
    edges = np.concatenate([src_e, dst_e], 0)
    g = Graph.from_edges(n, edges) if edges.size else Graph.from_edges(n, np.zeros((0, 2), np.int64))
    if use_device and g.num_directed_edges > 0:
        res = color_iterative(g.to_device(), max_rounds=max_rounds)
        colors = np.asarray(res.colors)
    else:
        colors = greedy_color(g)
    k = int(colors.max())
    rounds = [list(np.nonzero(colors == c)[0]) for c in range(1, k + 1)]
    port_deg = max(
        int(np.bincount(t[:, 0]).max()),
        int(np.bincount(t[:, 1]).max()),
    )
    return CommSchedule(rounds=rounds, num_rounds=k, lower_bound=port_deg)


def moe_all_to_all_transfers(send_counts: np.ndarray) -> List[Tuple[int, int]]:
    """Transfers implied by a MoE dispatch matrix ``send_counts[D, D]``
    (tokens device i sends to device j); zero entries need no transfer."""
    src, dst = np.nonzero(send_counts)
    keep = src != dst
    return list(zip(src[keep].tolist(), dst[keep].tolist()))
