"""Multi-head Latent Attention (DeepSeek-V2) with compressed KV cache and
weight-absorbed decode.

Train/prefill: queries get per-head nope+rope parts; keys/values are
up-projected from a rank-``kv_lora`` latent ``ckv`` (RMS-normed); a single
shared rope key head rides alongside. Decode caches only ``[ckv, k_rope]``
(r + dr floats/token — 9x smaller than full GQA KV for the assigned config),
and absorbs the up-projections into the query/output paths so the per-step
attention contracts directly against the latent cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, MLAConfig
from .layers import flash_attention, rms_norm, rope as apply_rope


def mla_init(b, cfg: ModelConfig, m: MLAConfig):
    d, h = cfg.d_model, cfg.n_heads
    b.dense("wq", (d, h, m.nope_head_dim + m.rope_head_dim), ("embed", "heads", None))
    b.dense("wdkv", (d, m.kv_lora_rank + m.rope_head_dim), ("embed", None))
    b.zeros("ckv_norm", (m.kv_lora_rank,), ("kv_lora",))
    b.dense("wukv", (m.kv_lora_rank, h, m.nope_head_dim + m.v_head_dim),
            ("kv_lora", "heads", None))
    b.dense("wo", (h, m.v_head_dim, d), ("heads", None, "embed"))
    return b


def _project(p, x, positions, m: MLAConfig, eps: float):
    dt = x.dtype
    q = jnp.einsum("btd,dhe->bthe", x, p["wq"].astype(dt))
    qn, qr = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    qr = apply_rope(qr, positions)
    kv = x @ p["wdkv"].astype(dt)
    ckv, kr = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    ckv = rms_norm(ckv, p["ckv_norm"], eps)
    kr = apply_rope(kr[:, :, None, :], positions)[:, :, 0]      # single rope head
    return qn, qr, ckv, kr


def mla_forward(p, x, positions, cfg: ModelConfig, m: MLAConfig):
    """Full-sequence MLA. Returns (out [B,T,d], (ckv, kr) for cache fill)."""
    dt = x.dtype
    qn, qr, ckv, kr = _project(p, x, positions, m, cfg.norm_eps)
    kn_v = jnp.einsum("btr,rhe->bthe", ckv, p["wukv"].astype(dt))
    kn = kn_v[..., :m.nope_head_dim]
    v = kn_v[..., m.nope_head_dim:]
    h = cfg.n_heads
    k = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None, :], qr.shape[:2] + (h, m.rope_head_dim))], axis=-1)
    q = jnp.concatenate([qn, qr], axis=-1)
    attn = flash_attention(
        q, k, v, q_positions=positions, kv_positions=positions, causal=True,
        scale=(m.nope_head_dim + m.rope_head_dim) ** -0.5)
    out = jnp.einsum("bthv,hvd->btd", attn, p["wo"].astype(dt))
    return out, (ckv, kr)


def mla_decode(p, x, ckv_cache, kr_cache, cur_len, positions,
               cfg: ModelConfig, m: MLAConfig):
    """One-token decode against the compressed latent cache (absorbed form).

    x: [B, 1, d]; ckv_cache: [B, S, r]; kr_cache: [B, S, dr].
    Caller has already written this step's (ckv, kr) into the caches.
    """
    dt = x.dtype
    qn, qr, ckv_new, kr_new = _project(p, x, positions, m, cfg.norm_eps)
    wukv = p["wukv"].astype(dt)
    wuk = wukv[..., :m.nope_head_dim]                       # [r, H, dn]
    wuv = wukv[..., m.nope_head_dim:]                       # [r, H, dv]
    # absorb k up-projection into the query
    q_lat = jnp.einsum("bqhd,rhd->bqhr", qn, wuk)           # [B,1,H,r]
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                         ckv_cache.astype(jnp.float32))
              + jnp.einsum("bqhe,bse->bhqs", qr.astype(jnp.float32),
                           kr_cache.astype(jnp.float32))) * scale
    s = ckv_cache.shape[1]
    valid = jnp.arange(s)[None, :] < cur_len[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bhqs,bsr->bqhr", probs.astype(dt), ckv_cache)
    vout = jnp.einsum("bqhr,rhv->bqhv", lat, wuv)
    out = jnp.einsum("bqhv,hvd->bqd", vout, p["wo"].astype(dt))
    return out, (ckv_new, kr_new)
