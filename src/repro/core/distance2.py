"""The coloring-model layer: distance-2 and partial distance-2 lowerings.

The engine (`repro.core.engine`) colors *constraint graphs*: its SweepSpec
edge space is just "who forbids whom". Distance-1 coloring feeds it the
graph's own edge list; richer coloring models used in scientific computing
(Jacobian/Hessian compression — Gebremedhin et al.'s survey; Taş et al.
arXiv:1701.02628 for the bipartite multicore case; Bogle et al.
arXiv:2107.00075 for distributed D2) differ ONLY in that edge space:

* ``model="d1"``  — the graph's edges (adjacent vertices differ);
* ``model="d2"``  — pairs at distance <= 2 differ. Equivalently distance-1
  coloring of the square graph G²; constraints are (edge, edge) *wedges*
  v—w—u sharing a middle vertex, plus the distance-1 pairs;
* ``model="pd2"`` — bipartite partial distance-2: color ONE vertex class of
  a :class:`repro.core.graph.BipartiteGraph` so that two same-class
  vertices sharing a neighbor differ (the structure of column compression
  of a sparse Jacobian). Constraints are the wedges through the *other*
  class only — same-class vertices are never adjacent, so there is no
  distance-1 term.

Because every driver (`color_iterative`, `color_dataflow`, the distributed
local solve) already lowers an arbitrary constraint edge list into per-round
:class:`repro.core.engine.SweepSpec`\\ s, supporting a new model is exactly
one host-side lowering — no new sweep loop, no new mex backend, identical
speculation/conflict semantics, and full backend parity (sort == bitmap ==
ell_pallas) for free.

Two lowering strategies (``strategy=``):

* ``"wedge"``  — emit the wedge *multiset* directly: for every directed edge
  (v, w), one entry per u in adj(w) (self wedges v—w—v masked inert). No
  sort, no dedup — O(W) sequential construction where
  W = sum_e deg(dst(e)) — so G² is never materialized; duplicate forbids
  are harmless to the mex (idempotent) and invisible to conflict counting
  (the pending reduction is per-vertex). Memory-lean when degrees allow
  (W within budget); blocks per-edge, row-contiguous in ``src``.
* ``"square"`` — materialize G² on host via :func:`square` (lexsort +
  dedup over the same W pairs): a bigger host peak, but the deduped device
  edge list (|E(G²)| <= W) is smaller, and all DeviceGraph layouts
  (CSR/ELL — the ``ell_pallas`` backend) become available.
* ``"auto"``   — ``"square"`` when the ELL layout is requested (the slab
  scatter needs deduped, width-bounded rows), else ``"wedge"``.

Both strategies produce the same constraint *set*, so drivers produce
bit-identical colors, rounds and conflict histories under either.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import numpy as np

import jax.numpy as jnp

from .graph import BipartiteGraph, DeviceGraph, Graph

MODELS = ("d1", "d2", "pd2")
_STRATEGIES = ("auto", "wedge", "square")


# --------------------------------------------------------------------------
# host-side wedge expansion
# --------------------------------------------------------------------------
def _expand_rows(row_ptr: np.ndarray, col_idx: np.ndarray,
                 targets: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenate the CSR rows of ``targets`` (repeats preserved).

    Returns (values, counts) where ``values`` is the concatenation of
    ``col_idx[row_ptr[t]:row_ptr[t+1]]`` for each t in ``targets`` in order,
    and ``counts[i]`` is the length contributed by ``targets[i]``. Pure
    fancy-indexing — no sort, no python loop."""
    counts = (row_ptr[targets + 1] - row_ptr[targets]).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int32), counts
    block_starts = np.cumsum(counts) - counts
    pos = np.arange(total, dtype=np.int64) - np.repeat(block_starts, counts)
    return col_idx[np.repeat(row_ptr[targets], counts) + pos], counts


def wedge_count(graph: Graph) -> int:
    """W = sum over directed edges (v, w) of deg(w) — the size of the D2
    wedge multiset (before adding the 2E distance-1 pairs). The memory the
    ``"wedge"`` strategy commits to; callers can pre-check it against their
    budget before choosing a strategy."""
    _src, dst = graph.directed_edges()
    deg = graph.degrees()
    return int(deg[dst].sum())


def d2_pairs(graph: Graph) -> Tuple[np.ndarray, np.ndarray, int]:
    """The distance-<=2 constraint multiset as (src, dst, live) arrays.

    Per directed edge (v, w), emits the block [(v, w), (v, u) for u in
    adj(w)] — so the result is row-contiguous in ``src`` (edge blocks stay
    in CSR edge order). Self wedges v—w—v are masked inert: both endpoints
    set to the phantom vertex V, exactly the padding convention DeviceGraph
    edge lists already use. ``live`` is the number of unmasked entries."""
    V = graph.num_vertices
    src, dst = graph.directed_edges()
    two_hop, counts = _expand_rows(graph.row_ptr, graph.col_idx, dst)
    sizes = counts + 1
    total = int(sizes.sum())
    fsrc = np.repeat(src, sizes).astype(np.int32)
    fdst = np.empty(total, np.int32)
    starts = np.cumsum(sizes) - sizes
    head = np.zeros(total, np.bool_)
    head[starts] = True
    fdst[head] = dst
    fdst[~head] = two_hop
    self_pair = fsrc == fdst  # only wedges u == v; d1 pairs have no loops
    fsrc[self_pair] = V
    fdst[self_pair] = V
    return fsrc, fdst, total - int(self_pair.sum())


def square(graph: Graph) -> Graph:
    """G² as a host :class:`Graph`: vertices of ``graph``, an edge between
    every pair at distance 1 or 2. Distance-2 coloring of G == distance-1
    coloring of G², so this is the exact (dedup'd) lowering — and the input
    to the distributed driver, whose partitioner wants a real host CSR."""
    fsrc, fdst, _ = d2_pairs(graph)
    keep = fsrc < graph.num_vertices
    return Graph.from_edges(graph.num_vertices,
                            np.stack([fsrc[keep], fdst[keep]], axis=1))


def pd2_pairs(bg: BipartiteGraph, side: str = "left"
              ) -> Tuple[np.ndarray, np.ndarray, int]:
    """The partial-D2 constraint multiset over one vertex class.

    For ``side="left"``: per (left v, right r) edge, one entry (v, u) for
    each left u in adj(r), self pairs masked inert. Row-contiguous in the
    colored class."""
    if side == "left":
        n, a_ptr, a_idx, b_ptr, b_idx = (bg.num_left, bg.l2r_ptr, bg.l2r_idx,
                                         bg.r2l_ptr, bg.r2l_idx)
    elif side == "right":
        n, a_ptr, a_idx, b_ptr, b_idx = (bg.num_right, bg.r2l_ptr, bg.r2l_idx,
                                         bg.l2r_ptr, bg.l2r_idx)
    else:
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    deg = np.diff(a_ptr).astype(np.int64)
    src = np.repeat(np.arange(n, dtype=np.int32), deg)
    back, counts = _expand_rows(b_ptr, b_idx, a_idx)
    fsrc = np.repeat(src, counts).astype(np.int32)
    fdst = back.astype(np.int32)
    self_pair = fsrc == fdst
    fsrc[self_pair] = n
    fdst[self_pair] = n
    return fsrc, fdst, fsrc.shape[0] - int(self_pair.sum())


def partial_square(bg: BipartiteGraph, side: str = "left") -> Graph:
    """The one-mode projection of ``bg`` onto ``side``: a host
    :class:`Graph` joining same-class vertices that share a neighbor.
    PD2 coloring of ``bg`` == distance-1 coloring of this graph."""
    n = bg.num_left if side == "left" else bg.num_right
    fsrc, fdst, _ = pd2_pairs(bg, side)
    keep = fsrc < n
    return Graph.from_edges(n, np.stack([fsrc[keep], fdst[keep]], axis=1))


# --------------------------------------------------------------------------
# DeviceGraph lowerings
# --------------------------------------------------------------------------
def _multiset_device_graph(num_vertices: int, fsrc: np.ndarray,
                           fdst: np.ndarray, live: int) -> DeviceGraph:
    """Wrap a constraint-pair multiset as an edges-layout DeviceGraph.

    ``max_degree`` is the max *multiset* row count — an over-bound on the
    true constraint degree (duplicates and masked self pairs only inflate
    it), so table backends sized from it can never drop a forbid."""
    row_count = np.bincount(fsrc[fsrc < num_vertices],
                            minlength=num_vertices)
    return DeviceGraph(
        num_vertices=num_vertices,
        num_directed_edges=live,
        src=jnp.asarray(fsrc),
        dst=jnp.asarray(fdst),
        max_degree=int(row_count.max()) if row_count.size else 0,
    )


def d2_device_graph(graph: Graph, *, strategy: str = "auto",
                    layout: Union[str, Sequence[str]] = "edges",
                    pad_edges_to: Optional[int] = None) -> DeviceGraph:
    """Lower ``graph`` to the distance-2 constraint DeviceGraph the engine
    colors. See the module docstring for the ``strategy`` trade-off."""
    strategy = _resolve_strategy(strategy, layout, pad_edges_to)
    if strategy == "square":
        return square(graph).to_device(layout=layout,
                                       pad_edges_to=pad_edges_to)
    return _multiset_device_graph(graph.num_vertices, *d2_pairs(graph))


def pd2_device_graph(bg: BipartiteGraph, *, side: str = "left",
                     strategy: str = "auto",
                     layout: Union[str, Sequence[str]] = "edges",
                     pad_edges_to: Optional[int] = None) -> DeviceGraph:
    """Lower one class of ``bg`` to its partial-D2 constraint DeviceGraph
    (vertices = the colored class)."""
    strategy = _resolve_strategy(strategy, layout, pad_edges_to)
    if strategy == "square":
        return partial_square(bg, side).to_device(layout=layout,
                                                  pad_edges_to=pad_edges_to)
    n = bg.num_left if side == "left" else bg.num_right
    return _multiset_device_graph(n, *pd2_pairs(bg, side))


def _resolve_strategy(strategy: str, layout: Union[str, Sequence[str]],
                      pad_edges_to: Optional[int] = None) -> str:
    """Pick/validate the lowering strategy. The wedge multiset carries no
    CSR/ELL geometry and its length is data-dependent, so CSR/ELL layouts
    and ``pad_edges_to`` force (under ``"auto"``) or require (explicitly)
    the square lowering — never silently dropped."""
    if strategy not in _STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"choose from {_STRATEGIES}")
    layouts = (layout,) if isinstance(layout, str) else tuple(layout)
    needs_square = (pad_edges_to is not None
                    or "ell" in layouts or "csr" in layouts)
    if strategy == "auto":
        return "square" if needs_square else "wedge"
    if strategy == "wedge" and needs_square:
        raise ValueError(
            "strategy='wedge' emits an edge multiset (duplicates, inert "
            "masks) with no CSR/ELL geometry or shape padding; use "
            f"strategy='square' for layout={layouts}, "
            f"pad_edges_to={pad_edges_to}")
    return strategy


# --------------------------------------------------------------------------
# the model= entry point the drivers thread through
# --------------------------------------------------------------------------
def as_constraint_graph(g, model: str = "d1", *, needs_ell: bool = False,
                        strategy: str = "auto",
                        side: str = "left") -> DeviceGraph:
    """Resolve a driver's ``(g, model=)`` arguments to the constraint
    DeviceGraph the engine actually colors.

    Accepted ``g`` per model:
      d1   DeviceGraph (used as-is) or host Graph (``to_device()``-ed);
      d2   host Graph — the two-hop expansion needs the host CSR;
      pd2  BipartiteGraph — ``side`` picks the colored class.

    ``needs_ell`` (set when the chosen mex backend requires the ELL
    layout) forces the ELL-capable lowering."""
    if model not in MODELS:
        raise ValueError(f"unknown coloring model {model!r}; "
                         f"choose from {MODELS}")
    layout = ("edges", "ell") if needs_ell else "edges"
    if isinstance(g, DeviceGraph):
        if model != "d1":
            raise ValueError(
                f"model={model!r} needs the host graph (two-hop expansion "
                "reads the host CSR): pass a Graph"
                + ("/BipartiteGraph" if model == "pd2" else "")
                + " instead of a DeviceGraph")
        return g
    if isinstance(g, BipartiteGraph):
        if model != "pd2":
            raise ValueError(
                f"BipartiteGraph only supports model='pd2' (got "
                f"model={model!r}); project it to a Graph first for "
                "d1/d2 semantics")
        return pd2_device_graph(g, side=side, strategy=strategy,
                                layout=layout)
    if not isinstance(g, Graph):
        raise TypeError(f"expected Graph/BipartiteGraph/DeviceGraph, "
                        f"got {type(g).__name__}")
    if model == "pd2":
        raise ValueError("model='pd2' needs a BipartiteGraph (which vertex "
                         "class would be colored?)")
    if model == "d1":
        return g.to_device(layout=layout)
    return d2_device_graph(g, strategy=strategy, layout=layout)


def constraint_host_graph(g, model: str = "d1", *,
                          side: str = "left") -> Graph:
    """Host-side counterpart of :func:`as_constraint_graph` for drivers
    that partition on host (``color_distributed``): returns the host
    constraint :class:`Graph` (always via the exact ``square`` lowering —
    the partitioner wants dedup'd CSR rows)."""
    if model not in MODELS:
        raise ValueError(f"unknown coloring model {model!r}; "
                         f"choose from {MODELS}")
    if isinstance(g, BipartiteGraph):
        if model != "pd2":
            raise ValueError(f"BipartiteGraph only supports model='pd2' "
                             f"(got model={model!r})")
        return partial_square(g, side)
    if not isinstance(g, Graph):
        raise TypeError(f"expected Graph/BipartiteGraph, "
                        f"got {type(g).__name__}")
    if model == "pd2":
        raise ValueError("model='pd2' needs a BipartiteGraph")
    return g if model == "d1" else square(g)
