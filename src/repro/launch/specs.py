"""ShapeDtypeStruct input stand-ins + sharding builders for every
(architecture x shape x mode) cell — weak-type-correct, shardable, zero
allocation. Modality frontends are stubs: whisper gets precomputed frame
embeddings, llama-vision gets pre-projected image tokens (per assignment).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import cache_spec as model_cache_spec
from ..models.config import ModelConfig, ShapeConfig
from ..parallel.sharding import Rules, spec_for_array
from ..models.params import is_axes_leaf


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract training/prefill batch for one cell."""
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encdec.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vlm.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return out


def batch_axes(cfg: ModelConfig) -> Dict[str, tuple]:
    out = {"tokens": ("batch", None), "labels": ("batch", None)}
    if cfg.family == "encdec":
        out["frames"] = ("batch", None, None)
    if cfg.family == "vlm":
        out["image_embeds"] = ("batch", None, None)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(caches abstract tree, caches axes, tokens abstract) for decode cells."""
    shapes, axes = model_cache_spec(cfg, shape.global_batch, shape.seq_len)
    tokens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    return shapes, axes, tokens


def tree_shardings(shape_tree, axes_tree, rules: Rules, mesh: Mesh):
    """NamedSharding tree from (ShapeDtypeStruct tree, logical-axes tree)."""
    def one(sds, axes):
        return NamedSharding(mesh, spec_for_array(tuple(sds.shape), axes, rules, mesh))
    return jax.tree.map(
        one, shape_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct) or is_axes_leaf(x))


def scalar_sharding(mesh: Mesh):
    return NamedSharding(mesh, P())
