"""Cross-PR perf trajectory: render how the committed pinned-scale
``BENCH_*.json`` baselines evolved over git history (ROADMAP item 5).

For each baseline file, walks every commit that touched it (oldest first),
reads the file AS OF that commit via ``git show``, and prints a per-row
``us_per_call`` trajectory plus a per-commit geometric-mean summary. The
working-tree version (if it differs from HEAD) is appended as the final
``worktree`` column, so a PR's effect is visible before it merges.

Numbers come from whatever machine produced each commit's baseline, so
the trajectory is indicative, not a controlled experiment — the geomean
line exists to make level shifts obvious, the per-row lines to attribute
them. The machine-invariant comparison lives in ``tools/bench_gate.py``.

Usage:
  python tools/bench_trend.py [FILES...]       # default: BENCH_*.json
  python tools/bench_trend.py --csv            # machine-readable
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import subprocess
import sys


def _git(*args: str) -> str:
    return subprocess.run(["git", *args], capture_output=True, text=True,
                          check=True).stdout


def extract_rows(payload: dict) -> dict:
    """name -> us_per_call from either committed-baseline schema: the
    ``benchmarks/run.py --json`` row list, or a ``roofline_round`` record
    (best per-round wall time of each path)."""
    if "rows" in payload:
        return {r["name"]: float(r["us_per_call"]) for r in payload["rows"]
                if float(r.get("us_per_call", 0.0)) > 0.0}
    if payload.get("kind") == "roofline_round":
        return {
            "roofline_round/three_pass":
                min(r["three_pass_us"] for r in payload["rounds"]),
            "roofline_round/fused":
                min(r["fused_us"] for r in payload["rounds"]),
        }
    return {}


def history(path: str):
    """[(short_rev, subject, rows_dict)] oldest→newest, + worktree tail."""
    revs = _git("log", "--reverse", "--format=%h %s", "--", path)
    out = []
    for line in revs.splitlines():
        rev, _, subject = line.partition(" ")
        try:
            blob = _git("show", f"{rev}:{path}")
        except subprocess.CalledProcessError:
            continue  # commit deleted the file
        rows = extract_rows(json.loads(blob))
        if rows:
            out.append((rev, subject[:48], rows))
    if os.path.exists(path):
        with open(path) as f:
            rows = extract_rows(json.load(f))
        if rows and (not out or rows != out[-1][2]):
            out.append(("worktree", "(uncommitted)", rows))
    return out


def geomean(values):
    vals = [v for v in values if v > 0]
    if not vals:
        return float("nan")
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def render(path: str, hist, csv: bool) -> None:
    if not hist:
        print(f"{path}: no history")
        return
    names = sorted(set().union(*(rows for _, _, rows in hist)))
    cols = [rev for rev, _, _ in hist]
    if csv:
        print(",".join(["file", "row"] + cols))
        for n in names:
            cells = [f"{rows.get(n, float('nan')):.1f}"
                     for _, _, rows in hist]
            print(",".join([path, n] + cells))
        return
    print(f"\n== {path} ==")
    for rev, subject, _ in hist:
        print(f"   {rev:>10s}  {subject}")
    w = max(len(n) for n in names)
    header = " ".join(f"{c:>12s}" for c in cols)
    print(f"{'row':<{w}s} {header}  (us_per_call)")
    for n in names:
        cells = " ".join(
            f"{rows[n]:>12.1f}" if n in rows else f"{'—':>12s}"
            for _, _, rows in hist)
        print(f"{n:<{w}s} {cells}")
    geo = " ".join(f"{geomean(rows.values()):>12.1f}" for _, _, rows in hist)
    print(f"{'geomean':<{w}s} {geo}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", default=None,
                    help="baseline JSON files (default: BENCH_*.json)")
    ap.add_argument("--csv", action="store_true",
                    help="machine-readable long-format output")
    args = ap.parse_args(argv)
    files = args.files or sorted(glob.glob("BENCH_*.json"))
    if not files:
        print("bench_trend: no BENCH_*.json baselines found")
        return 1
    for path in files:
        render(path, history(path), args.csv)
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:  # e.g. `| head` — not an error
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        sys.exit(0)
