"""Graph container + R-MAT generator tests (paper §4)."""
import numpy as np
import pytest

from repro.core import Graph, rmat, ordering


def test_from_edges_dedup_selfloop():
    edges = np.array([[0, 1], [1, 0], [0, 1], [2, 2], [1, 3]])
    g = Graph.from_edges(4, edges)
    assert g.num_edges == 2            # (0,1) and (1,3); self loop dropped
    assert g.max_degree() == 2
    src, dst = g.directed_edges()
    assert len(src) == 4
    assert not np.any(src == dst)


def test_csr_consistency():
    g = rmat.paper_graph("RMAT-G", scale=8, seed=3)
    src, dst = g.directed_edges()
    # symmetric: every (u,v) has (v,u)
    fwd = set(zip(src.tolist(), dst.tolist()))
    assert all((v, u) in fwd for (u, v) in fwd)
    assert np.all(np.diff(g.row_ptr) >= 0)
    assert g.row_ptr[-1] == len(dst)


@pytest.mark.parametrize("name", ["RMAT-ER", "RMAT-G", "RMAT-B"])
def test_rmat_structure_ordering(name):
    """Paper Table 2: max degree and variance increase ER -> G -> B."""
    g = rmat.paper_graph(name, scale=11, seed=0)
    s = g.stats()
    assert s["num_vertices"] == 2048
    # dup/self-loop removal shrinks |E| (paper §4.1); hostile graphs lose
    # more at small scale (dense subcommunities -> more duplicates)
    assert 0.75 * 8 * 2048 <= s["num_edges"] <= 8 * 2048


def test_rmat_hostility_ordering():
    stats = {n: rmat.paper_graph(n, scale=11, seed=0).stats()
             for n in ["RMAT-ER", "RMAT-G", "RMAT-B"]}
    assert stats["RMAT-ER"]["max_degree"] < stats["RMAT-G"]["max_degree"] \
        < stats["RMAT-B"]["max_degree"]
    assert stats["RMAT-ER"]["degree_variance"] < stats["RMAT-G"]["degree_variance"] \
        < stats["RMAT-B"]["degree_variance"]


def test_ell_padding():
    g = rmat.paper_graph("RMAT-ER", scale=7, seed=1)
    ell, deg = g.to_ell()
    assert ell.shape[0] == g.num_vertices
    for v in range(g.num_vertices):
        nbrs = set(g.col_idx[g.row_ptr[v]:g.row_ptr[v + 1]].tolist())
        got = set(ell[v][ell[v] < g.num_vertices].tolist())
        assert got == nbrs


def test_relabel_preserves_structure():
    g = rmat.paper_graph("RMAT-G", scale=8, seed=2)
    perm = np.random.default_rng(0).permutation(g.num_vertices).astype(np.int64)
    g2 = g.relabel(perm)
    assert g2.num_edges == g.num_edges
    assert g2.max_degree() == g.max_degree()


def test_orderings_are_permutations():
    g = rmat.paper_graph("RMAT-B", scale=8, seed=2)
    for name, fn in ordering.ORDERINGS.items():
        o = fn(g, seed=1)
        assert sorted(o.tolist()) == list(range(g.num_vertices)), name


def test_smallest_last_degeneracy():
    # smallest-last ordering: max back-degree == degeneracy <= max degree
    g = rmat.paper_graph("RMAT-B", scale=8, seed=5)
    o = ordering.smallest_degree_last(g)
    g2 = ordering.apply(g, o)
    from repro.core import greedy_color
    c1 = greedy_color(g2)
    assert c1.max() <= g.max_degree() + 1
