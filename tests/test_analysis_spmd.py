"""SPMD verifier fixtures: every COLL/WIRE/HALO code seeded as a minimal
mesh program (or a mutation of the shipping one) and asserted to produce
its exact finding code, plus clean-run pins on all shipping wire tiers.

The toy fixtures build one-device ``shard_map`` programs by hand so each
pass sees exactly one structural feature; the mutation fixtures
monkeypatch the real distributed driver so a *plausible* refactor (an
extra collective in one cond branch, a widened wire codec) is caught by
``compile_plan(verify="error")`` before anything compiles.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis import (AnalysisError, SpmdGeometry, analyze_spec,
                            check_collectives, check_halo_exactness,
                            check_wire_cost, verify_plan)
from repro.core.api import ColoringSpec, PlanShape, compile_plan
from repro.jax_compat import shard_map

sds = jax.ShapeDtypeStruct
SHAPE = PlanShape(num_vertices=48, padded_edges=512, max_degree=8)


def codes(findings):
    return [f.code for f in findings]


def mesh_jaxpr(fn, *avals, n_in=None):
    """Trace ``fn`` through a one-device shard_map (every aval sharded
    over the single "x" axis)."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    n = len(avals) if n_in is None else n_in
    sm = shard_map(fn, mesh=mesh, in_specs=(P("x"),) * n, out_specs=P("x"))
    return jax.make_jaxpr(sm)(*avals)


def toy_geometry(**kw):
    base = dict(num_devices=1, verts_local=8, edges_local=64,
                boundary_cap=2, wire="boundary", wire_colors=9,
                max_colors=9, frontier_cap_v=0, frontier_cap_e=0,
                axis_names=("x",))
    base.update(kw)
    return SpmdGeometry(**base)


X8 = sds((8,), jnp.int32)


# --------------------------------------------------------------------------
# collective safety: one toy program per COLL code
# --------------------------------------------------------------------------
class TestCollectives:
    def test_branch_mismatch_under_varying_pred_is_coll201(self):
        def fn(x):
            return lax.cond(x[0] > 0,                 # shard-varying
                            lambda v: lax.psum(v, "x"),
                            lambda v: v * 2, x)
        got = codes(check_collectives(mesh_jaxpr(fn, X8)))
        assert "COLL201" in got and "COLL103" not in got

    def test_identical_branch_sequences_is_coll103(self):
        def fn(x):
            return lax.cond(x[0] > 0,
                            lambda v: lax.psum(v, "x") + 1,
                            lambda v: lax.psum(v, "x") * 2, x)
        got = codes(check_collectives(mesh_jaxpr(fn, X8)))
        assert "COLL103" in got and "COLL201" not in got

    def test_psum_derived_uniform_pred_is_coll102(self):
        def fn(x):
            total = lax.psum(x.sum(), "x")            # replicated vote
            return lax.cond(total > 0,
                            lambda v: lax.psum(v, "x"),
                            lambda v: v * 2, x)
        got = codes(check_collectives(mesh_jaxpr(fn, X8)))
        assert "COLL102" in got
        assert not {"COLL103", "COLL201"} & set(got)

    def test_varying_loop_exit_with_collectives_is_coll202(self):
        def fn(x):
            def body(c):
                v, i = c
                return lax.psum(v, "x") * 0 + v, i + 1
            v, _ = lax.while_loop(lambda c: c[0][0] > 0, body,
                                  (x, jnp.int32(0)))
            return v
        got = codes(check_collectives(mesh_jaxpr(fn, X8)))
        assert "COLL202" in got

    def test_uniform_loop_exit_is_not_coll202(self):
        def fn(x):
            def body(c):
                v, i = c
                return lax.psum(v, "x") * 0 + v, i + 1
            v, _ = lax.while_loop(lambda c: c[1] < 3, body,
                                  (x, jnp.int32(0)))
            return v
        assert "COLL202" not in codes(check_collectives(mesh_jaxpr(fn, X8)))

    def test_unread_exchange_patched_carrier_is_coll203(self):
        def fn(x):
            def body(c):
                s, i = c
                g = lax.all_gather(s[:2], "x", tiled=True)
                return jnp.concatenate([g, s[2:]]), i + 1   # never read
            s, _ = lax.while_loop(lambda c: c[1] < 3, body,
                                  (x, jnp.int32(0)))
            return s
        assert "COLL203" in codes(check_collectives(mesh_jaxpr(fn, X8)))

    def test_in_round_read_clears_coll203(self):
        def fn(x):
            def body(c):
                s, i = c
                g = lax.all_gather(s[:2], "x", tiled=True)
                s2 = jnp.concatenate([g, s[2:]])
                return s2, i + s2[0] * 0                    # read in-round
            s, _ = lax.while_loop(lambda c: c[1] < 3, body,
                                  (x, jnp.int32(0)))
            return s
        assert "COLL203" not in codes(check_collectives(mesh_jaxpr(fn, X8)))


# --------------------------------------------------------------------------
# wire cost: a tiny exchange program per WIRE code (geometry: D=1, Vl=8,
# Bl=2, C=9 -> halo = 1 packed word = 4B/round, setup = 8B)
# --------------------------------------------------------------------------
def _wire_prog(round_width=1, setup_width=2, extra_gather=False,
               wide_psum=False):
    def fn(s, bids):
        setup = lax.all_gather(bids[:setup_width], "x", tiled=True)

        def body(c):
            v, i = c
            w = lax.all_gather(v[:round_width], "x", tiled=True)
            v = v + w.sum() + setup.sum() * 0
            if extra_gather:
                v = v + lax.all_gather(v[:1], "x", tiled=True).sum()
            if wide_psum:
                v = v + lax.psum(v[:4], "x").sum()
            vote = lax.psum(i, "x")                   # scalar control plane
            return v, i + vote * 0 + 1
        v, _ = lax.while_loop(lambda c: c[1] < 2, body, (s, jnp.int32(0)))
        return v
    return fn


class TestWireCost:
    def test_exact_tiers_are_wire101_only(self):
        got = codes(check_wire_cost(
            mesh_jaxpr(_wire_prog(), X8, X8), toy_geometry()))
        assert got == ["WIRE101"]

    def test_widened_round_payload_is_wire201(self):
        got = codes(check_wire_cost(
            mesh_jaxpr(_wire_prog(round_width=2), X8, X8), toy_geometry()))
        assert "WIRE201" in got

    def test_extra_round_gather_is_wire202(self):
        got = codes(check_wire_cost(
            mesh_jaxpr(_wire_prog(extra_gather=True), X8, X8),
            toy_geometry()))
        assert "WIRE202" in got and "WIRE201" not in got

    def test_nonscalar_psum_is_wire202(self):
        got = codes(check_wire_cost(
            mesh_jaxpr(_wire_prog(wide_psum=True), X8, X8), toy_geometry()))
        assert "WIRE202" in got

    def test_oversized_setup_exchange_is_wire203(self):
        got = codes(check_wire_cost(
            mesh_jaxpr(_wire_prog(setup_width=4), X8, X8), toy_geometry()))
        assert "WIRE203" in got


# --------------------------------------------------------------------------
# halo exactness: payload-width and read-side sinks (Vl = Vp = 8, D = 1)
# --------------------------------------------------------------------------
def _round_loop(body_fn):
    def fn(x):
        s, _ = lax.while_loop(lambda c: c[1] < 2, body_fn,
                              (x, jnp.int32(0)))
        return s
    return fn


class TestHaloExactness:
    def test_full_local_state_on_wire_is_halo201(self):
        def body(c):
            s, i = c
            g = lax.all_gather(s, "x", tiled=True)    # 8 entries >= Vl
            return s + g[:8] * 0, i + 1
        got = codes(check_halo_exactness(
            mesh_jaxpr(_round_loop(body), X8), toy_geometry()))
        assert got == ["HALO201"]

    def test_raw_payload_into_conflict_compare_is_halo202(self):
        def body(c):
            s, i = c
            g = lax.all_gather(s[:2], "x", tiled=True)
            conflict = (g == s[:2]).sum()             # raw payload compared
            return s + conflict, i + 1
        got = codes(check_halo_exactness(
            mesh_jaxpr(_round_loop(body), X8), toy_geometry()))
        assert got == ["HALO202"]

    def test_raw_payload_into_foreign_table_is_halo202(self):
        def body(c):
            s, i = c
            g = lax.all_gather(s[:2], "x", tiled=True)
            tbl = jnp.zeros((4,), jnp.int32)          # not the [Vp] view
            tbl = tbl.at[g % 4].set(1, mode="drop")
            return s + tbl.sum(), i + 1
        got = codes(check_halo_exactness(
            mesh_jaxpr(_round_loop(body), X8), toy_geometry()))
        assert "HALO202" in got

    def test_patch_through_vp_snapshot_proves_halo101(self):
        def body(c):
            s, i = c
            g = lax.all_gather(s[:2], "x", tiled=True)
            snap = s.at[jnp.arange(2)].set(g, mode="drop")  # the [Vp] patch
            conflict = (snap[:2] == s[:2]).sum()      # patched view only
            return snap + conflict * 0, i + 1
        got = codes(check_halo_exactness(
            mesh_jaxpr(_round_loop(body), X8), toy_geometry()))
        assert got == ["HALO101"]

    def test_full_wire_is_exempt(self):
        def body(c):
            s, i = c
            g = lax.all_gather(s, "x", tiled=True)
            return s + g[:8] * 0, i + 1
        got = check_halo_exactness(
            mesh_jaxpr(_round_loop(body), X8),
            toy_geometry(wire="full", boundary_cap=0))
        assert got == []


# --------------------------------------------------------------------------
# mutation fixtures on the SHIPPING program: a plausible refactor must be
# caught by compile_plan(verify="error") before anything compiles
# --------------------------------------------------------------------------
class TestShippingMutations:
    def test_branch_local_collective_rejected_as_coll201(self, monkeypatch):
        # seed the issue's acceptance mutation: a psum inside slab_solve
        # only — the solve cond's branches then issue mismatched collective
        # sequences under the shard-varying fits_solve predicate
        import repro.core.distributed as dist
        real = dist.frontier_sweep

        def mutant(*args, **kw):
            out = real(*args, **kw)
            leaf = jax.tree_util.tree_leaves(out)[0]
            vote = lax.psum(jnp.ravel(leaf)[0].astype(jnp.int32), "x")
            return jax.tree_util.tree_map(
                lambda a: jnp.where(vote < 0, a, a), out)
        monkeypatch.setattr(dist, "frontier_sweep", mutant)
        with pytest.raises(AnalysisError, match="COLL201"):
            compile_plan(ColoringSpec(strategy="distributed"), SHAPE,
                         verify="error")

    def test_widened_wire_codec_rejected_as_wire201(self, monkeypatch):
        # widen the halo codec to one entry per word without updating the
        # documented closed form: traced bytes-on-wire drift -> WIRE201
        import repro.parallel.compression as comp
        monkeypatch.setattr(comp, "halo_bits", lambda bound: 32)
        spec = ColoringSpec(strategy="distributed", wire="boundary")
        got = codes(analyze_spec(spec, SHAPE))
        assert "WIRE201" in got
        with pytest.raises(AnalysisError, match="WIRE201"):
            verify_plan(spec, SHAPE, mode="error")


# --------------------------------------------------------------------------
# clean-run pins: every shipping wire tier verifies clean and carries the
# three info-grade proofs
# --------------------------------------------------------------------------
class TestShippingClean:
    @pytest.mark.parametrize("wire", ["boundary", "full", "auto"])
    def test_wire_tiers_verify_clean(self, wire):
        verify_plan(ColoringSpec(strategy="distributed", wire=wire), SHAPE,
                    mode="error")

    def test_partition_2d_verifies_clean(self):
        verify_plan(ColoringSpec(strategy="distributed", partition="2d"),
                    SHAPE, mode="error")

    def test_frontier_off_verifies_clean(self):
        verify_plan(ColoringSpec(strategy="distributed", frontier="off"),
                    SHAPE, mode="error")

    def test_boundary_plan_carries_all_three_proofs(self):
        got = codes(analyze_spec(
            ColoringSpec(strategy="distributed", wire="boundary"), SHAPE))
        # COLL102: wire-selection cond proven uniform; WIRE101: the cost
        # table; HALO101: the exactness proof
        assert {"COLL101", "COLL102", "WIRE101", "HALO101"} <= set(got)
        assert not any(c.startswith(("COLL2", "WIRE2", "HALO2"))
                       for c in got)

    def test_full_plan_skips_halo_and_prices_spill(self):
        got = codes(analyze_spec(
            ColoringSpec(strategy="distributed", wire="full"), SHAPE))
        assert "WIRE101" in got
        assert not any(c.startswith("HALO") for c in got)
