"""Graph containers for the coloring engine.

Three representations:

* :class:`Graph` — host-side (numpy) CSR + directed edge list. Construction,
  dedup, symmetrization, stats live here.
* :class:`BipartiteGraph` — host-side two-sided CSR (left->right and
  right->left). The input structure of *partial distance-2* coloring
  (``model="pd2"``): Jacobian compression colors one vertex class of the
  row/column bipartite graph (Taş et al., arXiv:1701.02628). Lowered into
  the engine's one-sided constraint graph by ``repro.core.distance2``.
* :class:`DeviceGraph` — fixed-shape jnp arrays consumed by the JAX coloring
  algorithms. Layout-aware: always carries the directed edge list, and via
  ``Graph.to_device(layout=...)`` optionally the CSR arrays
  (``row_ptr``/``col_idx`` on device) and/or the ELL geometry (per-edge
  slot map + static width) the Pallas first-fit path scatters through — so
  mex backends pick their layout from the graph instead of callers
  hand-threading ``to_ell()`` output around. Registered as a jax pytree:
  the coloring drivers take it as a traced argument directly.
* :class:`ShardLayout` — host-side (numpy) shard-local CSR + halo layout for
  the distributed strategy: per-device row-contiguous edge slabs plus the
  interior/boundary classification and the static boundary->halo index maps
  the boundary-only wire gathers/scatters through. Built by
  ``repro.core.distributed.partition_graph``.

Conventions
-----------
* Vertices are ``int32`` ids in ``[0, V)``.
* The *directed* edge list contains both ``(u, v)`` and ``(v, u)`` for every
  undirected edge, so per-vertex reductions over ``src`` see every neighbor.
* Colors are positive ints; ``0`` means "uncolored".
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

_LAYOUTS = ("edges", "csr", "ell")


def pad_bucket(n: int, *, min_bucket: int = 256) -> int:
    """Round ``n`` up to the shape-bucket grid: multiples of ``2^(k-3)``
    within ``(2^(k-1), 2^k]`` (eighth-of-an-octave steps), floored at
    ``min_bucket`` for positive ``n``. ``n <= 0`` returns 0 — a degenerate
    (vertexless/edgeless) graph must not allocate a phantom slab.

    Padding waste stays at most 25% (typically a few percent) while the
    number of distinct shapes per size decade stays in the tens — the
    quantization that makes :class:`repro.core.api.ColoringPlan`'s
    "same bucket => zero retrace" achievable for real graph families, where
    raw edge counts almost never repeat exactly."""
    n = int(n)
    if n <= 0:
        return 0
    if n <= min_bucket:
        return int(min_bucket)
    k = (n - 1).bit_length()
    step = 1 << max(k - 3, 0)
    return -(-n // step) * step


@dataclasses.dataclass(frozen=True)
class Graph:
    """Host-side undirected graph in CSR form (numpy)."""

    num_vertices: int
    row_ptr: np.ndarray  # [V+1] int64
    col_idx: np.ndarray  # [2E]  int32, neighbors sorted per row

    # ---------------------------------------------------------- construction
    @staticmethod
    def from_edges(num_vertices: int, edges: np.ndarray) -> "Graph":
        """Build from an [M, 2] array of (possibly duplicated, possibly
        self-looped, possibly one-directional) edges — mirrors the paper's
        post-processing of R-MAT output (dup/self-loop removal).

        Dedup is a two-key ``np.lexsort`` over int32 endpoint arrays (not a
        dense ``src * V + dst`` linear index): no int64 key materialization,
        which cuts peak host memory on the scale >= 24 R-MAT graphs."""
        edges = np.asarray(edges)
        if edges.size == 0:
            return Graph(num_vertices,
                         np.zeros(num_vertices + 1, np.int64),
                         np.zeros(0, np.int32))
        u = edges[:, 0].astype(np.int32)
        v = edges[:, 1].astype(np.int32)
        keep = u != v  # drop self loops
        u, v = u[keep], v[keep]
        # symmetrize, dedup via lexicographic sort on (src, dst)
        src = np.concatenate([u, v])
        dst = np.concatenate([v, u])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        if src.size:
            first = np.empty(src.shape, np.bool_)
            first[0] = True
            np.logical_or(src[1:] != src[:-1], dst[1:] != dst[:-1],
                          out=first[1:])
            src, dst = src[first], dst[first]
        counts = np.bincount(src, minlength=num_vertices).astype(np.int64)
        row_ptr = np.zeros(num_vertices + 1, np.int64)
        np.cumsum(counts, out=row_ptr[1:])
        return Graph(num_vertices, row_ptr, dst.astype(np.int32))

    # ---------------------------------------------------------------- stats
    @property
    def num_directed_edges(self) -> int:
        return int(self.col_idx.shape[0])

    @property
    def num_edges(self) -> int:
        return self.num_directed_edges // 2

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr).astype(np.int64)

    def max_degree(self) -> int:
        d = self.degrees()
        return int(d.max()) if d.size else 0

    def degree_variance(self) -> float:
        d = self.degrees()
        return float(d.var()) if d.size else 0.0

    def isolated_fraction(self) -> float:
        d = self.degrees()
        return float((d == 0).mean()) if d.size else 0.0

    def stats(self) -> dict:
        """The columns of the paper's Table 2 / Table 4."""
        return {
            "num_vertices": self.num_vertices,
            "num_edges": self.num_edges,
            "avg_degree": (2.0 * self.num_edges / max(1, self.num_vertices)),
            "max_degree": self.max_degree(),
            "degree_variance": self.degree_variance(),
            "pct_isolated": 100.0 * self.isolated_fraction(),
        }

    # ------------------------------------------------------------ transforms
    def directed_edges(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) with both directions present; src is sorted."""
        src = np.repeat(
            np.arange(self.num_vertices, dtype=np.int32),
            np.diff(self.row_ptr).astype(np.int64),
        )
        return src, self.col_idx.astype(np.int32)

    def undirected_edges(self) -> np.ndarray:
        """The canonical undirected edge set: [E, 2] int32 with u < v, in
        lexicographic order (CSR order restricted to the lower direction)."""
        src, dst = self.directed_edges()
        half = src < dst
        return np.stack([src[half], dst[half]], 1)

    def _edge_keys(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """Dense int64 key of canonical (u < v) pairs — u*V+v stays below
        2^63 for any int32 vertex count, so no overflow."""
        return u.astype(np.int64) * np.int64(self.num_vertices) \
            + v.astype(np.int64)

    @staticmethod
    def _member_mask(sorted_keys: np.ndarray, keys: np.ndarray) -> np.ndarray:
        """[M] bool: which ``keys`` occur in ``sorted_keys`` (one
        searchsorted probe — the shared membership primitive of
        :meth:`has_edges` and :meth:`delta_info`)."""
        pos = np.searchsorted(sorted_keys, keys)
        hit = np.zeros(keys.shape[0], np.bool_)
        ok = pos < sorted_keys.shape[0]
        hit[ok] = sorted_keys[pos[ok]] == keys[ok]
        return hit

    @staticmethod
    def _canonical_pairs(edges, num_vertices: int) -> Tuple[np.ndarray, np.ndarray]:
        """Normalize an [M, 2] endpoint array: orient u < v, drop self
        loops, reject out-of-range ids. Duplicates are kept (callers dedup
        where it matters)."""
        edges = np.asarray(edges)
        if edges.size == 0:
            z = np.zeros(0, np.int32)
            return z, z.copy()
        edges = edges.reshape(-1, 2)
        a = edges[:, 0].astype(np.int64)
        b = edges[:, 1].astype(np.int64)
        if a.size and (min(a.min(), b.min()) < 0
                       or max(a.max(), b.max()) >= num_vertices):
            raise ValueError("delta edge endpoint out of range "
                             f"[0, {num_vertices})")
        u = np.minimum(a, b)
        v = np.maximum(a, b)
        keep = u != v
        return u[keep].astype(np.int32), v[keep].astype(np.int32)

    def has_edges(self, edges) -> np.ndarray:
        """[M] bool membership mask for candidate undirected edges ([M, 2]
        endpoints, either orientation; self loops are never present)."""
        u, v = self._canonical_pairs(edges, self.num_vertices)
        base = self.undirected_edges()
        base_keys = self._edge_keys(base[:, 0], base[:, 1])  # sorted (CSR)
        hit = self._member_mask(base_keys, self._edge_keys(u, v))
        # re-expand to the caller's (possibly self-looped) row count
        edges = np.asarray(edges)
        if edges.size == 0:
            return np.zeros(0, np.bool_)
        edges = edges.reshape(-1, 2)
        out = np.zeros(edges.shape[0], np.bool_)
        out[edges[:, 0] != edges[:, 1]] = hit
        return out

    def delta_info(self, inserts=None, deletes=None
                   ) -> Tuple["Graph", np.ndarray, int]:
        """Apply an undirected edge delta and report what changed:
        ``(new_graph, added_pairs, num_deleted)`` where ``added_pairs``
        is the [M, 2] canonical (u < v) set of *genuinely new* edges —
        absent before, present after — and ``num_deleted`` the count of
        genuinely removed ones.

        Delta semantics are idempotent set operations: duplicate rows,
        self loops, inserts of present edges and deletes of absent edges
        are all no-ops; an edge appearing in both lists ends PRESENT
        (deletes apply first, then inserts). The vertex set is fixed —
        streaming updates keep every shape envelope keyed on |V| intact.
        One O(E) pass over the current edge set serves the membership
        check, the delete filter and the rebuild (the streaming layer's
        per-batch host cost)."""
        V = self.num_vertices
        base = self.undirected_edges()
        base_keys = self._edge_keys(base[:, 0], base[:, 1])  # sorted (CSR)

        ins_pairs = np.zeros((0, 2), np.int32)
        ins_keys = np.zeros(0, np.int64)
        if inserts is not None:
            iu, iv = self._canonical_pairs(inserts, V)
            if iu.size:
                ins_pairs = np.unique(np.stack([iu, iv], 1), axis=0)
                ins_keys = self._edge_keys(ins_pairs[:, 0], ins_pairs[:, 1])

        keep = np.ones(base_keys.shape[0], np.bool_)
        if deletes is not None:
            du, dv = self._canonical_pairs(deletes, V)
            if du.size:
                del_keys = self._edge_keys(du, dv)
                if ins_keys.size:
                    # deletes first, then inserts: an edge in both lists
                    # ends present (and is never "new")
                    del_keys = del_keys[~np.isin(del_keys, ins_keys)]
                keep &= ~np.isin(base_keys, del_keys)

        new_pairs = ins_pairs
        if ins_keys.size:
            new_pairs = ins_pairs[~self._member_mask(base_keys, ins_keys)]
        new_graph = Graph.from_edges(
            V, np.concatenate([base[keep], new_pairs]))
        return new_graph, new_pairs, int((~keep).sum())

    def apply_delta(self, inserts=None, deletes=None) -> "Graph":
        """A new :class:`Graph` with ``inserts`` added and ``deletes``
        removed — :meth:`delta_info`'s graph, when the change report is
        not needed (same idempotent set semantics)."""
        return self.delta_info(inserts, deletes)[0]

    def relabel(self, perm: np.ndarray) -> "Graph":
        """Relabel vertices: new id of old vertex i is ``perm[i]``."""
        src, dst = self.directed_edges()
        new_src = perm[src].astype(np.int64)
        new_dst = perm[dst].astype(np.int64)
        half = new_src < new_dst
        return Graph.from_edges(
            self.num_vertices, np.stack([new_src[half], new_dst[half]], 1)
        )

    def to_device(self, *, layout: Union[str, Sequence[str]] = "edges",
                  pad_edges_to: Optional[int] = None,
                  ell_width: Optional[int] = None) -> "DeviceGraph":
        """Move the graph on device in the requested layout(s).

        layout: ``"edges"`` (directed edge list — always present),
            ``"csr"`` (adds ``row_ptr``/``col_idx`` device arrays), ``"ell"``
            (adds the ELL geometry — the per-edge slot map + static slab
            width — that the ``ell_pallas`` mex backend scatters through;
            the dense neighbor slab itself stays host-side via
            :meth:`to_ell`, since the engine rebuilds color slabs per sweep
            and never reads neighbor ids from device), or any sequence of
            these. Backends pick what they need from the result.
        ell_width: optional ELL width override (default: max degree; a
            smaller width truncates rows and is only safe for callers that
            do not need exact neighborhoods).
        """
        layouts = (layout,) if isinstance(layout, str) else tuple(layout)
        unknown = set(layouts) - set(_LAYOUTS)
        if unknown:
            raise ValueError(f"unknown layout(s) {sorted(unknown)}; "
                             f"choose from {_LAYOUTS}")
        src, dst = self.directed_edges()
        e = src.shape[0]
        pad = (pad_edges_to or e) - e
        if pad < 0:
            raise ValueError(f"pad_edges_to={pad_edges_to} < num edges {e}")

        # incident-edge auxiliary (every layout): [V+1] int32 row pointers
        # into the (row-contiguous) directed edge list — one gather is all
        # the frontier layer needs to compact an active vertex set with its
        # incident constraint edges (repro.core.frontier.compact_frontier)
        inc_ptr_dev = None
        if self.num_directed_edges <= np.iinfo(np.int32).max:
            inc_ptr_dev = jnp.asarray(self.row_ptr.astype(np.int32))

        row_ptr_dev = col_idx_dev = slot_dev = None
        width = 0
        if "csr" in layouts:
            # device row_ptr is int32 (int64 would silently downcast under
            # default jax anyway); guard the 2E < 2^31 assumption explicitly
            if self.num_directed_edges > np.iinfo(np.int32).max:
                raise ValueError("csr device layout needs 2E < 2^31; "
                                 f"got {self.num_directed_edges} edges")
            row_ptr_dev = jnp.asarray(self.row_ptr.astype(np.int32))
            col_idx_dev = jnp.asarray(self.col_idx)
        if "ell" in layouts:
            width = max(1, int(ell_width if ell_width is not None
                               else self.max_degree()))
            # slot of each edge within its row; out-of-width and padding
            # edges get ``width`` so ELL scatters drop them (mode="drop")
            pos = np.arange(e, dtype=np.int64) - self.row_ptr[src]
            slot = np.minimum(pos, width).astype(np.int32)
            if pad:
                slot = np.concatenate([slot, np.full(pad, width, np.int32)])
            slot_dev = jnp.asarray(slot)

        if pad:
            # padding edges point at a phantom vertex V with src=V so they are
            # inert in segment reductions over [0, V)
            src = np.concatenate([src, np.full(pad, self.num_vertices, np.int32)])
            dst = np.concatenate([dst, np.full(pad, self.num_vertices, np.int32)])
        return DeviceGraph(
            num_vertices=self.num_vertices,
            num_directed_edges=e,
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            max_degree=self.max_degree(),
            row_ptr=row_ptr_dev,
            col_idx=col_idx_dev,
            ell_slot=slot_dev,
            ell_width=width,
            inc_ptr=inc_ptr_dev,
        )

    def to_ell(self, max_degree: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Padded ELL adjacency: ([V, D] int32 neighbor ids, [V] degrees).

        Pad slots hold ``V`` (phantom vertex). Used by the Pallas firstfit
        path, which wants a dense regular slab.
        """
        deg = self.degrees()
        d_max = int(max_degree if max_degree is not None else (deg.max() if deg.size else 0))
        ell = np.full((self.num_vertices, max(1, d_max)), self.num_vertices, np.int32)
        src, dst = self.directed_edges()
        # position of each edge within its row
        pos = np.arange(src.shape[0], dtype=np.int64) - self.row_ptr[src]
        ok = pos < d_max
        ell[src[ok], pos[ok]] = dst[ok]
        return ell, deg.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class BipartiteGraph:
    """Host-side bipartite graph: ``num_left`` x ``num_right`` vertices with
    edges only across the classes, stored as CSR in both directions.

    This is the structure partial distance-2 coloring runs on: coloring the
    *left* class so that no two left vertices sharing a right neighbor get
    the same color (equivalently: distance-1 coloring of the left one-mode
    projection). ``repro.core.distance2.pd2_device_graph`` lowers it into
    the engine's edge space; :func:`repro.core.greedy_ref.greedy_color_pd2`
    is the serial oracle.
    """

    num_left: int
    num_right: int
    l2r_ptr: np.ndarray  # [L+1] int64; row r of left vertex v
    l2r_idx: np.ndarray  # [E]   int32 right ids, sorted per row
    r2l_ptr: np.ndarray  # [R+1] int64
    r2l_idx: np.ndarray  # [E]   int32 left ids, sorted per row

    @staticmethod
    def from_edges(num_left: int, num_right: int,
                   edges: np.ndarray) -> "BipartiteGraph":
        """Build from an [M, 2] array of (left, right) pairs; duplicates are
        dropped (no self-loop concept: the classes are disjoint)."""
        edges = np.asarray(edges)
        if edges.size == 0:
            lv = np.zeros(0, np.int32)
            rv = np.zeros(0, np.int32)
        else:
            lv = edges[:, 0].astype(np.int32)
            rv = edges[:, 1].astype(np.int32)
        if lv.size and (lv.min() < 0 or lv.max() >= num_left
                        or rv.min() < 0 or rv.max() >= num_right):
            raise ValueError("bipartite edge endpoint out of range")

        def _csr(src, dst, n_src):
            order = np.lexsort((dst, src))
            s, d = src[order], dst[order]
            if s.size:
                first = np.empty(s.shape, np.bool_)
                first[0] = True
                np.logical_or(s[1:] != s[:-1], d[1:] != d[:-1], out=first[1:])
                s, d = s[first], d[first]
            ptr = np.zeros(n_src + 1, np.int64)
            np.cumsum(np.bincount(s, minlength=n_src), out=ptr[1:])
            return ptr, d.astype(np.int32)

        l2r_ptr, l2r_idx = _csr(lv, rv, num_left)
        r2l_ptr, r2l_idx = _csr(rv, lv, num_right)
        return BipartiteGraph(num_left, num_right,
                              l2r_ptr, l2r_idx, r2l_ptr, r2l_idx)

    # ---------------------------------------------------------------- stats
    @property
    def num_edges(self) -> int:
        return int(self.l2r_idx.shape[0])

    def left_degrees(self) -> np.ndarray:
        return np.diff(self.l2r_ptr).astype(np.int64)

    def right_degrees(self) -> np.ndarray:
        return np.diff(self.r2l_ptr).astype(np.int64)

    def stats(self) -> dict:
        ld, rd = self.left_degrees(), self.right_degrees()
        return {
            "num_left": self.num_left,
            "num_right": self.num_right,
            "num_edges": self.num_edges,
            "max_left_degree": int(ld.max()) if ld.size else 0,
            "max_right_degree": int(rd.max()) if rd.size else 0,
        }


@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """Fixed-shape device arrays in one or more layouts (a jax pytree).

    The directed edge list (``src``/``dst``) is always present; CSR and ELL
    layouts are optional and requested via ``Graph.to_device(layout=...)``.
    ``max_degree`` rides along as static metadata — it is the color bound
    the ``bitmap`` and ``ell_pallas`` mex backends size themselves from;
    ``-1`` means unknown (hand-built graphs), which those backends reject
    rather than silently under-sizing their tables.
    """

    num_vertices: int
    num_directed_edges: int
    src: jnp.ndarray  # [E2p] int32 in [0, V]; V = padding
    dst: jnp.ndarray  # [E2p] int32 in [0, V]
    max_degree: int = -1
    row_ptr: Optional[jnp.ndarray] = None   # [V+1] int32 (layout="csr")
    col_idx: Optional[jnp.ndarray] = None   # [2E]  int32 (layout="csr")
    ell_slot: Optional[jnp.ndarray] = None  # [E2p] int32 (layout="ell")
    ell_width: int = 0                      # static slab width (layout="ell")
    inc_ptr: Optional[jnp.ndarray] = None   # [V+1] int32 incident-edge row
    # pointers into src/dst (attached by to_device under EVERY layout; its
    # presence asserts the edge list is row-contiguous — the frontier
    # layer's compaction invariant). Hand-built edge lists (e.g. the wedge
    # multisets) leave it None, which disables the frontier path.

    @property
    def padded_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def has_csr(self) -> bool:
        return self.row_ptr is not None

    @property
    def has_ell(self) -> bool:
        return self.ell_slot is not None

    @property
    def has_frontier(self) -> bool:
        """True when the incident-edge auxiliary is present, i.e. the
        frontier execution layer can compact active sets on this graph."""
        return self.inc_ptr is not None


def _devicegraph_flatten(g: DeviceGraph):
    children = (g.src, g.dst, g.row_ptr, g.col_idx, g.ell_slot, g.inc_ptr)
    aux = (g.num_vertices, g.num_directed_edges, g.max_degree, g.ell_width)
    return children, aux


def _devicegraph_unflatten(aux, children):
    src, dst, row_ptr, col_idx, ell_slot, inc_ptr = children
    num_vertices, num_directed_edges, max_degree, ell_width = aux
    return DeviceGraph(num_vertices=num_vertices,
                       num_directed_edges=num_directed_edges,
                       src=src, dst=dst, max_degree=max_degree,
                       row_ptr=row_ptr, col_idx=col_idx,
                       ell_slot=ell_slot, ell_width=ell_width,
                       inc_ptr=inc_ptr)


jax.tree_util.register_pytree_node(
    DeviceGraph, _devicegraph_flatten, _devicegraph_unflatten)


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """Shard-local CSR + halo layout — the first-class partitioned form of a
    host :class:`Graph` (built by ``repro.core.distributed.partition_graph``).

    Device ``d`` owns partition-space vertices ``[d*Vl, (d+1)*Vl)``. Each
    local vertex is classified at partition time: **interior** (no
    cross-shard edge — its color never leaves the shard) or **boundary**
    (some neighbor lives on another shard). ``bnd`` is the static
    gather/scatter index map of the boundary set into a fixed per-shard halo
    slab: the boundary-only wire gathers ``packed[bnd[d]]`` and every shard
    scatters the ``[D, Bl]`` payload back through the same (static) global
    ids — interior vertices are structurally absent from the exchange.

    lsrc [D, El]     local src ids, row-contiguous per shard (CSR order,
                     ELL slots recoverable on device), pad = ``Vl``;
    ldst [D, El]     partition-space global dst ids, pad = ``Vl*D``;
    bnd  [D, Bl]     local ids of each shard's boundary vertices, pad =
                     ``Vl`` (``Bl`` = max boundary count, or the pinned
                     ``pad_boundary_to`` capacity);
    perm [V] or None original-id -> partition-space-id map (``"2d"``
                     block-cyclic scheme; ``None`` = identity, ``"1d"``).

    Iterating yields the legacy ``(lsrc, ldst, verts_local)`` triple.
    """

    lsrc: np.ndarray
    ldst: np.ndarray
    bnd: np.ndarray
    verts_local: int
    num_vertices: int
    num_devices: int
    scheme: str = "1d"
    perm: Optional[np.ndarray] = None
    boundary_counts: Optional[np.ndarray] = None

    def __iter__(self):
        return iter((self.lsrc, self.ldst, self.verts_local))

    @property
    def edges_local(self) -> int:
        return int(self.lsrc.shape[1])

    @property
    def boundary_local(self) -> int:
        return int(self.bnd.shape[1])

    @property
    def padded_vertices(self) -> int:
        return int(self.verts_local * self.num_devices)

    @property
    def interior_counts(self) -> np.ndarray:
        if self.perm is not None:
            owned = np.bincount(
                np.asarray(self.perm) // self.verts_local,
                minlength=self.num_devices)
        else:
            owned = np.minimum(
                np.maximum(self.num_vertices
                           - np.arange(self.num_devices) * self.verts_local,
                           0),
                self.verts_local)
        return owned - np.asarray(self.boundary_counts)

    def padded_boundary(self, cap: int) -> np.ndarray:
        """``bnd`` widened (pad = ``Vl``) to a pinned capacity — the plan
        path, where every served graph must produce identically-shaped halo
        slabs. A graph whose densest boundary exceeds ``cap`` is rejected
        rather than truncated (a truncated halo would drop remote reads)."""
        Bl = self.boundary_local
        if Bl > cap:
            raise ValueError(
                f"densest shard holds {Bl} boundary vertices, above the "
                f"requested halo capacity pad_boundary_to={cap}")
        out = np.full((self.num_devices, int(cap)), self.verts_local,
                      np.int32)
        out[:, :Bl] = self.bnd
        return out

    def unpermute(self, colors: np.ndarray) -> np.ndarray:
        """Colors in partition space ``[Vl*D]`` -> original vertex ids
        ``[V]`` (inverts the ``"2d"`` relabel; a ``"1d"`` layout just trims
        the vertex padding)."""
        colors = np.asarray(colors).reshape(-1)
        if self.perm is None:
            return colors[:self.num_vertices]
        return colors[self.perm]
