"""Version shim for the Pallas TPU compiler-params rename.

jax 0.4.37 exposes ``pltpu.TPUCompilerParams``; newer releases renamed it to
``pltpu.CompilerParams``. Resolve whichever exists once, here, so kernel
modules stay version-agnostic.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

TPUCompilerParams = getattr(pltpu, "TPUCompilerParams", None) or getattr(
    pltpu, "CompilerParams")
