"""Input-spec stand-ins, cache specs, and FLOPs/param accounting."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import counting, cache_spec
from repro.models.config import SHAPES
from repro.launch import specs as S


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_specs_abstract(arch):
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    b = S.batch_specs(cfg, shape)
    assert b["tokens"].shape == (256, 4096)
    assert b["tokens"].dtype == jnp.int32
    if cfg.family == "vlm":
        assert b["image_embeds"].shape[1] == cfg.vlm.num_image_tokens
    if cfg.family == "encdec":
        assert b["frames"].shape[1] == cfg.encdec.enc_seq
    # axes tree matches structurally
    axes = S.batch_axes(cfg)
    assert set(axes) == set(b)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_spec_matches_init_cache(arch):
    """Abstract cache specs must mirror the concrete cache exactly — the
    dry-run lowers decode from the former, runtime uses the latter."""
    from repro import models
    cfg = get_smoke_config(arch)
    shapes, axes = cache_spec(cfg, batch=2, max_len=16)
    concrete = models.init_cache(cfg, 2, 16)
    flat_s = jax.tree.leaves(shapes)
    flat_c = jax.tree.leaves(concrete)
    assert len(flat_s) == len(flat_c)
    for s, c in zip(flat_s, flat_c):
        assert tuple(s.shape) == tuple(c.shape)
        assert s.dtype == c.dtype


def test_model_flops_kinds():
    cfg = get_config("qwen3-4b")
    tr = counting.model_flops(cfg, SHAPES["train_4k"])
    pf = counting.model_flops(cfg, SHAPES["prefill_32k"])
    dc = counting.model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert tr == 6.0 * n * 4096 * 256
    assert pf == 2.0 * n * 32768 * 32
    assert dc == 2.0 * n * 128


def test_moe_active_params_smaller():
    cfg = get_config("grok-1-314b")
    assert cfg.active_param_count() < 0.4 * cfg.param_count()
    dense = get_config("qwen3-4b")
    assert dense.active_param_count() == dense.param_count()


def test_decode_specs_batch():
    cfg = get_config("mistral-nemo-12b")
    shapes, axes, tok = S.decode_specs(cfg, SHAPES["decode_32k"])
    assert tok.shape == (128,)
    # KV cache spans the full context
    k = shapes["blocks"]["b0"]["k"]
    assert k.shape[2] == 32768  # [layers, B, S, KH, hd]
