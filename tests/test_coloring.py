"""Coloring algorithm tests: serial oracle, ITERATIVE, DATAFLOW — the
paper's correctness + quality claims (C1-C4 in DESIGN.md)."""
import numpy as np
import pytest

from repro.core import (Graph, rmat, greedy_color, color_iterative,
                        color_dataflow, dataflow_levels, validate_coloring,
                        num_colors)

GRAPHS = ["RMAT-ER", "RMAT-G", "RMAT-B"]


def _graph(name, scale=10, seed=1):
    return rmat.paper_graph(name, scale=scale, seed=seed)


# ------------------------------------------------------------ serial oracle
@pytest.mark.parametrize("name", GRAPHS)
def test_greedy_valid(name):
    g = _graph(name)
    colors = greedy_color(g)
    assert validate_coloring(g, colors)
    assert colors.max() <= g.max_degree() + 1


def test_greedy_path_graph_two_colors():
    edges = np.array([[i, i + 1] for i in range(9)])
    g = Graph.from_edges(10, edges)
    assert greedy_color(g).max() == 2


def test_greedy_complete_graph():
    n = 8
    edges = np.array([[i, j] for i in range(n) for j in range(i + 1, n)])
    g = Graph.from_edges(n, edges)
    colors = greedy_color(g)
    assert colors.max() == n
    assert validate_coloring(g, colors)


# --------------------------------------------------------------- ITERATIVE
@pytest.mark.parametrize("name", GRAPHS)
@pytest.mark.parametrize("concurrency", [16, 128])
def test_iterative_valid(name, concurrency):
    g = _graph(name)
    res = color_iterative(g.to_device(), concurrency=concurrency)
    assert validate_coloring(g, np.asarray(res.colors))


def test_iterative_p1_equals_serial():
    """concurrency=1 degenerates to serial greedy: zero conflicts,
    bit-identical colors (Alg. 2 -> Alg. 1)."""
    g = _graph("RMAT-G")
    res = color_iterative(g.to_device(), concurrency=1)
    assert res.total_conflicts == 0
    assert res.rounds == 1
    np.testing.assert_array_equal(np.asarray(res.colors), greedy_color(g))


@pytest.mark.parametrize("name", GRAPHS)
def test_iterative_conflicts_grow_with_concurrency(name):
    """Paper Fig. 10(a): conflicts increase with thread concurrency (C3)."""
    g = _graph(name, scale=11)
    confs = [color_iterative(g.to_device(), concurrency=p).total_conflicts
             for p in [1, 16, 256]]
    assert confs[0] == 0
    assert confs[0] <= confs[1] <= confs[2]


def test_iterative_conflicts_small_and_few_rounds():
    """Paper C2: conflicts << |V| at realistic concurrency; few rounds."""
    g = _graph("RMAT-B", scale=12)
    res = color_iterative(g.to_device(), concurrency=16)
    assert res.total_conflicts < 0.02 * g.num_vertices
    assert res.rounds <= 6


def test_iterative_color_quality_near_serial():
    """Paper C1/Fig. 11: parallel colors ~= serial colors; the hostile
    RMAT-B shows a modest increase at high concurrency (as in the paper)."""
    for name in GRAPHS:
        g = _graph(name, scale=11)
        serial = num_colors(greedy_color(g))
        par = color_iterative(g.to_device(), concurrency=128).num_colors
        assert par <= int(1.35 * serial) + 2, (name, par, serial)
        low = color_iterative(g.to_device(), concurrency=16).num_colors
        assert low <= serial + 2, (name, low, serial)


# ---------------------------------------------------------------- DATAFLOW
@pytest.mark.parametrize("name", GRAPHS)
def test_dataflow_identical_to_serial(name):
    """C4: the dataflow fixpoint produces EXACTLY the serial greedy coloring
    (priority = index, as on the XMT)."""
    g = _graph(name)
    res = color_dataflow(g.to_device())
    np.testing.assert_array_equal(np.asarray(res.colors), greedy_color(g))


def test_dataflow_sweeps_bounded_by_dag_depth():
    """Chaotic iteration converges in AT MOST depth(DAG)+1 sweeps — and
    often faster (it can beat the XMT's dataflow critical path, since
    non-final inputs may coincidentally produce final values)."""
    g = _graph("RMAT-G", scale=9)
    res = color_dataflow(g.to_device())
    _, depth = dataflow_levels(g.to_device())
    assert 2 <= res.sweeps <= depth + 2


def test_dataflow_levels_independent_sets():
    """Vertices of one wavefront are pairwise non-adjacent."""
    g = _graph("RMAT-B", scale=9)
    lv, depth = dataflow_levels(g.to_device())
    lv = np.asarray(lv)
    src, dst = g.directed_edges()
    assert not np.any(lv[src] == lv[dst]), "adjacent vertices share a level"


def test_empty_and_isolated_graphs():
    g = Graph.from_edges(5, np.zeros((0, 2), np.int64))
    colors = greedy_color(g)
    assert np.all(colors == 1)
    res = color_iterative(g.to_device(), concurrency=4)
    assert np.all(np.asarray(res.colors) == 1)
    res2 = color_dataflow(g.to_device())
    assert np.all(np.asarray(res2.colors) == 1)
