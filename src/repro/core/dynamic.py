"""Streaming/dynamic coloring: edge-delta batches as frontier seeds.

The paper's speculation loop (§3, Alg. 2) is already an incremental repair
mechanism — each round recolors only the conflicted vertices — and Rokos et
al. (arXiv:1505.04086) make detect-and-recolor over the conflicted frontier
the scalable core of the method. This module closes the loop for *streaming*
graphs: an edge-delta batch is just another frontier seed.

:class:`DynamicColoring` holds a live (graph, coloring) pair and applies
insert/delete batches incrementally:

* **deletes** only relax constraints — the coloring stays valid untouched
  (they may leave palette gaps, which is why ``num_colors`` counts distinct
  colors, not the max);
* **inserts** can create monochromatic edges — exactly the paper's phase-2
  conflicts. Their endpoints become the pending seed of a ``"recolor"``
  run (repro.core.api.RecolorStrategy): the registered fourth strategy that
  warm-starts the ITERATIVE round loop from (committed colors, seed mask)
  and lets round 0 take the compacted frontier path
  (:func:`repro.core.frontier.compact_frontier`), so a delta repair sweeps
  the O(seed) slab instead of the O(E) edge list.

Plans make repairs retrace-free: the state rides a
:class:`repro.core.api.ColoringPlan` compiled against a headroomed
envelope on the :func:`repro.core.graph.pad_bucket` ladder, so every delta
batch that stays inside the envelope reuses ONE jitted program
(``plan.traces`` stays at 1 — pinned in tests); a batch that outgrows it
recompiles against a larger bucket (counted in ``recompiles``) and keeps
streaming.

Color quality is bounded, not exact: every color ever assigned is a mex
over a vertex's live neighborhood, hence at most ``max_degree_seen + 1``
(the largest max degree the stream has passed through). A fresh recoloring
of the final graph may use fewer colors; ``repro.serve`` or the
``stream_compare`` benchmark report the ratio.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .api import ColoringPlan, ColoringReport, ColoringSpec, PlanShape, \
    compile_plan, get_strategy
from .graph import Graph, pad_bucket


@dataclasses.dataclass
class DeltaReport:
    """What one :meth:`DynamicColoring.apply_batch` did.

    inserted / deleted count *effective* edge changes (idempotent set
    semantics: duplicates, self loops, inserts of present edges and
    deletes of absent ones are no-ops). ``seed_size`` is the number of
    vertices seeded for repair (endpoints of newly monochromatic edges);
    ``report`` the repair's :class:`repro.core.api.ColoringReport`, or
    ``None`` when the batch created no conflicts and the coloring stood.
    ``wall_time_s`` covers the whole batch: host delta application,
    conflict detection, and the (possible) device repair."""

    inserted: int
    deleted: int
    seed_size: int
    report: Optional[ColoringReport]
    wall_time_s: float

    @property
    def repaired(self) -> bool:
        return self.report is not None


class DynamicColoring:
    """A live colored graph under streaming edge deltas.

    ``spec`` must resolve to the ``"recolor"`` strategy (the default);
    engine / frontier / concurrency knobs compose as everywhere else. The
    coloring model is distance-1 only — under d2/pd2 an edge delta
    perturbs constraints beyond its endpoints, so the endpoint seed would
    under-repair. The vertex set is fixed at construction (isolated
    vertices are fine — size the graph for the stream).

    ``edge_headroom`` / ``degree_headroom`` scale the plan envelope above
    the current graph so delta batches stay inside one compiled program;
    pass ``plan_shape`` to pin the envelope for a whole stream explicitly.

    Invariants (asserted by the test suite):
      * after every batch, ``colors`` is a valid coloring of ``graph``
        under every engine backend;
      * ``num_colors <= max_degree_seen + 1`` — every color ever assigned
        was a mex over a live neighborhood;
      * same-envelope batches never retrace (``plan.traces`` stays 1).
    """

    def __init__(self, graph: Graph, spec: Optional[ColoringSpec] = None,
                 *, edge_headroom: float = 1.5,
                 degree_headroom: float = 1.5,
                 plan_shape: Optional[PlanShape] = None):
        spec = self._check_spec(spec)
        self.spec = spec
        self._graph = graph
        self._edge_headroom = float(edge_headroom)
        self._degree_headroom = float(degree_headroom)
        self._pinned_shape = plan_shape
        self.recompiles = 0
        self.max_degree_seen = graph.max_degree()
        self._plan = self._compile(plan_shape or self._envelope(graph))
        # the cold start: no colors, everything pending — the same compiled
        # program later delta repairs reuse (zero retrace)
        self._colors = np.asarray(self._plan(graph).colors)

    # -------------------------------------------------------------- plumbing
    @staticmethod
    def _check_spec(spec: Optional[ColoringSpec]) -> ColoringSpec:
        spec = ColoringSpec(strategy="recolor") if spec is None else spec
        if get_strategy(spec.strategy).name != "recolor":
            raise ValueError(
                "DynamicColoring needs the 'recolor' strategy (got "
                f"{spec.strategy!r}); other strategies have no warm start")
        if spec.model != "d1":
            raise ValueError(
                "DynamicColoring is distance-1 only: under d2/pd2 an edge "
                "delta perturbs constraints beyond its endpoints, so the "
                "endpoint seed would under-repair")
        if spec.ordering != "natural":
            raise ValueError("DynamicColoring repairs in place; ordering "
                             "must be 'natural'")
        return spec

    def _envelope(self, graph: Graph) -> PlanShape:
        """Headroomed envelope on the pad_bucket ladder: deltas that stay
        inside it ride one compiled program. The edge floor (one minimum
        bucket) lets a stream start from a sparse — even empty — graph
        without an immediate recompile."""
        e = max(int(graph.num_directed_edges * self._edge_headroom), 1)
        d = graph.max_degree()
        return PlanShape(
            num_vertices=graph.num_vertices,
            padded_edges=pad_bucket(e),
            max_degree=max(int(d * self._degree_headroom), d + 2, 8))

    def _compile(self, shape: PlanShape) -> ColoringPlan:
        return compile_plan(self.spec, shape)

    def _ensure_envelope(self, graph: Graph) -> None:
        st = self._plan.statics
        if (graph.num_directed_edges <= st.padded_edges
                and graph.max_degree() <= st.max_degree):
            return
        if self._pinned_shape is not None:
            raise ValueError(
                f"stream outgrew the pinned plan envelope {st}: graph has "
                f"{graph.num_directed_edges} directed edges / max degree "
                f"{graph.max_degree()}; construct with a larger plan_shape "
                "or let DynamicColoring manage the envelope")
        self._plan = self._compile(self._envelope(graph))
        self.recompiles += 1

    # ------------------------------------------------------------ the state
    @property
    def graph(self) -> Graph:
        return self._graph

    @property
    def colors(self) -> np.ndarray:
        return self._colors

    @property
    def plan(self) -> ColoringPlan:
        return self._plan

    @property
    def num_colors(self) -> int:
        from .metrics import num_colors
        return num_colors(self._colors)

    @property
    def color_bound(self) -> int:
        """The provable palette bound: every color ever assigned was a mex
        over a live neighborhood, so ``<= max_degree_seen + 1``."""
        return self.max_degree_seen + 1

    # ------------------------------------------------------------ the delta
    def apply_batch(self, inserts=None, deletes=None) -> DeltaReport:
        """Apply one edge-delta batch and repair the coloring incrementally.

        ``inserts`` / ``deletes`` are [M, 2] endpoint arrays (either
        orientation; duplicates/self-loops/no-ops welcome — set
        semantics, deletes first). Only the endpoints of *newly
        monochromatic* edges are recolored; a conflict-free batch leaves
        every color untouched."""
        t0 = time.perf_counter()
        old = self._graph
        new_graph, new_pairs, n_del = old.delta_info(inserts, deletes)

        # genuinely-new inserts: absent before — their monochromatic
        # endpoints are the repair seed
        seed = np.zeros(old.num_vertices, np.bool_)
        if new_pairs.shape[0]:
            u, v = new_pairs[:, 0], new_pairs[:, 1]
            conf = self._colors[u] == self._colors[v]
            seed[u[conf]] = True
            seed[v[conf]] = True
        seed_size = int(seed.sum())

        # nothing commits until the whole batch succeeds: a pinned-envelope
        # overflow (raises here) or a repair that fails to converge (raises
        # in the plan call) leaves graph/colors/max_degree_seen still
        # agreeing, so a caller can catch, resize/relax and retry the batch
        self._ensure_envelope(new_graph)
        report = None
        if seed_size:
            report = self._plan(new_graph, colors=self._colors, seed=seed)
        self._graph = new_graph
        self.max_degree_seen = max(self.max_degree_seen,
                                   new_graph.max_degree())
        if report is not None:
            self._colors = np.asarray(report.colors)
        return DeltaReport(inserted=int(new_pairs.shape[0]), deleted=n_del,
                           seed_size=seed_size, report=report,
                           wall_time_s=time.perf_counter() - t0)

    def recolor_full(self) -> ColoringReport:
        """Recolor the current graph from scratch through the same plan
        (palette compaction: drops the accumulated streaming gaps)."""
        report = self._plan(self._graph)
        self._colors = np.asarray(report.colors)
        return report

    # -------------------------------------------------------- checkpointing
    def state_dict(self) -> dict:
        """The complete streaming state as a flat dict of host arrays — a
        pytree ``repro.train.checkpoint.save`` writes verbatim. Everything
        a bit-identical resume needs is here: the canonical undirected
        edge set (``Graph.from_edges`` round-trips it to the SAME CSR —
        both sides are lexsort-canonical), the committed colors, the plan
        envelope (so the restored program is compiled against the same
        static shapes), and the stream counters. The spec is NOT included
        (not an array): serialize it separately via
        :meth:`repro.core.api.ColoringSpec.to_dict`."""
        st = self._plan.statics
        return {
            "edges": self._graph.undirected_edges().astype(np.int64),
            "colors": self._colors.astype(np.int32),
            "num_vertices": np.int64(self._graph.num_vertices),
            "max_degree_seen": np.int64(self.max_degree_seen),
            "recompiles": np.int64(self.recompiles),
            "envelope": np.asarray(
                [st.num_vertices, st.padded_edges, st.max_degree], np.int64),
            "pinned": np.int64(self._pinned_shape is not None),
            "headroom": np.asarray(
                [self._edge_headroom, self._degree_headroom], np.float64),
        }

    @classmethod
    def from_state(cls, state: dict,
                   spec: Optional[ColoringSpec] = None) -> "DynamicColoring":
        """Rebuild a live stream from :meth:`state_dict` output — WITHOUT
        rerunning the cold start: the committed colors are restored as-is,
        and the plan recompiles against the checkpointed envelope, so
        every delta batch after the restore produces bit-identical colors
        to the unkilled run (pinned by ``tests/test_serve_faults.py``)."""
        spec = cls._check_spec(spec)
        self = cls.__new__(cls)
        self.spec = spec
        V = int(state["num_vertices"])
        self._graph = Graph.from_edges(
            V, np.asarray(state["edges"]).reshape(-1, 2))
        colors = np.asarray(state["colors"]).astype(np.int32)
        if colors.shape != (V,):
            raise ValueError(f"checkpointed colors shape {colors.shape} "
                             f"!= ({V},)")
        hr = np.asarray(state["headroom"], np.float64)
        self._edge_headroom, self._degree_headroom = float(hr[0]), float(hr[1])
        env = [int(x) for x in np.asarray(state["envelope"])]
        shape = PlanShape(num_vertices=env[0], padded_edges=env[1],
                          max_degree=env[2])
        self._pinned_shape = shape if int(state["pinned"]) else None
        self.recompiles = int(state["recompiles"])
        self.max_degree_seen = int(state["max_degree_seen"])
        self._plan = self._compile(shape)
        self._colors = colors
        return self
