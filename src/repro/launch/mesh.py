"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model"); the pod
axis is data-parallel by default (DCN-friendly: only gradient all-reduce
crosses pods) and can host pipeline stages via the PP feature flag.

Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
