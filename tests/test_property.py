"""Hypothesis property tests on the coloring system's invariants.

Skipped cleanly (not a collection error) where ``hypothesis`` is absent;
``requirements.txt`` pins it for environments that install dev deps.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (BipartiteGraph, Graph, greedy_color, color_iterative,
                        color_dataflow, validate_coloring,
                        validate_pd2_coloring)
from repro.core.mex import segment_mex

import jax.numpy as jnp


@st.composite
def random_graphs(draw, max_v=40, max_e=120):
    n = draw(st.integers(2, max_v))
    m = draw(st.integers(0, max_e))
    edges = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
        min_size=m, max_size=m))
    return Graph.from_edges(n, np.array(edges or [[0, 0]], dtype=np.int64))


@settings(max_examples=40, deadline=None)
@given(random_graphs())
def test_greedy_always_valid(g):
    colors = greedy_color(g)
    assert validate_coloring(g, colors)
    assert colors.max() <= g.max_degree() + 1


@settings(max_examples=25, deadline=None)
@given(random_graphs(), st.sampled_from([1, 3, 7, 64]),
       st.sampled_from(["sort", "bitmap"]))
def test_iterative_always_valid(g, p, engine):
    res = color_iterative(g.to_device(), concurrency=p, max_rounds=128,
                          engine=engine)
    assert validate_coloring(g, np.asarray(res.colors))


@settings(max_examples=25, deadline=None)
@given(random_graphs())
def test_dataflow_equals_serial(g):
    res = color_dataflow(g.to_device())
    np.testing.assert_array_equal(np.asarray(res.colors), greedy_color(g))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, 12)),
                min_size=1, max_size=60))
def test_segment_mex_matches_python(pairs):
    """Sorted-gap mex == straightforward python mex."""
    n = 10
    v = jnp.asarray([p[0] for p in pairs] + list(range(n)), jnp.int32)
    c = jnp.asarray([p[1] for p in pairs] + [0] * n, jnp.int32)
    got = np.asarray(segment_mex(v, c, n))
    for vid in range(n):
        present = {cc for (vv, cc) in pairs if vv == vid} | {0}
        mex = 1
        while mex in present:
            mex += 1
        assert got[vid] == mex


@settings(max_examples=20, deadline=None)
@given(random_graphs(max_v=24, max_e=60), st.sampled_from(["sort", "bitmap"]))
def test_d2_no_two_hop_pair_shares_a_color(g, engine):
    """For ANY graph: after model="d2" coloring, no pair of vertices at
    distance <= 2 shares a color (checked against the dense two-hop
    closure, independently of the wedge lowering under test)."""
    res = color_iterative(g, concurrency=4, engine=engine, model="d2",
                          max_rounds=512)
    colors = np.asarray(res.colors)
    V = g.num_vertices
    A = np.zeros((V, V), bool)
    src, dst = g.directed_edges()
    A[src, dst] = True
    reach2 = A | (A.astype(np.int64) @ A.astype(np.int64) > 0)
    np.fill_diagonal(reach2, False)
    u, v = np.nonzero(reach2)
    assert (colors > 0).all()
    assert not np.any(colors[u] == colors[v])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(1, 8), st.integers(0, 40),
       st.integers(0, 2 ** 31 - 1))
def test_pd2_no_shared_neighbor_pair_shares_a_color(L, R, m, seed):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, L, m), rng.integers(0, R, m)], 1)
    bg = BipartiteGraph.from_edges(L, R, edges)
    res = color_dataflow(bg, model="pd2")
    assert validate_pd2_coloring(bg, np.asarray(res.colors))


@settings(max_examples=20, deadline=None)
@given(random_graphs())
def test_permutation_equivariance(g):
    """Relabeling vertices permutes the dataflow coloring accordingly
    (greedy follows index order, so colors map through the permutation)."""
    perm = np.random.default_rng(0).permutation(g.num_vertices).astype(np.int64)
    g2 = g.relabel(perm)
    c1 = greedy_color(g)   # color of old vertex i
    c2 = greedy_color(g2)  # color of new vertex perm[i]
    # not necessarily equal colors (order changed), but both valid and
    # within the same Delta+1 bound
    assert validate_coloring(g2, c2)
    assert c2.max() <= g.max_degree() + 1
