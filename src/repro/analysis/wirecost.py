"""Static wire-cost model — bytes-on-wire accounting (WIRE codes).

The three-tier distributed exchange has a *closed-form* per-round byte
count, documented in DESIGN.md §Perf and deliberately **re-derived here
from the documented formulas** rather than imported from the runtime
modules — so the pass cross-checks two independent implementations and
code/doc drift in either becomes a lint error (WIRE201):

* **H-C4 boundary halo** — ``D * ceil(Bl / k) * 4`` bytes/round, with
  ``k = 32 // (bit_length(wire_colors) + 1)`` packed entries per int32
  word (``repro.parallel.compression``'s layout);
* **H-C1 full spill** — ``Vp * 2`` bytes/round (the packed-int16 gather);
* **H-C3 frontier slab** — ``D * cap_v * 4`` when ``(gid, color)`` packs
  into one word (``bit_length(Vp) + bit_length(wire_colors) <= 32``),
  else two int32 gathers totalling ``D * cap_v * 8``;
* **setup** — one ``D * Bl * 4`` boundary-map gather, outside the round
  loop (zero per-round id traffic).

:func:`check_wire_cost` walks the traced mesh program, attributes every
``all_gather`` to a tier by its structural position (pre-loop = setup;
in-loop true-branch of a gathering cond = slab; in-loop otherwise = the
configured round tier), and compares traced output bytes against the
closed forms. Scalar ``psum`` votes (<= 2 elements) are control plane,
inventoried in the cost table but never gated.

:func:`closed_form_table` / :func:`wire_cost_table` emit the
machine-readable cost table (also surfaced via
``python -m repro.analysis --distributed --json``); the ``dist_scale``
benchmark asserts its measured per-round bytes against it within the
plan-envelope padding tolerance.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .findings import Finding
from .jaxpr_walk import site_of
from .spmd import (SpmdGeometry, aval_nbytes, cond_branches,
                   distributed_geometry, find_shard_jaxprs, iter_round_loops,
                   sub_jaxpr, while_parts)

# psum outputs at or below this element count are termination/fit votes
# (control plane), not wire payload
_VOTE_ELEMS = 2


# ---------------------------------------------------------------------------
# the closed forms (DESIGN.md §Perf — independent of the runtime modules)
# ---------------------------------------------------------------------------
def halo_round_bytes(num_devices: int, boundary_local: int,
                     wire_colors: int) -> int:
    """H-C4: ``D * ceil(Bl/k) * 4`` — bit-packed boundary halo words."""
    if boundary_local <= 0:
        return 0
    bits = max(1, int(wire_colors).bit_length()) + 1
    k = max(1, 32 // bits)
    return num_devices * (-(-boundary_local // k)) * 4


def spill_round_bytes(verts_global: int) -> int:
    """H-C1: the full packed-int16 ``[Vp]`` gather."""
    return verts_global * 2


def slab_round_bytes(num_devices: int, frontier_cap_v: int,
                     verts_global: int, wire_colors: int) -> int:
    """H-C3: ``(gid, color)`` slab entries — one packed int32 word when
    both fields fit, else two int32 gathers."""
    if frontier_cap_v <= 0:
        return 0
    packed = (wire_colors > 0 and
              int(verts_global).bit_length()
              + int(wire_colors).bit_length() <= 32)
    return num_devices * frontier_cap_v * (4 if packed else 8)


def setup_bytes(num_devices: int, boundary_local: int) -> int:
    """The one-time boundary->halo id-map gather (``D * Bl * 4``)."""
    return num_devices * boundary_local * 4 if boundary_local > 0 else 0


def closed_form_table(*, num_devices: int, verts_local: int,
                      boundary_local: int, wire_colors: int,
                      frontier_cap_v: int = 0, wire: str = "boundary",
                      scheme: str = "1d") -> Dict:
    """The machine-readable cost table for one program geometry — raw
    numbers, no tracing. The ``dist_scale`` benchmark evaluates this at
    the measured layout and asserts its accounting matches."""
    Vp = verts_local * num_devices
    tiers: Dict[str, Dict] = {}
    if wire == "boundary":
        tiers["halo"] = {
            "bytes_per_round": halo_round_bytes(num_devices, boundary_local,
                                                wire_colors),
            "formula": "D*ceil(Bl/k)*4, k=32//(bit_length(C)+1)"}
        tiers["setup"] = {
            "bytes_once": setup_bytes(num_devices, boundary_local),
            "formula": "D*Bl*4"}
    else:
        tiers["spill"] = {"bytes_per_round": spill_round_bytes(Vp),
                          "formula": "Vp*2"}
    if frontier_cap_v > 0:
        tiers["slab"] = {
            "bytes_per_round": slab_round_bytes(num_devices, frontier_cap_v,
                                                Vp, wire_colors),
            "formula": "D*cap_v*4 packed | D*cap_v*8 two-gather"}
    return {"wire": wire, "scheme": scheme, "num_devices": num_devices,
            "verts_local": verts_local, "verts_global": Vp,
            "boundary_local": boundary_local, "wire_colors": wire_colors,
            "frontier_cap_v": frontier_cap_v, "tiers": tiers}


def wire_cost_table(spec, statics) -> Optional[Dict]:
    """:func:`closed_form_table` for a plan spec/envelope (None for
    non-distributed strategies)."""
    from ..core.api import get_strategy
    if get_strategy(spec.strategy).wants != "host":
        return None
    g = distributed_geometry(spec, statics)
    return closed_form_table(
        num_devices=g.num_devices, verts_local=g.verts_local,
        boundary_local=g.boundary_cap, wire_colors=g.wire_colors,
        frontier_cap_v=g.frontier_cap_v, wire=g.wire,
        scheme=spec.partition)


# ---------------------------------------------------------------------------
# traced-program attribution
# ---------------------------------------------------------------------------
def _collect(jaxpr, sink, *, in_loop: bool, branch: Optional[int]):
    """Record every collective with its structural position. ``branch`` is
    the cond-branch index when inside an in-loop cond that gathers
    (1 = predicate-true = the slab wire), else None."""
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in ("all_gather", "psum", "pmin", "pmax"):
            sink.append((eqn, in_loop, branch))
        elif prim == "while":
            _, body, _, _ = while_parts(eqn)
            if body is not None:
                _collect(body, sink, in_loop=True, branch=None)
        elif prim == "cond":
            for idx, b in enumerate(cond_branches(eqn)):
                _collect(b, sink, in_loop=in_loop,
                         branch=(idx if in_loop else branch))
        else:
            sub = sub_jaxpr(eqn.params.get("jaxpr",
                                           eqn.params.get("call_jaxpr")))
            if sub is not None:
                _collect(sub, sink, in_loop=in_loop, branch=branch)


def check_wire_cost(closed_jaxpr, geometry: SpmdGeometry, *,
                    context: str = "") -> List[Finding]:
    """Compare every traced collective's bytes against the closed-form
    tier accounting for ``geometry``. Returns WIRE findings (the WIRE101
    info carries the per-tier cost table entries)."""
    g = geometry
    findings: List[Finding] = []
    D, Vp = g.num_devices, g.verts_global
    exp_halo = halo_round_bytes(D, g.boundary_cap, g.wire_colors)
    exp_spill = spill_round_bytes(Vp)
    exp_slab = slab_round_bytes(D, g.frontier_cap_v, Vp, g.wire_colors)
    exp_setup = setup_bytes(D, g.boundary_cap)
    slab_packed = (g.wire_colors > 0 and
                   int(Vp).bit_length()
                   + int(g.wire_colors).bit_length() <= 32)

    for shard_eqn, body in find_shard_jaxprs(closed_jaxpr):
        colls: List = []
        _collect(body, colls, in_loop=False, branch=None)

        setup_sum = 0
        setup_sites = []
        slab_sum = 0
        slab_count = 0
        round_tier: List = []  # (eqn, bytes)
        votes = 0
        for eqn, in_loop, branch in colls:
            nbytes = sum(aval_nbytes(v) for v in eqn.outvars)
            if eqn.primitive.name != "all_gather":
                if all(_elems(v) <= _VOTE_ELEMS for v in eqn.outvars):
                    votes += 1
                    continue
                findings.append(Finding(
                    "WIRE202", site_of(eqn),
                    f"non-scalar {eqn.primitive.name} "
                    f"({nbytes} B) matches no documented wire tier",
                    context))
                continue
            if not in_loop:
                setup_sum += nbytes
                setup_sites.append(site_of(eqn))
                continue
            if branch == 1 and g.frontier_cap_v > 0:
                slab_sum += nbytes
                slab_count += 1
                continue
            round_tier.append((eqn, nbytes))

        # --- setup: the one-time boundary-map gather -----------------------
        if setup_sum != exp_setup:
            findings.append(Finding(
                "WIRE203", setup_sites[0] if setup_sites
                else site_of(shard_eqn, "plan:distributed"),
                f"pre-loop exchange ships {setup_sum} B, closed form says "
                f"D*Bl*4 = {exp_setup} B (D={D}, Bl={g.boundary_cap})",
                context))

        # --- slab tier -----------------------------------------------------
        if g.frontier_cap_v > 0:
            if slab_sum != exp_slab or \
                    slab_count != (1 if slab_packed else 2):
                findings.append(Finding(
                    "WIRE201", "core/distributed.py:slab_wire",
                    f"slab tier ships {slab_sum} B in {slab_count} "
                    f"gather(s), closed form says {exp_slab} B in "
                    f"{1 if slab_packed else 2} (D={D}, "
                    f"cap_v={g.frontier_cap_v}, packed={slab_packed})",
                    context))

        # --- the configured round tier ------------------------------------
        exp_round = exp_halo if g.wire == "boundary" else exp_spill
        tier_name = "halo" if g.wire == "boundary" else "spill"
        got_round = sum(b for _, b in round_tier)
        if len(round_tier) > 1:
            for eqn, b in round_tier[1:]:
                findings.append(Finding(
                    "WIRE202", site_of(eqn),
                    f"extra per-round all_gather ({b} B) beyond the single "
                    f"{tier_name}-tier exchange: unaccounted wire bytes",
                    context))
            got_round = round_tier[0][1]
        if got_round != exp_round or not round_tier:
            site = (site_of(round_tier[0][0]) if round_tier else
                    "core/distributed.py:"
                    + ("boundary_wire" if g.wire == "boundary"
                       else "full_wire"))
            findings.append(Finding(
                "WIRE201", site,
                f"{tier_name} tier ships {got_round} B/round, closed form "
                f"says {exp_round} B (D={D}, Bl={g.boundary_cap}, Vp={Vp}, "
                f"C={g.wire_colors})", context))

        table = closed_form_table(
            num_devices=D, verts_local=g.verts_local,
            boundary_local=g.boundary_cap, wire_colors=g.wire_colors,
            frontier_cap_v=g.frontier_cap_v, wire=g.wire)
        parts = [f"{name}={t.get('bytes_per_round', t.get('bytes_once'))}B"
                 for name, t in sorted(table["tiers"].items())]
        findings.append(Finding(
            "WIRE101", "core/distributed.py:_bsp_local",
            f"wire={g.wire} D={D} Vl={g.verts_local} Bl={g.boundary_cap} "
            f"C={g.wire_colors} cap_v={g.frontier_cap_v}: "
            + " ".join(parts) + f" votes/round<={votes}", context))
    return findings


def _elems(v) -> int:
    import numpy as np
    try:
        return int(np.prod(v.aval.shape)) if v.aval.shape else 1
    except Exception:
        return 1
