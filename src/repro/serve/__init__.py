"""Serving layer.

Two lanes:

* **Coloring service** (``repro.serve.coloring``): a batched coloring
  server over the spec/plan front door — LRU cache of compiled
  :class:`repro.core.api.ColoringPlan`s keyed by ``(spec, PlanShape)``
  bucket envelope, vmapped micro-batching of same-bucket requests, and
  latency/throughput stats. CLI smoke:
  ``PYTHONPATH=src python -m repro.serve.coloring --smoke``.
* **LM serving**: the family-dispatched cache/decode primitives live in
  ``repro.models`` (`cache_spec`, `init_cache`, `decode_step`,
  `forward(..., caches=)`) so each architecture's cache layout sits next
  to its math; this package re-exports them as the serving API and hosts
  the batched driver (`repro.launch.serve`). Cache sharding
  (sequence-sharded KV with LSE-combine collectives, ring buffers for
  local attention, O(1) recurrent states) is documented in DESIGN.md §6.
"""
from ..models import cache_spec, init_cache, decode_step, forward

__all__ = ["cache_spec", "init_cache", "decode_step", "forward",
           "ColoringService", "ServedReport"]


def __getattr__(name):
    # lazy (PEP 562): keeps `python -m repro.serve.coloring` free of the
    # runpy double-import warning and the package import light
    if name in ("ColoringService", "ServedReport"):
        from . import coloring
        return getattr(coloring, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
