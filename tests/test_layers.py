"""Numerical layer tests: flash vs exact attention, local banding, softcap,
rope, SSD chunking vs sequential recurrence, RG-LRU scan vs loop."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.layers import (flash_attention, local_attention,
                                 cache_attention, rope, rms_norm)
from repro.models.mamba2 import ssd_scan
from repro.models.rglru import rglru_forward, rglru_decode, rglru_init
from repro.models.params import ParamBuilder
from repro.models.config import ModelConfig, RGLRUConfig


def _exact_attention(q, k, v, causal=True, window=0, softcap=0.0):
    b, tq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, tq, kh, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * d ** -0.5
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    tk = k.shape[1]
    qp = jnp.arange(tq)[:, None]
    kp = jnp.arange(tk)[None, :]
    valid = jnp.ones((tq, tk), bool)
    if causal:
        valid &= kp <= qp
    if window:
        valid &= (qp - kp) < window
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(b, tq, h, d)


@pytest.mark.parametrize("t,h,kh,d", [(33, 4, 4, 16), (64, 8, 2, 32), (100, 4, 1, 8)])
@pytest.mark.parametrize("chunks", [(16, 16), (64, 32), (1024, 1024)])
def test_flash_matches_exact(t, h, kh, d, chunks):
    rng = np.random.default_rng(t + h)
    q = jnp.asarray(rng.normal(size=(2, t, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, t, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, t, kh, d)), jnp.float32)
    pos = jnp.arange(t, dtype=jnp.int32)
    got = flash_attention(q, k, v, q_positions=pos, kv_positions=pos,
                          causal=True, q_chunk=chunks[0], kv_chunk=chunks[1])
    want = _exact_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("softcap", [0.0, 30.0])
@pytest.mark.parametrize("window", [8, 16, 128])
def test_local_matches_exact(window, softcap):
    rng = np.random.default_rng(window)
    t, h, kh, d = 50, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(2, t, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, t, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, t, kh, d)), jnp.float32)
    pos = jnp.arange(t, dtype=jnp.int32)
    got = local_attention(q, k, v, window=window, q_positions=pos, softcap=softcap)
    want = _exact_attention(q, k, v, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_cache_attention_masks_by_cur_len():
    rng = np.random.default_rng(0)
    b, s, h, d = 2, 16, 2, 8
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out_a = cache_attention(q, k, v, cur_len=jnp.asarray([4, 16]))
    # zero out the cache beyond cur_len: result must not change
    mask = (jnp.arange(s)[None, :, None, None] <
            jnp.asarray([4, 16])[:, None, None, None])
    out_b = cache_attention(q, k * mask, v * mask, cur_len=jnp.asarray([4, 16]))
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b), atol=1e-6)


def test_rope_orthogonal_and_relative():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)
    y = rope(x, pos)
    # norms preserved (rotation)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    def dot_at(i, j):
        qi = rope(q, jnp.asarray([i]))
        kj = rope(k, jnp.asarray([j]))
        return float((qi * kj).sum())
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4


def test_ssd_chunked_matches_sequential():
    """Chunked SSD == naive per-step recurrence."""
    rng = np.random.default_rng(0)
    b, t, h, p, n = 2, 24, 3, 4, 8
    x = jnp.asarray(rng.normal(size=(b, t, h, p)), jnp.float32)
    dta = jnp.asarray(-np.abs(rng.normal(size=(b, t, h))) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    for chunk in [4, 8, 24]:
        y, final = ssd_scan(x, dta, bm, cm, chunk)
        # sequential reference
        s = np.zeros((b, h, p, n))
        ys = np.zeros((b, t, h, p))
        for i in range(t):
            a = np.exp(np.asarray(dta[:, i]))                  # [b,h]
            s = s * a[..., None, None] + np.einsum(
                "bhp,bn->bhpn", np.asarray(x[:, i]), np.asarray(bm[:, i]))
            ys[:, i] = np.einsum("bhpn,bn->bhp", s, np.asarray(cm[:, i]))
        np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4)
        np.testing.assert_allclose(np.asarray(final), s, atol=1e-4)


def test_rglru_scan_matches_stepwise():
    cfg = ModelConfig(name="t", family="hybrid", num_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=1, d_ff=32, vocab_size=64,
                      rglru=RGLRUConfig(d_rnn=16))
    b = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    rglru_init(b, cfg, cfg.rglru)
    p, _ = b.build()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 10, 16)) * 0.5, jnp.float32)
    y_full, h_final, tail = rglru_forward(p, x, cfg, cfg.rglru)
    # stepwise
    state = jnp.zeros((2, 16), jnp.float32)
    ctail = jnp.zeros((2, cfg.rglru.conv_width - 1, 16), jnp.float32)
    outs = []
    for i in range(10):
        y, state, ctail = rglru_decode(p, x[:, i:i+1], state, ctail, cfg, cfg.rglru)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_final), np.asarray(state), atol=2e-5)


def test_rms_norm_unit_scale():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64)) * 7, jnp.float32)
    y = rms_norm(x, jnp.zeros(64))
    rms = np.sqrt((np.asarray(y) ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
