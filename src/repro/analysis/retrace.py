"""Retrace-hazard lint: static jit args resolved at trace time, non-hashable
statics, and plan-envelope leaks.

Two complementary passes:

**AST pass** (:func:`lint_source` / :func:`lint_package`) — finds every
``jax.jit``-wrapped function with ``static_argnames``/``static_argnums``
and checks each static parameter:

* RETRACE001 — the parameter admits a ``None`` sentinel that the *body*
  resolves (default ``None``, or an ``x is None`` test inside the jitted
  body). This is exactly the PR-6 ``interpret=None`` cache-poisoning class:
  the sentinel is the jit cache key, so the trace-time resolution freezes
  into the cache and a later flip of the resolved global silently serves
  the stale trace. The fix pattern is :func:`repro.kernels.ops.
  resolve_interpret` — resolve OUTSIDE the jit boundary.
* RETRACE002 — the parameter's default is a non-hashable literal
  (list/dict/set): every call re-traces, or raises on cache lookup.

**Trace pass** (:func:`check_trace_constants`) — inspects the concrete
constants a traced program captured. A compiled :class:`ColoringPlan`
promises zero retrace across the envelope, which requires every large
array in the program to enter as an *argument* (part of the pytree) —
a closure-captured concrete array instead bakes graph DATA into the
program as a constant (RETRACE003): wrong answers for every later graph
served through the plan, with no retrace to save you. Envelope-derived
constants (iota ramps, constant fills) are exempt — they are functions of
the static shape, identical for every served graph.
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Sequence

import numpy as np

from .findings import Finding
from .jaxpr_walk import collect_consts, rel_source_path

# consts at or above this element count are checked against the
# envelope-derived exemptions; smaller ones cannot hold per-edge data
CONST_ELEMS_THRESHOLD = 128


# --------------------------------------------------------------------------
# AST pass
# --------------------------------------------------------------------------
def _is_jax_jit(node: ast.AST) -> bool:
    """Matches ``jax.jit`` / ``jit`` callee nodes."""
    if isinstance(node, ast.Attribute):
        return (node.attr == "jit" and isinstance(node.value, ast.Name)
                and node.value.id == "jax")
    return isinstance(node, ast.Name) and node.id == "jit"


def _static_names_from_call(call: ast.Call,
                            fn: Optional[ast.FunctionDef]) -> List[str]:
    """Static argnames declared by a ``jax.jit(...)``/``partial(jax.jit,...)``
    call, resolving ``static_argnums`` positions against ``fn``'s params."""
    names: List[str] = []
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            if isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, str):
                names.append(kw.value.value)
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                names.extend(e.value for e in kw.value.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, str))
        elif kw.arg == "static_argnums" and fn is not None:
            pos = []
            if isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, int):
                pos = [kw.value.value]
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                pos = [e.value for e in kw.value.elts
                       if isinstance(e, ast.Constant)
                       and isinstance(e.value, int)]
            params = [a.arg for a in fn.args.args]
            names.extend(params[p] for p in pos if p < len(params))
    return names


def _jit_static_names(fn: ast.FunctionDef) -> List[str]:
    """Static argnames if ``fn`` is decorated with a jitting wrapper."""
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        callee = dec.func
        # functools.partial(jax.jit, static_argnames=...)
        is_partial = (isinstance(callee, ast.Attribute)
                      and callee.attr == "partial") or (
                          isinstance(callee, ast.Name)
                          and callee.id == "partial")
        if is_partial and dec.args and _is_jax_jit(dec.args[0]):
            return _static_names_from_call(dec, fn)
        # @jax.jit(static_argnames=...)
        if _is_jax_jit(callee):
            return _static_names_from_call(dec, fn)
    return []


def _defaults_of(fn: ast.FunctionDef) -> dict:
    """param name -> default AST node (positional + kw-only)."""
    out = {}
    pos = fn.args.args
    for arg, d in zip(pos[len(pos) - len(fn.args.defaults):],
                      fn.args.defaults):
        out[arg.arg] = d
    for arg, d in zip(fn.args.kwonlyargs, fn.args.kw_defaults):
        if d is not None:
            out[arg.arg] = d
    return out


class _IsNoneFinder(ast.NodeVisitor):
    """Collects names compared against None (``x is None`` either way)."""

    def __init__(self):
        self.names = set()

    def visit_Compare(self, node: ast.Compare):
        operands = [node.left] + list(node.comparators)
        if any(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            has_none = any(isinstance(o, ast.Constant) and o.value is None
                           for o in operands)
            if has_none:
                self.names.update(o.id for o in operands
                                  if isinstance(o, ast.Name))
        self.generic_visit(node)


_MUTABLE_DEFAULTS = (ast.List, ast.Dict, ast.Set)


def lint_source(source: str, filename: str,
                context: str = "retrace-lint") -> List[Finding]:
    """AST-lint one module's source for static-jit-arg hazards."""
    findings: List[Finding] = []
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as e:
        findings.append(Finding(
            "ANALYSIS000", f"{rel_source_path(filename)}:<module>",
            f"could not parse: {e}", context))
        return findings

    # jitted via assignment: jf = jax.jit(f, static_argnames=...)
    assigned: dict = {}  # target fn name -> static names
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jax_jit(node.func):
            if node.args and isinstance(node.args[0], ast.Name):
                assigned.setdefault(node.args[0].id, []).extend(
                    _static_names_from_call(node, None))

    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        statics = _jit_static_names(node) + assigned.get(node.name, [])
        if not statics:
            continue
        site = f"{rel_source_path(filename)}:{node.name}"
        defaults = _defaults_of(node)
        none_cmp = _IsNoneFinder()
        for stmt in node.body:
            none_cmp.visit(stmt)
        for name in statics:
            d = defaults.get(name)
            if (isinstance(d, ast.Constant) and d.value is None) \
                    or name in none_cmp.names:
                how = ("defaults to None" if isinstance(d, ast.Constant)
                       and d.value is None else "is tested `is None` in the "
                       "jitted body")
                findings.append(Finding(
                    "RETRACE001", site,
                    f"static jit arg {name!r} {how}: the sentinel is the "
                    "cache key, so trace-time resolution freezes into the "
                    "jit cache (resolve outside the jit boundary, like "
                    "kernels.ops.resolve_interpret)", context))
            if isinstance(d, _MUTABLE_DEFAULTS):
                findings.append(Finding(
                    "RETRACE002", site,
                    f"static jit arg {name!r} has a non-hashable default "
                    f"({type(d).__name__.lower()} literal)", context))
    return findings


def lint_package(root: str, context: str = "retrace-lint") -> List[Finding]:
    """Lint every ``.py`` under ``root`` (a directory)."""
    findings: List[Finding] = []
    for dirpath, _dirnames, filenames in sorted(os.walk(root)):
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            with open(path, "r", encoding="utf-8") as f:
                findings.extend(lint_source(f.read(), path, context))
    return findings


# --------------------------------------------------------------------------
# trace pass
# --------------------------------------------------------------------------
def _is_affine_ramp(arr: np.ndarray) -> bool:
    """iota/arange-like: 1-D with constant stride (stride 0 = constant)."""
    if arr.ndim != 1 or arr.size < 2:
        return True
    if not np.issubdtype(arr.dtype, np.number):
        return False
    d = np.diff(arr.astype(np.float64))
    return bool((d == d[0]).all())


def _is_envelope_derived(arr: np.ndarray) -> bool:
    """Constants a shape-specialized program may legitimately bake in:
    constant fills and affine ramps (arange/iota and their reshapes) are
    pure functions of the static envelope."""
    if arr.size == 0:
        return True
    flat = arr.reshape(-1)
    if not np.issubdtype(arr.dtype, np.number):
        return bool((flat == flat[0]).all())
    if (flat == flat[0]).all():
        return True
    return _is_affine_ramp(flat)


def check_trace_constants(closed_jaxpr, context: str = "",
                          site: str = "plan:program") -> List[Finding]:
    """RETRACE003: large non-envelope-derived constants baked into a
    trace (see module docstring)."""
    findings: List[Finding] = []
    for arr in collect_consts(closed_jaxpr):
        if arr.size < CONST_ELEMS_THRESHOLD:
            continue
        if _is_envelope_derived(arr):
            continue
        findings.append(Finding(
            "RETRACE003", site,
            f"trace captured a concrete {arr.dtype}{list(arr.shape)} "
            "constant that is neither a fill nor an iota ramp: a "
            "closure-captured data array is frozen for every graph the "
            "plan ever serves — pass it as a program argument instead",
            context))
    return findings
