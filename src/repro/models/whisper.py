"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

Per the assignment, the modality frontend is a STUB: ``input_specs`` feeds
precomputed mel-frame embeddings [B, enc_seq, d_model] (what the two conv
layers would produce). Encoder: non-causal self-attention + GELU MLP.
Decoder: causal self-attention + cross-attention over encoder output + GELU
MLP. Sinusoidal positions on both sides (deviation from Whisper's learned
decoder positions, noted in DESIGN.md: the assigned decode shapes exceed the
original 448-position table). Decode caches self K/V per layer plus the
per-layer cross K/V computed once at prefill.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .params import ParamBuilder
from . import layers as L
from .transformer import (
    BlockSpec, _attn_init, _attn_full, _attn_decode, _mlp_part, _block_init,
)
from ..parallel.sharding import constrain

_SELF = BlockSpec("enc", "dense")        # non-causal, no rope
_DEC_SELF = BlockSpec("global", "dense")  # causal


def _sinusoid(t: int, d: int, dtype):
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(1, d // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _dec_block_init(b: ParamBuilder, cfg: ModelConfig):
    d = cfg.d_model
    b.zeros("ln1", (d,), ("embed",))
    _attn_init(b.child("attn"), cfg)
    b.zeros("ln_cross", (d,), ("embed",))
    _attn_init(b.child("cross"), cfg)
    b.zeros("ln2", (d,), ("embed",))
    L.mlp_init(b.child("mlp"), d, cfg.d_ff, cfg.act)
    return b


def init_encdec(cfg: ModelConfig, key: Optional[jax.Array]):
    dt = jnp.dtype(cfg.param_dtype)
    b = ParamBuilder(key, dt)
    e = cfg.encdec
    b.stacked_child("enc_blocks", e.enc_layers,
                    lambda bb: _block_init(bb.child("b0"), cfg, _SELF))
    b.zeros("enc_norm", (cfg.d_model,), ("embed",))
    b.dense("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
            scale=cfg.d_model ** -0.5)
    b.stacked_child("dec_blocks", cfg.num_layers,
                    lambda bb: _dec_block_init(bb.child("b0"), cfg))
    b.zeros("final_norm", (cfg.d_model,), ("embed",))
    return b.build()


def encode(cfg: ModelConfig, params, frames):
    """frames: [B, enc_seq, d_model] (conv-stub output) -> [B, enc_seq, d]."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]
    x = constrain(x, ("batch", "seq", None))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, gp):
        x, _, _ = _apply_enc(gp["b0"], cfg, x, positions)
        return constrain(x, ("batch", "seq", None)), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, params["enc_blocks"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _apply_enc(p, cfg, x, positions):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    y, _ = _attn_full(p["attn"], cfg, h, positions, _SELF, causal=False)
    x = x + y
    out, aux = _mlp_part(p, cfg, _SELF, x)
    return out, aux, None


def _apply_dec(p, cfg, x, positions, enc_out, cache=None):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    y, self_cache = _attn_full(p["attn"], cfg, h, positions, _DEC_SELF,
                               cache=cache.get("self") if cache else None)
    x = x + y
    hc = L.rms_norm(x, p["ln_cross"], cfg.norm_eps)
    dt = x.dtype
    q = jnp.einsum("btd,dhe->bthe", hc, p["cross"]["wq"].astype(dt))
    k = jnp.einsum("btd,dhe->bthe", enc_out, p["cross"]["wk"].astype(dt))
    v = jnp.einsum("btd,dhe->bthe", enc_out, p["cross"]["wv"].astype(dt))
    yc = L.flash_attention(
        q, k, v, q_positions=positions,
        kv_positions=jnp.zeros((k.shape[1],), jnp.int32), causal=False)
    x = x + jnp.einsum("bthe,hed->btd", yc, p["cross"]["wo"].astype(dt))
    out, _ = _mlp_part(p, cfg, _DEC_SELF, x)
    new_cache = None
    if cache is not None:
        new_cache = {"self": self_cache,
                     "cross_k": k.astype(cache["cross_k"].dtype),
                     "cross_v": v.astype(cache["cross_v"].dtype)}
    return out, new_cache


def forward(cfg: ModelConfig, params, tokens, frames, caches=None):
    """Joint encoder+decoder forward. Returns (logits, aux=0, caches|None)."""
    enc_out = encode(cfg, params, frames)
    t = tokens.shape[1]
    positions = jnp.arange(t, dtype=jnp.int32)
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # match d^-1/2 embed init
    x = x + _sinusoid(t, cfg.d_model, x.dtype)[None]
    x = constrain(x, ("batch", "seq", None))

    def body(x, inp):
        gp = inp["params"]
        gc = inp.get("cache")
        x, nc = _apply_dec(gp["b0"], cfg, x, positions, enc_out,
                           cache=gc["b0"] if gc else None)
        return constrain(x, ("batch", "seq", None)), nc

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    scan_inp = {"params": params["dec_blocks"]}
    if caches is not None:
        scan_inp["cache"] = caches["blocks"]
    x, ncaches = lax.scan(body, x, scan_inp)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", x, params["embed"].T.astype(x.dtype))
    logits = constrain(logits, ("batch", None, "vocab"))
    new_caches = None
    if caches is not None:
        new_caches = {"blocks": {"b0": ncaches},
                      "cur_len": jnp.full((tokens.shape[0],), t, jnp.int32)}
    return logits, jnp.zeros((), jnp.float32), new_caches


def decode_step(cfg: ModelConfig, params, caches, tokens):
    """One decoder token against self+cross caches. tokens [B]."""
    cur_len = caches["cur_len"]
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens[:, None]]
    x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    t_pos = _sinusoid_at(cur_len, cfg.d_model, dt)
    x = x + t_pos[:, None]

    def body(x, inp):
        gp, gc = inp["params"]["b0"], inp["cache"]["b0"]
        h = L.rms_norm(x, gp["ln1"], cfg.norm_eps)
        y, self_c = _attn_decode(gp["attn"], cfg, h, gc["self"], cur_len, _DEC_SELF)
        x = x + y
        hc = L.rms_norm(x, gp["ln_cross"], cfg.norm_eps)
        q = jnp.einsum("btd,dhe->bthe", hc, gp["cross"]["wq"].astype(x.dtype))
        yc = L.cache_attention(q, gc["cross_k"], gc["cross_v"],
                               cur_len=jnp.full((x.shape[0],), gc["cross_k"].shape[1], jnp.int32))
        x = x + jnp.einsum("bthe,hed->btd", yc, gp["cross"]["wo"].astype(x.dtype))
        x, _ = _mlp_part(gp, cfg, _DEC_SELF, x)
        return x, {"self": self_c, "cross_k": gc["cross_k"], "cross_v": gc["cross_v"]}

    x, ncaches = lax.scan(
        body, x, {"params": params["dec_blocks"], "cache": caches["blocks"]})
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", x, params["embed"].T.astype(x.dtype))[:, 0]
    return logits, {"blocks": {"b0": ncaches}, "cur_len": cur_len + 1}


def _sinusoid_at(positions, d, dtype):
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(1, d // 2 - 1)))
    ang = positions[:, None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """Abstract decoder cache tree (self KV + cross KV per layer)."""
    kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = jnp.dtype(cfg.dtype)
    e = cfg.encdec
    per = {
        "self": {"k": jax.ShapeDtypeStruct((batch, max_len, kh, hd), cdt),
                 "v": jax.ShapeDtypeStruct((batch, max_len, kh, hd), cdt)},
        "cross_k": jax.ShapeDtypeStruct((batch, e.enc_seq, kh, hd), cdt),
        "cross_v": jax.ShapeDtypeStruct((batch, e.enc_seq, kh, hd), cdt),
    }
    per_axes = {
        "self": {"k": ("cache_batch", "cache_seq", "kv_heads", None),
                 "v": ("cache_batch", "cache_seq", "kv_heads", None)},
        "cross_k": ("cache_batch", None, "kv_heads", None),
        "cross_v": ("cache_batch", None, "kv_heads", None),
    }
    stack = lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape, s.dtype)
    shapes = {"blocks": {"b0": jax.tree.map(
        stack, per, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))},
        "cur_len": jax.ShapeDtypeStruct((batch,), jnp.int32)}
    axes = {"blocks": {"b0": jax.tree.map(
        lambda a: ("layers",) + tuple(a), per_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e2 is None or isinstance(e2, str) for e2 in x))},
        "cur_len": ("cache_batch",)}
    return shapes, axes
