"""Hypothesis property tests on the coloring system's invariants.

Skipped cleanly (not a collection error) where ``hypothesis`` is absent;
``requirements.txt`` pins it for environments that install dev deps.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Graph, greedy_color, color_iterative, color_dataflow,
                        validate_coloring)
from repro.core.mex import segment_mex

import jax.numpy as jnp


@st.composite
def random_graphs(draw, max_v=40, max_e=120):
    n = draw(st.integers(2, max_v))
    m = draw(st.integers(0, max_e))
    edges = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
        min_size=m, max_size=m))
    return Graph.from_edges(n, np.array(edges or [[0, 0]], dtype=np.int64))


@settings(max_examples=40, deadline=None)
@given(random_graphs())
def test_greedy_always_valid(g):
    colors = greedy_color(g)
    assert validate_coloring(g, colors)
    assert colors.max() <= g.max_degree() + 1


@settings(max_examples=25, deadline=None)
@given(random_graphs(), st.sampled_from([1, 3, 7, 64]),
       st.sampled_from(["sort", "bitmap"]))
def test_iterative_always_valid(g, p, engine):
    res = color_iterative(g.to_device(), concurrency=p, max_rounds=128,
                          engine=engine)
    assert validate_coloring(g, np.asarray(res.colors))


@settings(max_examples=25, deadline=None)
@given(random_graphs())
def test_dataflow_equals_serial(g):
    res = color_dataflow(g.to_device())
    np.testing.assert_array_equal(np.asarray(res.colors), greedy_color(g))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, 12)),
                min_size=1, max_size=60))
def test_segment_mex_matches_python(pairs):
    """Sorted-gap mex == straightforward python mex."""
    n = 10
    v = jnp.asarray([p[0] for p in pairs] + list(range(n)), jnp.int32)
    c = jnp.asarray([p[1] for p in pairs] + [0] * n, jnp.int32)
    got = np.asarray(segment_mex(v, c, n))
    for vid in range(n):
        present = {cc for (vv, cc) in pairs if vv == vid} | {0}
        mex = 1
        while mex in present:
            mex += 1
        assert got[vid] == mex


@settings(max_examples=20, deadline=None)
@given(random_graphs())
def test_permutation_equivariance(g):
    """Relabeling vertices permutes the dataflow coloring accordingly
    (greedy follows index order, so colors map through the permutation)."""
    perm = np.random.default_rng(0).permutation(g.num_vertices).astype(np.int64)
    g2 = g.relabel(perm)
    c1 = greedy_color(g)   # color of old vertex i
    c2 = greedy_color(g2)  # color of new vertex perm[i]
    # not necessarily equal colors (order changed), but both valid and
    # within the same Delta+1 bound
    assert validate_coloring(g2, c2)
    assert c2.max() <= g.max_degree() + 1
