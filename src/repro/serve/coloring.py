"""The coloring service — async admission, deadline batching, restartable.

The serving face of the paper's claim (§Alg.1/§6) that speculate-and-
iterate coloring holds up under real concurrency pressure. Two front ends
share one compiled-plan LRU (:class:`PlanCache`, keyed by the ``(spec,
PlanShape)`` *bucket envelope* of a request so a whole graph family rides
ONE jitted program):

* :class:`ColoringService` — the synchronous in-process server (PR 5's
  API, kept bit-compatible): ``color``/``color_batch`` with vmapped
  same-key micro-batching and flush-atomic stats.
* :class:`AsyncColoringService` — the production shape. ``submit`` is
  **admission**, not execution: requests land on per-tenant FIFO queues
  behind a bounded global depth (overflow raises :class:`AdmissionError`
  — backpressure, not an unbounded heap). A scheduler turn
  (:meth:`~AsyncColoringService.pump`, driven inline, by
  :meth:`~AsyncColoringService.start`'s worker thread, or by a test with
  a fake clock) moves work in two steps:

  1. **deficit round-robin** over tenant queues — each backlogged tenant
     admits at most ``tenant_quantum`` requests per turn into the open
     micro-batches, so one flooding tenant cannot starve the rest (the
     optimistic-admission framing of Taş et al. arXiv:1701.02628: admit
     speculatively, account after the fact);
  2. **deadline flushing** — an open batch (same ``(spec, envelope)``
     key) flushes when it reaches ``max_batch`` (reason ``"size"``) OR
     when its oldest request ages past ``max_delay_s`` (reason
     ``"deadline"``), replacing PR 5's same-key-arrival-only coalescing;
     ``drain()`` force-flushes the rest (reason ``"drain"``).

  Per-tenant **streams** (:meth:`~AsyncColoringService.open_stream` /
  :meth:`~AsyncColoringService.submit_delta`) ride the same queues: edge
  deltas interleave fairly with coloring requests, and apply to the
  tenant's :class:`repro.core.dynamic.DynamicColoring` strictly in
  submission order. :meth:`~AsyncColoringService.checkpoint` snapshots
  every stream (as a jax pytree, via ``repro.train.checkpoint``) plus the
  cumulative metrics; :meth:`~AsyncColoringService.restore` resumes a
  killed server **bit-identically** — the Rokos detect-and-recolor repair
  (arXiv:1505.04086) is the unit of restartable work, and the restored
  plan recompiles against the checkpointed envelope so every subsequent
  repair reproduces the unkilled run's colors exactly (pinned across all
  four engines in ``tests/test_serve_faults.py``).

Observability is always on: a :class:`repro.serve.metrics.WindowedMetrics`
tracks windowed p50/p99 latency, cache hit rate, retrace count and the
flush-reason histogram, committed atomically per flush.

CLI (``python -m repro.serve``):

    PYTHONPATH=src python -m repro.serve --smoke
    PYTHONPATH=src python -m repro.serve --scale 10 --requests 48 \\
        --tenants 3 --batch 8 --deadline-ms 20 --stream-batches 4
"""
from __future__ import annotations

import argparse
import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.api import (ColoringPlan, ColoringReport, ColoringSpec,
                        PlanShape, _plan_shape, compile_plan)
from ..core.dynamic import DeltaReport, DynamicColoring
from .metrics import WindowedMetrics

Request = Union[object, Tuple[object, ColoringSpec]]  # graph | (graph, spec)


def _latency_summary(lat_s: Sequence[float]) -> dict:
    if not lat_s:
        return {"count": 0}
    a = np.asarray(lat_s, np.float64) * 1e3
    return {
        "count": int(a.size),
        "mean_ms": float(a.mean()),
        "p50_ms": float(np.percentile(a, 50)),
        "p95_ms": float(np.percentile(a, 95)),
        "max_ms": float(a.max()),
    }


# --------------------------------------------------------------------------
# the shared plan cache
# --------------------------------------------------------------------------
class PlanCache:
    """LRU of compiled :class:`ColoringPlan`s keyed ``(spec, envelope)`` —
    the one cache both service front ends share.

    Pure mechanism: lookups return ``(plan, was_hit, evictions)`` and
    mutate NO statistics — callers commit hit/miss/eviction counters
    atomically per flush (the accounting discipline
    ``tests/test_serve_coloring.py`` pins)."""

    def __init__(self, cache_size: int = 32):
        if cache_size < 1:
            raise ValueError("cache_size must be >= 1")
        self.cache_size = int(cache_size)
        self._plans: "OrderedDict[Tuple[ColoringSpec, PlanShape], ColoringPlan]" = OrderedDict()
        self._lock = threading.Lock()

    def envelope(self, spec: ColoringSpec, graph) -> PlanShape:
        """The bucket envelope a request is served under (== cache key
        shape): constraint-space vertex count, pad_bucket edge capacity,
        and the max-degree bound rounded up to a full power-of-two octave
        (floored at 8). Degree is quantized much more coarsely than edges
        on purpose: max-degree jitter across one graph family spans tens
        of percent (R-MAT hubs), and an oversized color table is cheap
        next to the retrace a fragmented cache key would cost."""
        raw = _plan_shape(spec, graph)
        d = int(raw.max_degree)
        return PlanShape(
            num_vertices=raw.num_vertices,
            padded_edges=raw.padded_edges,
            max_degree=max(8, 1 << (d - 1).bit_length()) if d > 0 else d)

    def get(self, spec: ColoringSpec, graph_or_shape
            ) -> Tuple[ColoringPlan, bool, int]:
        """The cached plan serving ``(spec, envelope)`` — compiled on
        first use, LRU-refreshed on every hit. Returns
        ``(plan, was_hit, evictions)``. Compilation happens outside the
        cache lock (it is the slow path)."""
        shape = (graph_or_shape if isinstance(graph_or_shape, PlanShape)
                 else self.envelope(spec, graph_or_shape))
        key = (spec, shape)
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self._plans.move_to_end(key)
                return plan, True, 0
        plan = compile_plan(spec, shape)
        with self._lock:
            raced = self._plans.get(key)
            if raced is not None:
                return raced, True, 0
            self._plans[key] = plan
            evicted = 0
            while len(self._plans) > self.cache_size:
                self._plans.popitem(last=False)
                evicted += 1
        return plan, False, evicted

    def __len__(self) -> int:
        return len(self._plans)


# --------------------------------------------------------------------------
# the synchronous service (PR 5 API, flush-atomic stats)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServedReport:
    """One served request: the report plus the service-side bookkeeping
    (which cache key it rode, whether the plan was compiled for it, and
    whether it went through a vmapped micro-batch)."""

    report: ColoringReport
    key: Tuple[ColoringSpec, PlanShape]
    cache_hit: bool
    batched: bool
    latency_s: float


class ColoringService:
    """An in-process coloring server with a compiled-plan LRU cache.

    cache_size   max resident plans; least-recently-used plans evict.
    default_spec spec applied to bare-graph requests (default:
                 ``ColoringSpec()`` — iterative/d1/sort).
    clock        monotonic float-seconds callable (injectable — tests
                 drive a fake clock; default ``time.perf_counter``).

    Stats discipline: latency/cache counters commit **atomically per
    flush** through :meth:`_commit` — one locked update per ``color``
    call or per ``color_batch`` group, never per enqueue. A concurrent
    ``stats()`` reader therefore always sees a consistent snapshot
    (requests == recorded latencies); the deterministic-clock test pins
    the granularity.
    """

    def __init__(self, *, cache_size: int = 32,
                 default_spec: Optional[ColoringSpec] = None,
                 latency_window: int = 4096,
                 clock: Optional[Callable[[], float]] = None):
        self.default_spec = default_spec or ColoringSpec()
        self._cache = PlanCache(cache_size=cache_size)
        self._clock = clock or time.perf_counter
        # sliding latency window: a long-lived service must not grow one
        # float per request forever, and stats() must not re-percentile an
        # unbounded history — counters/throughput stay exact over the full
        # lifetime, percentiles cover the last `latency_window` requests
        self._lat: deque = deque(maxlen=int(latency_window))
        self._counters = dict(requests=0, cache_hits=0, cache_misses=0,
                              evictions=0, batched_requests=0,
                              micro_batches=0)
        self._t_serving = 0.0
        self._lock = threading.Lock()

    # ------------------------------------------------------------- the cache
    @property
    def cache_size(self) -> int:
        return self._cache.cache_size

    def envelope(self, spec: ColoringSpec, graph) -> PlanShape:
        """The bucket envelope a request is served under (== cache key
        shape); see :meth:`PlanCache.envelope`."""
        return self._cache.envelope(spec, graph)

    def plan_for(self, spec: ColoringSpec, graph_or_shape
                 ) -> Tuple[ColoringPlan, bool]:
        """The cached plan serving ``(spec, envelope)``. Returns
        ``(plan, was_cache_hit)``; the lookup's cache counters commit as
        one atomic update."""
        plan, hit, ev = self._cache.get(spec, graph_or_shape)
        self._commit(hits=int(hit), misses=int(not hit), evictions=ev)
        return plan, hit

    # ----------------------------------------------------------- the serving
    def _norm(self, req: Request) -> Tuple[object, ColoringSpec]:
        if isinstance(req, tuple) and len(req) == 2 \
                and isinstance(req[1], ColoringSpec):
            return req
        return req, self.default_spec

    def color(self, graph, spec: Optional[ColoringSpec] = None,
              **runtime) -> ServedReport:
        """Serve one request (``runtime`` kwargs flow to the plan — e.g.
        the ``"recolor"`` strategy's ``colors=``/``seed=`` warm start)."""
        spec = spec or self.default_spec
        t0 = self._clock()
        plan, hit, ev = self._cache.get(spec, graph)
        report = plan(graph, **runtime)
        dt = self._clock() - t0
        self._commit(n=1, latencies=(dt,), serving_s=dt, hits=int(hit),
                     misses=int(not hit), evictions=ev)
        return ServedReport(report=report, key=(spec, plan.statics),
                            cache_hit=hit, batched=False, latency_s=dt)

    def color_batch(self, requests: Sequence[Request]) -> list:
        """Serve a batch: requests sharing a cache key micro-batch through
        ONE vmapped ``plan.map`` program (strategies that support it);
        the rest loop over their cached plan. Results come back in
        submission order as :class:`ServedReport`s; stats commit once per
        flushed group."""
        reqs = [self._norm(r) for r in requests]
        groups: "OrderedDict[tuple, list]" = OrderedDict()
        for i, (g, spec) in enumerate(reqs):
            key = (spec, self.envelope(spec, g))
            groups.setdefault(key, []).append(i)
        out: list = [None] * len(reqs)
        for key, idxs in groups.items():
            spec, shape = key
            t0 = self._clock()
            plan, hit, ev = self._cache.get(spec, shape)
            if plan.strategy.supports_map and len(idxs) > 1:
                reports = plan.map([reqs[i][0] for i in idxs])
                dt = self._clock() - t0
                per = dt / len(idxs)
                for i, rep in zip(idxs, reports):
                    out[i] = ServedReport(report=rep, key=key,
                                          cache_hit=hit, batched=True,
                                          latency_s=per)
                self._commit(n=len(idxs), latencies=[per] * len(idxs),
                             serving_s=dt, hits=int(hit),
                             misses=int(not hit), evictions=ev,
                             micro_batches=1, batched=len(idxs))
            else:
                lats: List[float] = []
                for j, i in enumerate(idxs):
                    t1 = self._clock()
                    rep = plan(reqs[i][0])
                    now = self._clock()
                    # the group's first request carries the plan lookup /
                    # compile cost, matching color() and the map path —
                    # stats stay comparable across serving paths
                    d1 = (now - t0) if j == 0 else (now - t1)
                    lats.append(d1)
                    out[i] = ServedReport(report=rep, key=key,
                                          cache_hit=hit or j > 0,
                                          batched=False, latency_s=d1)
                self._commit(n=len(idxs), latencies=lats,
                             serving_s=sum(lats), hits=int(hit),
                             misses=int(not hit), evictions=ev)
        return out

    def _commit(self, *, n: int = 0, latencies: Sequence[float] = (),
                serving_s: float = 0.0, hits: int = 0, misses: int = 0,
                evictions: int = 0, micro_batches: int = 0,
                batched: int = 0) -> None:
        """The ONE stats mutation point: every counter update for a flush
        (or a standalone plan lookup) lands in a single critical section.
        Per-enqueue mutation is exactly the race this class used to have —
        a reader between a latency append and its counter increment saw
        requests != latencies — so all paths route here."""
        with self._lock:
            c = self._counters
            c["requests"] += n
            c["cache_hits"] += hits
            c["cache_misses"] += misses
            c["evictions"] += evictions
            c["micro_batches"] += micro_batches
            c["batched_requests"] += batched
            self._lat.extend(latencies)
            self._t_serving += serving_s

    # -------------------------------------------------------------- the stats
    def stats(self) -> dict:
        """Aggregate service stats: request/cache counters, resident plan
        count, latency summary in ms (over the sliding ``latency_window``),
        and end-to-end throughput (over the full lifetime)."""
        with self._lock:
            s = dict(self._counters)
            lat = list(self._lat)
            t_serving = self._t_serving
        s["resident_plans"] = len(self._cache)
        s["latency"] = _latency_summary(lat)
        s["throughput_gps"] = (s["requests"] / t_serving
                               if t_serving > 0 else 0.0)
        return s


# --------------------------------------------------------------------------
# the async service
# --------------------------------------------------------------------------
class AdmissionError(RuntimeError):
    """Raised by ``submit``/``submit_delta`` when the global queue depth is
    at capacity — the caller sheds load or retries after a pump."""


class ServeHandle:
    """A pending request's completion handle.

    ``done`` flips when the request's flush resolves it; :meth:`result`
    returns the :class:`AsyncServed` (or raises the flush's error). With
    no timeout the request must already be served — ``pump()``/``drain()``
    the service, or ``start()`` its worker thread and pass a timeout."""

    __slots__ = ("_ev", "_out", "_err")

    def __init__(self):
        self._ev = threading.Event()
        self._out = None
        self._err: Optional[BaseException] = None

    def _resolve(self, out=None, err: Optional[BaseException] = None):
        self._out, self._err = out, err
        self._ev.set()

    @property
    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None):
        if timeout is not None:
            self._ev.wait(timeout)
        if not self._ev.is_set():
            raise RuntimeError(
                "request not served yet: pump()/drain() the service, or "
                "start() its worker thread and pass result(timeout=...)")
        if self._err is not None:
            raise self._err
        return self._out


@dataclasses.dataclass(frozen=True)
class AsyncServed:
    """One asynchronously served request: the result plus the scheduling
    facts (which flush reason released it, how long it queued)."""

    kind: str                    # "color" | "delta"
    tenant: str
    result: object               # ColoringReport | DeltaReport
    cache_hit: Optional[bool]    # None for stream deltas (no plan cache)
    batched: bool
    flush_reason: str
    queue_age_s: float           # enqueue -> flush start
    latency_s: float             # enqueue -> result ready

    @property
    def report(self):
        return self.result


@dataclasses.dataclass
class _Pending:
    kind: str
    tenant: str
    key: tuple
    enqueue_t: float
    handle: ServeHandle
    graph: object = None
    spec: Optional[ColoringSpec] = None
    inserts: Optional[np.ndarray] = None
    deletes: Optional[np.ndarray] = None


class AsyncColoringService:
    """Async, multi-tenant, observable, restartable coloring service.

    default_spec     spec for bare ``submit`` calls;
    cache_size       resident compiled plans (LRU);
    max_queue_depth  bound on requests admitted but not yet flushed —
                     ``submit`` raises :class:`AdmissionError` beyond it;
    tenant_quantum   DRR quantum: requests a backlogged tenant may admit
                     into open batches per scheduler turn;
    max_batch        micro-batch size that triggers a ``"size"`` flush;
    max_delay_s      the deadline budget: an open batch older than this
                     flushes on the next turn (reason ``"deadline"``).
                     The service-level guarantee — asserted by
                     ``serve_bench`` — is that no request's queue age
                     exceeds ``max_delay_s`` plus one in-flight flush
                     (``metrics`` records ``max_exec_s``, the stall bound);
    clock            injectable monotonic clock (fake-clock tests);
    metrics          a :class:`WindowedMetrics` (default: fresh, on the
                     same clock).

    Drive it inline (``pump()`` per scheduler turn, ``drain()`` to
    finish), or call ``start()`` for a background worker thread.
    """

    def __init__(self, *, default_spec: Optional[ColoringSpec] = None,
                 cache_size: int = 32, max_queue_depth: int = 1024,
                 tenant_quantum: int = 4, max_batch: int = 8,
                 max_delay_s: float = 0.005,
                 clock: Optional[Callable[[], float]] = None,
                 metrics: Optional[WindowedMetrics] = None,
                 stream_edge_headroom: float = 1.5,
                 stream_degree_headroom: float = 1.5):
        if max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if tenant_quantum < 1:
            raise ValueError("tenant_quantum must be >= 1")
        self.default_spec = default_spec or ColoringSpec()
        self.plans = PlanCache(cache_size=cache_size)
        self.max_queue_depth = int(max_queue_depth)
        self.tenant_quantum = int(tenant_quantum)
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self._clock = clock or time.perf_counter
        self.metrics = metrics or WindowedMetrics(clock=self._clock)
        self._stream_headroom = (float(stream_edge_headroom),
                                 float(stream_degree_headroom))
        self._lock = threading.Lock()        # queues/batches/depth state
        self._pump_lock = threading.Lock()   # serializes flush drivers
        self._queues: "OrderedDict[str, deque]" = OrderedDict()
        self._deficit: Dict[str, float] = {}
        self._open: "OrderedDict[tuple, List[_Pending]]" = OrderedDict()
        self._depth = 0
        self._streams: Dict[str, DynamicColoring] = {}
        self._stream_specs: Dict[str, ColoringSpec] = {}
        self._stream_tr: Dict[str, int] = {}
        self.tenant_served: Dict[str, int] = {}
        self._ckpt_step = -1
        self._thread: Optional[threading.Thread] = None
        self._stop_ev = threading.Event()

    # ------------------------------------------------------------- admission
    @property
    def backlog(self) -> int:
        """Requests admitted but not yet flushed (queued + open batches)."""
        return self._depth

    def _enqueue(self, p: _Pending) -> ServeHandle:
        with self._lock:
            if self._depth >= self.max_queue_depth:
                self.metrics.record_rejected()
                raise AdmissionError(
                    f"queue depth {self._depth} at capacity "
                    f"{self.max_queue_depth}; pump()/drain() or shed load")
            self._queues.setdefault(p.tenant, deque()).append(p)
            self._deficit.setdefault(p.tenant, 0.0)
            self._depth += 1
        return p.handle

    def submit(self, graph, spec: Optional[ColoringSpec] = None, *,
               tenant: str = "default") -> ServeHandle:
        """Admit one coloring request onto ``tenant``'s queue. Returns a
        :class:`ServeHandle` immediately; the request executes in a later
        flush (micro-batched with same-``(spec, envelope)`` peers)."""
        spec = spec or self.default_spec
        key = ("color", spec, self.plans.envelope(spec, graph))
        return self._enqueue(_Pending(
            kind="color", tenant=tenant, key=key, enqueue_t=self._clock(),
            handle=ServeHandle(), graph=graph, spec=spec))

    def submit_delta(self, tenant: str, inserts=None,
                     deletes=None) -> ServeHandle:
        """Admit one edge-delta batch for ``tenant``'s open stream. Deltas
        ride the same tenant queue as coloring requests (fair interleaving)
        and apply to the stream strictly in submission order."""
        if tenant not in self._streams:
            raise KeyError(f"no open stream for tenant {tenant!r}; call "
                           "open_stream first")
        return self._enqueue(_Pending(
            kind="delta", tenant=tenant, key=("stream", tenant),
            enqueue_t=self._clock(), handle=ServeHandle(),
            inserts=None if inserts is None else np.asarray(inserts),
            deletes=None if deletes is None else np.asarray(deletes)))

    # --------------------------------------------------------------- streams
    def open_stream(self, tenant: str, graph,
                    spec: Optional[ColoringSpec] = None,
                    **dyn_kwargs) -> DynamicColoring:
        """Open ``tenant``'s streaming session: cold-start a
        :class:`DynamicColoring` (synchronously — the initial coloring is
        the session's creation cost) that subsequent ``submit_delta``
        batches repair incrementally. One stream per tenant."""
        if tenant in self._streams:
            raise ValueError(f"tenant {tenant!r} already has an open stream")
        if "/" in tenant or "__" in tenant:
            raise ValueError("tenant names must avoid '/' and '__' (the "
                             f"checkpoint path encoding): {tenant!r}")
        spec = spec or ColoringSpec(strategy="recolor",
                                    engine=self.default_spec.engine)
        eh, dh = self._stream_headroom
        dyn_kwargs.setdefault("edge_headroom", eh)
        dyn_kwargs.setdefault("degree_headroom", dh)
        dyn = DynamicColoring(graph, spec, **dyn_kwargs)
        self._streams[tenant] = dyn
        self._stream_specs[tenant] = spec
        self._stream_tr[tenant] = dyn.plan.traces + dyn.recompiles
        return dyn

    def stream(self, tenant: str) -> DynamicColoring:
        """The live stream session for ``tenant`` (read access: ``.graph``,
        ``.colors``, ``.num_colors``...)."""
        return self._streams[tenant]

    @property
    def stream_tenants(self) -> Tuple[str, ...]:
        return tuple(sorted(self._streams))

    # ------------------------------------------------------------- scheduling
    def _admit(self) -> None:
        """One deficit-round-robin cycle: every backlogged tenant gains
        ``tenant_quantum`` deficit and admits that many requests (FIFO)
        from its queue into the open batches. Idle tenants' deficit resets
        — DRR's classic rule, so quiet tenants don't bank unfair bursts."""
        for tenant in list(self._queues):
            q = self._queues[tenant]
            if not q:
                self._deficit[tenant] = 0.0
                continue
            self._deficit[tenant] += self.tenant_quantum
            take = min(len(q), int(self._deficit[tenant]))
            for _ in range(take):
                p = q.popleft()
                self._open.setdefault(p.key, []).append(p)
            self._deficit[tenant] -= take

    def _take_due(self, force: bool) -> List[Tuple[tuple, list, str]]:
        """Pop every batch that must flush: full ``max_batch`` chunks
        (reason ``"size"``), batches whose oldest request aged past
        ``max_delay_s`` (``"deadline"``), and — under ``force`` — whatever
        remains (``"drain"``). Order within a key is always preserved."""
        out: List[Tuple[tuple, list, str]] = []
        now = self._clock()
        for key in list(self._open):
            batch = self._open[key]
            while len(batch) >= self.max_batch:
                out.append((key, batch[:self.max_batch], "size"))
                batch = batch[self.max_batch:]
            if batch:
                if now - batch[0].enqueue_t >= self.max_delay_s:
                    out.append((key, batch, "deadline"))
                    batch = []
                elif force:
                    out.append((key, batch, "drain"))
                    batch = []
            if batch:
                self._open[key] = batch
            else:
                del self._open[key]
        return out

    def pump(self) -> int:
        """One scheduler turn: DRR-admit, then flush every due batch.
        Returns the number of requests flushed. Safe to call from one
        driver at a time (a worker thread or the submitting thread);
        drivers serialize on an internal lock."""
        with self._pump_lock:
            with self._lock:
                self._admit()
                due = self._take_due(force=False)
            n = 0
            for key, batch, reason in due:
                n += self._flush(key, batch, reason)
            return n

    def drain(self) -> int:
        """Serve everything admitted so far: repeat scheduler turns with
        forced flushing until no work remains. Returns requests served."""
        total = 0
        while True:
            with self._pump_lock:
                with self._lock:
                    self._admit()
                    due = self._take_due(force=True)
                    empty = not due and self._depth == 0
                for key, batch, reason in due:
                    total += self._flush(key, batch, reason)
            if not due:
                if empty:
                    return total
                # tenant queues still hold work beyond this cycle's deficit
                continue

    # ---------------------------------------------------------- the executor
    def _flush(self, key: tuple, batch: List[_Pending], reason: str) -> int:
        """Execute one micro-batch and commit its metrics atomically."""
        t0 = self._clock()
        try:
            if key[0] == "color":
                served = self._flush_color(key, batch, reason, t0)
            else:
                served = self._flush_stream(key, batch, reason, t0)
        except Exception as e:  # resolve every handle; the service survives
            for p in batch:
                if not p.handle.done:
                    p.handle._resolve(err=e)
            served = 0
        with self._lock:
            self._depth -= len(batch)
            for p in batch:
                self.tenant_served[p.tenant] = \
                    self.tenant_served.get(p.tenant, 0) + 1
        return served

    def _flush_color(self, key, batch, reason, t0) -> int:
        _, spec, shape = key
        plan, hit, _ = self.plans.get(spec, shape)
        tr0 = plan.traces
        use_map = len(batch) > 1 and plan.strategy.supports_map
        if use_map:
            # pad the vmapped batch to the fixed max_batch shape (repeat
            # the tail graph, discard its extra reports): deadline flushes
            # release batches at ANY occupancy, and letting each size jit
            # its own map program would retrace mid-flush — a multi-second
            # stall the deadline budget can't absorb. One map program per
            # envelope, ever.
            gs = [p.graph for p in batch]
            gs += [gs[-1]] * (self.max_batch - len(gs))
            reports = plan.map(gs)[:len(batch)]
        else:
            reports = [plan(p.graph) for p in batch]
        t1 = self._clock()
        lats = [t1 - p.enqueue_t for p in batch]
        ages = [t0 - p.enqueue_t for p in batch]
        for p, rep, lat, age in zip(batch, reports, lats, ages):
            p.handle._resolve(AsyncServed(
                kind="color", tenant=p.tenant, result=rep, cache_hit=hit,
                batched=use_map, flush_reason=reason, queue_age_s=age,
                latency_s=lat))
        self.metrics.record_flush(
            reason, latencies=lats, queue_ages=ages, exec_s=t1 - t0,
            cache_hit=hit, retraces=plan.traces - tr0, batched=use_map)
        return len(batch)

    def _flush_stream(self, key, batch, reason, t0) -> int:
        tenant = key[1]
        dyn = self._streams[tenant]
        outs = []
        for p in batch:  # strictly in submission order — stream semantics
            outs.append(dyn.apply_batch(inserts=p.inserts,
                                        deletes=p.deletes))
        t1 = self._clock()
        lats = [t1 - p.enqueue_t for p in batch]
        ages = [t0 - p.enqueue_t for p in batch]
        for p, dr, lat, age in zip(batch, outs, lats, ages):
            p.handle._resolve(AsyncServed(
                kind="delta", tenant=tenant, result=dr, cache_hit=None,
                batched=len(batch) > 1, flush_reason=reason,
                queue_age_s=age, latency_s=lat))
        tr = dyn.plan.traces + dyn.recompiles
        retraces = max(0, tr - self._stream_tr[tenant])
        self._stream_tr[tenant] = tr
        self.metrics.record_flush(
            reason, latencies=lats, queue_ages=ages, exec_s=t1 - t0,
            retraces=retraces, batched=len(batch) > 1, stream=True)
        return len(batch)

    # ------------------------------------------------------------ the worker
    def start(self, tick_s: float = 0.001) -> None:
        """Spawn the background scheduler thread (pumps until
        :meth:`stop`). Don't combine with a fake clock — deadline ages
        would never advance."""
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._stop_ev.clear()

        def loop():
            while not self._stop_ev.is_set():
                if self.pump() == 0:
                    self._stop_ev.wait(tick_s)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="coloring-serve")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop_ev.set()
        self._thread.join()
        self._thread = None

    # --------------------------------------------------------- checkpointing
    def checkpoint(self, root: str, *, step: Optional[int] = None,
                   keep: int = 3) -> int:
        """Snapshot every tenant stream + the cumulative metrics to
        ``root`` (atomic, via ``repro.train.checkpoint.save``). Only
        quiescent state checkpoints: the backlog must be zero (``drain()``
        first) — queued request graphs are caller-owned and not part of
        the restartable state. Returns the checkpoint step."""
        if self.backlog:
            raise RuntimeError(
                f"cannot checkpoint with {self.backlog} requests in "
                "flight; drain() first")
        from ..train import checkpoint as ckpt
        if step is None:
            step = self._ckpt_step + 1
        tree = {
            "streams": {t: dyn.state_dict()
                        for t, dyn in self._streams.items()},
            "metrics": self.metrics.state_dict(),
        }
        meta = {
            "schema": 1,
            "stream_specs": {t: s.to_dict()
                             for t, s in self._stream_specs.items()},
        }
        ckpt.save(root, step, tree, keep=keep, meta=meta)
        self._ckpt_step = step
        return step

    @classmethod
    def restore(cls, root: str, *, step: Optional[int] = None,
                **kwargs) -> "AsyncColoringService":
        """Rebuild a service from :meth:`checkpoint` output: every tenant
        stream resumes bit-identically (colors, graph, plan envelope,
        palette bound) and the cumulative metrics counters continue from
        their checkpointed values. ``kwargs`` are the service's process
        config (``max_batch``, ``max_delay_s``, ... — deliberately not
        checkpointed)."""
        from ..train import checkpoint as ckpt
        tree, manifest, step = ckpt.load(root, step=step)
        meta = manifest.get("meta", {})
        if meta.get("schema") != 1:
            raise ValueError(f"unknown service checkpoint schema in {root}: "
                             f"{meta.get('schema')!r}")
        self = cls(**kwargs)
        self.metrics.load_state(tree.get("metrics", {}))
        for tenant, state in tree.get("streams", {}).items():
            spec = ColoringSpec.from_dict(meta["stream_specs"][tenant])
            dyn = DynamicColoring.from_state(state, spec)
            self._streams[tenant] = dyn
            self._stream_specs[tenant] = spec
            self._stream_tr[tenant] = dyn.plan.traces + dyn.recompiles
        self._ckpt_step = step
        return self


# ---------------------------------------------------------------- CLI smoke
def main(argv=None):
    ap = argparse.ArgumentParser(
        description="coloring service smoke: open-loop multi-tenant "
                    "serving through the async admission loop, then a "
                    "streaming + checkpoint/restore demo")
    ap.add_argument("--smoke", action="store_true",
                    help="small preset (scale 8, 16 requests)")
    ap.add_argument("--family", default="RMAT-G",
                    choices=["RMAT-ER", "RMAT-G", "RMAT-B"])
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8,
                    help="micro-batch size (the 'size' flush trigger)")
    ap.add_argument("--deadline-ms", type=float, default=20.0,
                    help="deadline flush budget per open batch")
    ap.add_argument("--queue-depth", type=int, default=256)
    ap.add_argument("--strategy", default="dataflow")
    ap.add_argument("--engine", default="sort")
    ap.add_argument("--cache-size", type=int, default=8)
    ap.add_argument("--stream-batches", type=int, default=4,
                    help="edge-delta batches for the streaming + restore "
                         "demo (0 disables)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for the restore demo (default: a "
                         "temporary directory)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.scale, args.requests = min(args.scale, 8), min(args.requests, 16)

    from ..core import rmat, validate_coloring

    spec = ColoringSpec(strategy=args.strategy, engine=args.engine,
                        concurrency=64)
    svc = AsyncColoringService(
        default_spec=spec, cache_size=args.cache_size,
        max_batch=args.batch, max_delay_s=args.deadline_ms / 1e3,
        max_queue_depth=args.queue_depth)
    graphs = [rmat.paper_graph(args.family, scale=args.scale, seed=s)
              for s in range(args.requests)]
    print(f"[serve] family={args.family} scale={args.scale} "
          f"requests={args.requests} tenants={args.tenants} "
          f"batch={args.batch} deadline={args.deadline_ms}ms "
          f"strategy={args.strategy} engine={args.engine}")

    t0 = time.perf_counter()
    handles = []
    for i, g in enumerate(graphs):
        while True:
            try:
                handles.append(svc.submit(g, tenant=f"t{i % args.tenants}"))
                break
            except AdmissionError:
                svc.pump()
        svc.pump()
    svc.drain()
    wall = time.perf_counter() - t0
    served = [h.result() for h in handles]
    for s_, g in zip(served, graphs):
        assert validate_coloring(g, s_.report.colors)
    snap = svc.metrics.snapshot()
    cum, win = snap["cumulative"], snap["window"]
    print(f"[serve] served {cum['requests']} requests in {wall:.2f}s "
          f"({cum['requests'] / wall:.1f} graphs/s) across "
          f"{len(svc.tenant_served)} tenants")
    print(f"[serve] flushes: {cum['flushes']} "
          f"(size={cum['flush_reasons']['size']} "
          f"deadline={cum['flush_reasons']['deadline']} "
          f"drain={cum['flush_reasons']['drain']}); "
          f"cache hit rate={snap['cache_hit_rate']:.2f}; "
          f"retraces={cum['retraces']}")
    if win["count"]:
        print(f"[serve] latency: p50={win['p50_ms']:.1f}ms "
              f"p99={win['p99_ms']:.1f}ms max={win['max_ms']:.1f}ms "
              f"(max includes the compile); max queue age "
              f"{cum['max_queue_age_s'] * 1e3:.1f}ms")

    if args.stream_batches > 0:
        g = graphs[0]
        rng = np.random.default_rng(0)
        svc.open_stream("stream", g,
                        ColoringSpec(strategy="recolor", engine=args.engine,
                                     concurrency=64))
        m = max(1, g.num_edges // 100)  # ~1% edge-delta batches
        print(f"[serve] streaming: {args.stream_batches} delta batches of "
              f"~{m} inserts + ~{m} deletes (1% of |E|)")
        for b in range(args.stream_batches):
            V = g.num_vertices
            ins = np.stack([rng.integers(0, V, m),
                            rng.integers(0, V, m)], 1)
            cur = svc.stream("stream").graph.undirected_edges()
            dels = cur[rng.integers(0, cur.shape[0], m)]
            h = svc.submit_delta("stream", inserts=ins, deletes=dels)
            svc.drain()
            dr = h.result().result
            dyn = svc.stream("stream")
            assert validate_coloring(dyn.graph, dyn.colors)
            print(f"[serve]   batch {b}: +{dr.inserted}/-{dr.deleted} "
                  f"edges, seed={dr.seed_size}, repaired={dr.repaired}, "
                  f"colors={dyn.num_colors} (bound {dyn.color_bound}), "
                  f"{dr.wall_time_s * 1e3:.1f}ms")
        # the restart story, live: checkpoint, restore, bit-compare
        import tempfile
        root = args.checkpoint_dir or tempfile.mkdtemp(prefix="serve_ckpt_")
        step = svc.checkpoint(root)
        svc2 = AsyncColoringService.restore(
            root, default_spec=spec, max_batch=args.batch,
            max_delay_s=args.deadline_ms / 1e3)
        same = np.array_equal(svc.stream("stream").colors,
                              svc2.stream("stream").colors)
        print(f"[serve] checkpoint step {step} -> restore: "
              f"bit-identical colors={same}, metrics requests="
              f"{svc2.metrics.snapshot()['cumulative']['requests']}")
        assert same
        dyn = svc.stream("stream")
        print(f"[serve] streaming done: colors={dyn.num_colors}, "
              f"plan retraces={dyn.plan.traces} (1 = zero-retrace "
              f"repairs), recompiles={dyn.recompiles}")
    return svc


if __name__ == "__main__":
    main()
