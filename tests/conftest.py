import os
import sys

import pytest

# tests run on the single real CPU device; the dry-run (and only the
# dry-run) forces 512 host devices in its own process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# archs whose smoke train step / decode takes tens of seconds on CPU; their
# cases run under `-m slow`, keeping the default tier-1 suite fast. One
# shared set so the per-file slow selections can't drift apart.
SLOW_ARCHS = frozenset({
    "recurrentgemma-2b", "deepseek-v2-lite-16b", "llama-3.2-vision-11b",
    "whisper-medium", "grok-1-314b", "gemma2-2b",
})


def arch_params(arch_ids, slow_set=SLOW_ARCHS, extra_marks=None):
    """Parametrize ids, marking ``slow_set`` members slow (plus any
    per-arch ``extra_marks``: {arch: [marks]})."""
    out = []
    for a in arch_ids:
        marks = [pytest.mark.slow] if a in slow_set else []
        marks += (extra_marks or {}).get(a, [])
        out.append(pytest.param(a, marks=marks) if marks else a)
    return out


class FakeClock:
    """A deterministic monotonic clock for timing-sensitive tests.

    Inject as ``clock=`` into the serving layer (``ColoringService``,
    ``AsyncColoringService``, ``WindowedMetrics``) so deadline-flush and
    latency-percentile tests never ``sleep`` in tier-1: time advances only
    when the test says so (``tick``), and every latency/queue-age sample
    becomes an exact, assertable number."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def tick(self, dt: float) -> float:
        """Advance time by ``dt`` seconds and return the new reading."""
        self.t += float(dt)
        return self.t


@pytest.fixture
def fake_clock():
    """A fresh :class:`FakeClock` at t=0."""
    return FakeClock()
