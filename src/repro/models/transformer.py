"""Composable decoder stack covering the dense / MoE / SSM / hybrid / VLM
families (whisper's encoder-decoder lives in whisper.py on the same block
machinery).

An architecture is a (prefix, scanned-pattern × groups, suffix) list of
``BlockSpec(attn, mlp)``; the scanned groups run under ``lax.scan`` with
optional ``jax.checkpoint`` (remat), which keeps the HLO small, the compile
times sane at 512 devices, and the activation footprint = one group per
layer.

Modes: ``full`` (train forward / prefill with cache fill) and ``decode``
(one token against caches). Caches are plain pytrees so dry-run can lower
``decode_step`` from ShapeDtypeStructs without ever running prefill.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig, MoEConfig
from .params import ParamBuilder
from . import layers as L
from .moe import moe_init, moe_apply
from .mla import mla_init, mla_forward, mla_decode
from .mamba2 import mamba2_init, mamba2_forward, mamba2_decode, _dims as ssm_dims
from .rglru import rglru_init, rglru_forward, rglru_decode
from ..parallel.sharding import constrain


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    attn: str   # global | local | mla | ssd | rec | cross | enc
    mlp: str    # dense | moe | none

    @property
    def key(self) -> str:
        return f"{self.attn}_{self.mlp}"


def arch_blocks(cfg: ModelConfig):
    """(prefix, pattern, n_groups, suffix) of BlockSpecs for a config."""
    if cfg.family == "ssm":
        return [], [BlockSpec("ssd", "none")], cfg.num_layers, []
    if cfg.family == "hybrid":
        pat = [BlockSpec("rec", "dense"), BlockSpec("rec", "dense"),
               BlockSpec("local", "dense")]
        n = cfg.num_layers // len(pat)
        rest = cfg.num_layers - n * len(pat)
        suffix = [BlockSpec("rec", "dense")] * rest
        return [], pat, n, suffix
    if cfg.family == "vlm":
        v = cfg.vlm
        pat = [BlockSpec("global", "dense")] * (v.cross_every - 1) \
            + [BlockSpec("cross", "dense")]
        assert cfg.num_layers % v.cross_every == 0
        return [], pat, cfg.num_layers // v.cross_every, []
    if cfg.family == "moe":
        attn = "mla" if cfg.mla is not None else "global"
        nd = cfg.moe.first_dense_layers
        prefix = [BlockSpec(attn, "dense")] * nd
        return prefix, [BlockSpec(attn, "moe")], cfg.num_layers - nd, []
    # dense
    if cfg.layer_pattern == "local_global":
        pat = [BlockSpec("local", "dense"), BlockSpec("global", "dense")]
        assert cfg.num_layers % 2 == 0
        return [], pat, cfg.num_layers // 2, []
    return [], [BlockSpec("global", "dense")], cfg.num_layers, []


# ------------------------------------------------------------------ init
def _attn_init(b: ParamBuilder, cfg: ModelConfig, kv_axis="kv_heads"):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b.dense("wq", (d, h, hd), ("embed", "heads", None))
    b.dense("wk", (d, kh, hd), ("embed", kv_axis, None))
    b.dense("wv", (d, kh, hd), ("embed", kv_axis, None))
    b.dense("wo", (h, hd, d), ("heads", None, "embed"))
    if cfg.qk_norm:
        b.zeros("q_norm", (hd,), (None,))
        b.zeros("k_norm", (hd,), (None,))
    return b


def _cross_init(b: ParamBuilder, cfg: ModelConfig):
    _attn_init(b, cfg)
    b.zeros("gate_attn", (), ())
    b.zeros("gate_mlp", (), ())
    return b


def _block_init(b: ParamBuilder, cfg: ModelConfig, spec: BlockSpec):
    d = cfg.d_model
    b.zeros("ln1", (d,), ("embed",))
    if spec.attn in ("global", "local", "enc"):
        _attn_init(b.child("attn"), cfg)
    elif spec.attn == "cross":
        b.zeros("ln_cross", (d,), ("embed",))
        _cross_init(b.child("cross"), cfg)
        _attn_init(b.child("attn"), cfg)
    elif spec.attn == "mla":
        mla_init(b.child("attn"), cfg, cfg.mla)
    elif spec.attn == "ssd":
        mamba2_init(b.child("attn"), cfg, cfg.ssm)
    elif spec.attn == "rec":
        rglru_init(b.child("attn"), cfg, cfg.rglru)
    else:
        raise ValueError(spec.attn)
    if cfg.post_norms and spec.attn not in ("ssd",):
        b.zeros("ln1_post", (d,), ("embed",))
    if spec.mlp != "none":
        b.zeros("ln2", (d,), ("embed",))
        if spec.mlp == "moe":
            moe_init(b.child("mlp"), cfg, cfg.moe)
        else:
            dff = cfg.d_ff
            if cfg.moe is not None and cfg.moe.first_dense_d_ff:
                dff = cfg.moe.first_dense_d_ff
            L.mlp_init(b.child("mlp"), d, dff, cfg.act)
        if cfg.post_norms:
            b.zeros("ln2_post", (d,), ("embed",))
    return b


def init_lm(cfg: ModelConfig, key: Optional[jax.Array]):
    """Build (params, axes). ``key=None`` -> abstract (ShapeDtypeStruct)."""
    dt = jnp.dtype(cfg.param_dtype)
    b = ParamBuilder(key, dt)
    # embed std = d^-1/2: keeps tied logits ~unit-std (inputs re-scaled by
    # sqrt(d) when emb_scale is set, the gemma convention)
    b.dense("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
            scale=cfg.d_model ** -0.5)
    prefix, pattern, n_groups, suffix = arch_blocks(cfg)
    for i, spec in enumerate(prefix):
        _block_init(b.child(f"prefix{i}"), cfg, spec)
    b.stacked_child(
        "blocks", n_groups,
        lambda bb: [_block_init(bb.child(f"b{j}"), cfg, s)
                    for j, s in enumerate(pattern)])
    for i, spec in enumerate(suffix):
        _block_init(b.child(f"suffix{i}"), cfg, spec)
    b.zeros("final_norm", (cfg.d_model,), ("embed",))
    if not cfg.tie_embeddings:
        b.dense("lm_head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return b.build()


# ------------------------------------------------------------------ apply
def _attn_full(p, cfg, x, positions, spec, cache=None, *, causal=True):
    """Self attention over a full sequence; optionally fills a cache."""
    dt = x.dtype
    # gather the sequence-parallel residual ONCE here (Megatron-SP style);
    # without this XLA re-gathers inside the attention chunk loops
    x = constrain(x, ("batch", None, None))
    q = jnp.einsum("btd,dhe->bthe", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhe->bthe", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhe->bthe", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if spec.attn != "enc":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    if spec.attn == "local" and cfg.local_window:
        out = L.local_attention(q, k, v, window=cfg.local_window,
                                q_positions=positions, softcap=cfg.attn_softcap)
    else:
        out = L.flash_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=causal, softcap=cfg.attn_softcap)
    out = constrain(out, ("batch", None, "heads", None))
    y = jnp.einsum("bthe,hed->btd", out, p["wo"].astype(dt))
    new_cache = None
    if cache is not None:
        new_cache = _fill_kv_cache(cache, k, v, cfg, spec)
    return y, new_cache


def _fill_kv_cache(cache, k, v, cfg, spec):
    t = k.shape[1]
    if spec.attn == "local" and cfg.local_window:
        w = cache["k"].shape[1]
        tail_k, tail_v = k[:, -w:], v[:, -w:]
        start = max(0, t - w)
        slots = (start + jnp.arange(tail_k.shape[1])) % w
        return {"k": cache["k"].at[:, slots].set(tail_k),
                "v": cache["v"].at[:, slots].set(tail_v)}
    return {"k": lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, 1),
            "v": lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, 1)}


def _attn_decode(p, cfg, x, cache, cur_len, spec):
    """Single-token attention against a cache (ring buffer for local)."""
    dt = x.dtype
    positions = cur_len[:, None]
    q = jnp.einsum("btd,dhe->bthe", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhe->bthe", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhe->bthe", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if spec.attn != "enc":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    b = x.shape[0]
    s = cache["k"].shape[1]
    if spec.attn == "local" and cfg.local_window:
        w = s
        slot = cur_len % w
        kc = cache["k"].at[jnp.arange(b), slot].set(k[:, 0].astype(cache["k"].dtype))
        vc = cache["v"].at[jnp.arange(b), slot].set(v[:, 0].astype(cache["v"].dtype))
        # position held by ring slot j after writing position cur_len
        slots = jnp.arange(w)
        pos_of_slot = cur_len[:, None] - ((cur_len[:, None] - slots[None]) % w)
        scores_len = jnp.where(pos_of_slot >= 0, pos_of_slot + 1, 0)
        out = _ring_attention(q, kc, vc, pos_of_slot, cur_len, cfg.attn_softcap)
    else:
        kc = cache["k"].at[jnp.arange(b), cur_len].set(k[:, 0].astype(cache["k"].dtype))
        vc = cache["v"].at[jnp.arange(b), cur_len].set(v[:, 0].astype(cache["v"].dtype))
        out = L.cache_attention(q, kc, vc, cur_len=cur_len + 1,
                                softcap=cfg.attn_softcap)
    y = jnp.einsum("bthe,hed->btd", out, p["wo"].astype(dt))
    return y, {"k": kc, "v": vc}


def _ring_attention(q, kc, vc, pos_of_slot, cur_len, softcap):
    """cache_attention over a ring buffer whose slot->position map varies."""
    b, tq, h, d = q.shape
    kh = kc.shape[2]
    g = h // kh
    qg = q.reshape(b, tq, kh, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc,
                        preferred_element_type=jnp.float32) * d ** -0.5
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = (pos_of_slot >= 0) & (pos_of_slot <= cur_len[:, None])
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(vc.dtype), vc,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, tq, h, -1).astype(q.dtype)


def _cross_attn(p, cfg, x, img_kv):
    """Gated cross attention to (precomputed) image K/V."""
    dt = x.dtype
    q = jnp.einsum("btd,dhe->bthe", x, p["wq"].astype(dt))
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = img_kv
    tq = x.shape[1]
    out = L.flash_attention(
        q, k, v, q_positions=jnp.arange(tq), kv_positions=jnp.zeros((k.shape[1],), jnp.int32),
        causal=False)
    return jnp.einsum("bthe,hed->btd", out, p["wo"].astype(dt))


def _image_kv(p, cfg, image_embeds):
    dt = image_embeds.dtype
    k = jnp.einsum("btd,dhe->bthe", image_embeds, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhe->bthe", image_embeds, p["wv"].astype(dt))
    if cfg.qk_norm:
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


def _mlp_part(p, cfg, spec, x):
    aux = jnp.zeros((), jnp.float32)
    if spec.mlp == "none":
        return x, aux
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if spec.mlp == "moe":
        y, aux = moe_apply(p["mlp"], h, cfg, cfg.moe)
    else:
        pm = {k2: v.astype(x.dtype) for k2, v in p["mlp"].items()}
        hh = constrain(h, ("batch", None, None))
        y = L.mlp_apply(pm, hh, cfg.act)
    if cfg.post_norms:
        y = L.rms_norm(y, p["ln2_post"], cfg.norm_eps)
    return x + y, aux


def block_apply_full(p, cfg: ModelConfig, spec: BlockSpec, x, positions,
                     cache=None, image_kv=None):
    """Train/prefill block. Returns (x, new_cache, aux)."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = None
    if spec.attn in ("global", "local", "enc"):
        y, new_cache = _attn_full(p["attn"], cfg, h, positions, spec,
                                  cache=cache, causal=(spec.attn != "enc"))
    elif spec.attn == "cross":
        y, new_cache = _attn_full(p["attn"], cfg, h, positions, spec, cache=cache)
        if cfg.post_norms:
            y = L.rms_norm(y, p["ln1_post"], cfg.norm_eps)
        x = x + y
        hc = L.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        yc = _cross_attn(p["cross"], cfg, hc, image_kv)
        x = x + jnp.tanh(p["cross"]["gate_attn"].astype(x.dtype)) * yc
        return _mlp_part(p, cfg, spec, x) + (new_cache,)
    elif spec.attn == "mla":
        y, kv = mla_forward(p["attn"], h, positions, cfg, cfg.mla)
        if cache is not None:
            ckv, kr = kv
            new_cache = {
                "ckv": lax.dynamic_update_slice_in_dim(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, 1),
                "kr": lax.dynamic_update_slice_in_dim(
                    cache["kr"], kr.astype(cache["kr"].dtype), 0, 1)}
    elif spec.attn == "ssd":
        y, state, tail = mamba2_forward(p["attn"], h, cfg, cfg.ssm)
        if cache is not None:
            new_cache = {"state": state, "conv": tail.astype(cache["conv"].dtype)}
        x = x + y
        return x, jnp.zeros((), jnp.float32), new_cache
    elif spec.attn == "rec":
        y, state, tail = rglru_forward(p["attn"], h, cfg, cfg.rglru)
        if cache is not None:
            new_cache = {"state": state, "conv": tail.astype(cache["conv"].dtype)}
    else:
        raise ValueError(spec.attn)
    if cfg.post_norms:
        y = L.rms_norm(y, p["ln1_post"], cfg.norm_eps)
    x = x + y
    out, aux = _mlp_part(p, cfg, spec, x)
    return out, aux, new_cache


def block_apply_decode(p, cfg: ModelConfig, spec: BlockSpec, x, cache, cur_len,
                       image_kv=None):
    """One-token block. Returns (x, new_cache)."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.attn in ("global", "local", "enc"):
        y, new_cache = _attn_decode(p["attn"], cfg, h, cache, cur_len, spec)
    elif spec.attn == "cross":
        y, new_cache = _attn_decode(p["attn"], cfg, h, cache["self"], cur_len, spec)
        new_cache = {"self": new_cache, "img_k": cache["img_k"], "img_v": cache["img_v"]}
        if cfg.post_norms:
            y = L.rms_norm(y, p["ln1_post"], cfg.norm_eps)
        x = x + y
        hc = L.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        yc = _cross_attn(p["cross"], cfg, hc, (cache["img_k"], cache["img_v"]))
        x = x + jnp.tanh(p["cross"]["gate_attn"].astype(x.dtype)) * yc
        out, _ = _mlp_part(p, cfg, spec, x)
        return out, new_cache
    elif spec.attn == "mla":
        b = x.shape[0]
        # write this step's latent into the cache first
        positions = cur_len[:, None]
        from .mla import _project
        qn, qr, ckv, kr = _project(p["attn"], h, positions, cfg.mla, cfg.norm_eps)
        ckv_c = cache["ckv"].at[jnp.arange(b), cur_len].set(ckv[:, 0].astype(cache["ckv"].dtype))
        kr_c = cache["kr"].at[jnp.arange(b), cur_len].set(kr[:, 0].astype(cache["kr"].dtype))
        y, _ = mla_decode(p["attn"], h, ckv_c, kr_c, cur_len + 1, positions,
                          cfg, cfg.mla)
        new_cache = {"ckv": ckv_c, "kr": kr_c}
    elif spec.attn == "ssd":
        y, state, tail = mamba2_decode(p["attn"], h, cache["state"],
                                       cache["conv"], cfg, cfg.ssm)
        x = x + y
        return x, {"state": state, "conv": tail}
    elif spec.attn == "rec":
        y, state, tail = rglru_decode(p["attn"], h, cache["state"],
                                      cache["conv"], cfg, cfg.rglru)
        new_cache = {"state": state, "conv": tail}
    else:
        raise ValueError(spec.attn)
    if cfg.post_norms:
        y = L.rms_norm(y, p["ln1_post"], cfg.norm_eps)
    x = x + y
    out, _ = _mlp_part(p, cfg, spec, x)
    return out, new_cache


# ------------------------------------------------------------------ caches
def block_cache_spec(cfg: ModelConfig, spec: BlockSpec, batch: int, max_len: int):
    """ShapeDtypeStructs + logical axes for one block's cache."""
    kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = jnp.dtype(cfg.dtype)
    if spec.attn in ("global", "enc"):
        s = {"k": jax.ShapeDtypeStruct((batch, max_len, kh, hd), cdt),
             "v": jax.ShapeDtypeStruct((batch, max_len, kh, hd), cdt)}
        a = {"k": ("cache_batch", "cache_seq", "kv_heads", None),
             "v": ("cache_batch", "cache_seq", "kv_heads", None)}
    elif spec.attn == "local":
        w = min(cfg.local_window, max_len)
        s = {"k": jax.ShapeDtypeStruct((batch, w, kh, hd), cdt),
             "v": jax.ShapeDtypeStruct((batch, w, kh, hd), cdt)}
        a = {"k": ("cache_batch", "cache_seq", "kv_heads", None),
             "v": ("cache_batch", "cache_seq", "kv_heads", None)}
    elif spec.attn == "cross":
        inner_s, inner_a = block_cache_spec(
            cfg, BlockSpec("global", spec.mlp), batch, max_len)
        ti = cfg.vlm.num_image_tokens
        s = {"self": inner_s,
             "img_k": jax.ShapeDtypeStruct((batch, ti, kh, hd), cdt),
             "img_v": jax.ShapeDtypeStruct((batch, ti, kh, hd), cdt)}
        a = {"self": inner_a,
             "img_k": ("cache_batch", None, "kv_heads", None),
             "img_v": ("cache_batch", None, "kv_heads", None)}
    elif spec.attn == "mla":
        m = cfg.mla
        s = {"ckv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), cdt),
             "kr": jax.ShapeDtypeStruct((batch, max_len, m.rope_head_dim), cdt)}
        a = {"ckv": ("cache_batch", "cache_seq", "kv_lora"),
             "kr": ("cache_batch", "cache_seq", None)}
    elif spec.attn == "ssd":
        d_inner, n_heads, conv_dim = ssm_dims(cfg, cfg.ssm)
        s = {"state": jax.ShapeDtypeStruct(
                (batch, n_heads, cfg.ssm.headdim, cfg.ssm.d_state), jnp.float32),
             "conv": jax.ShapeDtypeStruct(
                (batch, cfg.ssm.conv_width - 1, conv_dim), cdt)}
        a = {"state": ("cache_batch", None, None, None),
             "conv": ("cache_batch", None, "rnn")}
    elif spec.attn == "rec":
        dr = cfg.rglru.d_rnn or cfg.d_model
        s = {"state": jax.ShapeDtypeStruct((batch, dr), jnp.float32),
             "conv": jax.ShapeDtypeStruct((batch, cfg.rglru.conv_width - 1, dr), cdt)}
        a = {"state": ("cache_batch", "rnn"),
             "conv": ("cache_batch", None, "rnn")}
    else:
        raise ValueError(spec.attn)
    return s, a


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """Abstract cache tree + axes tree for the whole model."""
    prefix, pattern, n_groups, suffix = arch_blocks(cfg)
    shapes: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    for i, spec in enumerate(prefix):
        shapes[f"prefix{i}"], axes[f"prefix{i}"] = block_cache_spec(cfg, spec, batch, max_len)
    blk_s, blk_a = {}, {}
    for j, spec in enumerate(pattern):
        s, a = block_cache_spec(cfg, spec, batch, max_len)
        blk_s[f"b{j}"] = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((n_groups,) + x.shape, x.dtype), s,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        blk_a[f"b{j}"] = jax.tree.map(
            lambda x: ("layers",) + tuple(x), a,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x))
    shapes["blocks"], axes["blocks"] = blk_s, blk_a
    for i, spec in enumerate(suffix):
        shapes[f"suffix{i}"], axes[f"suffix{i}"] = block_cache_spec(cfg, spec, batch, max_len)
    shapes["cur_len"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
    axes["cur_len"] = ("cache_batch",)
    return shapes, axes


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    shapes, _ = cache_spec(cfg, batch, max_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ------------------------------------------------------------------ model
def _embed(cfg, params, tokens):
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _logits(cfg, params, x):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("btd,dv->btv", x, head.astype(x.dtype))
    logits = constrain(logits, ("batch", None, "vocab"))
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def forward(cfg: ModelConfig, params, tokens, *, image_embeds=None,
            caches=None):
    """Full-sequence forward. Returns (logits, aux_loss, new_caches|None)."""
    prefix, pattern, n_groups, suffix = arch_blocks(cfg)
    t = tokens.shape[1]
    positions = jnp.arange(t, dtype=jnp.int32)
    x = _embed(cfg, params, tokens)
    x = constrain(x, ("batch", "seq", None))
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {} if caches is not None else None

    img_kv_per_group = None
    if cfg.family == "vlm":
        # image K/V are per cross-layer; computed on the fly inside the scan
        image_embeds = image_embeds.astype(x.dtype)

    for i, spec in enumerate(prefix):
        c = caches.get(f"prefix{i}") if caches is not None else None
        x, aux, nc = block_apply_full(params[f"prefix{i}"], cfg, spec, x,
                                      positions, cache=c)
        aux_total += aux
        if new_caches is not None:
            new_caches[f"prefix{i}"] = nc

    def group_body(x, group_inp):
        gp = group_inp["params"]
        gc = group_inp.get("cache")
        auxg = jnp.zeros((), jnp.float32)
        ncache = {}
        for j, spec in enumerate(pattern):
            pj = gp[f"b{j}"]
            cj = gc[f"b{j}"] if gc is not None else None
            if spec.attn == "cross":
                ikv = _image_kv(pj["cross"], cfg, image_embeds)
                x, aux, nc = block_apply_full(pj, cfg, spec, x, positions,
                                              cache=cj["self"] if cj else None,
                                              image_kv=ikv)
                if cj is not None:
                    nc = {"self": nc, "img_k": ikv[0].astype(cj["img_k"].dtype),
                          "img_v": ikv[1].astype(cj["img_v"].dtype)}
            else:
                x, aux, nc = block_apply_full(pj, cfg, spec, x, positions, cache=cj)
            x = constrain(x, ("batch", "seq", None))
            auxg += aux
            if cj is not None:
                ncache[f"b{j}"] = nc
        return x, (auxg, ncache)

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers and n_groups > 1:
        scan_inp = {"params": params["blocks"]}
        if caches is not None:
            scan_inp["cache"] = caches["blocks"]
        x, (auxs, ncaches) = lax.scan(body, x, scan_inp)
        aux_total += auxs.sum()
        if new_caches is not None:
            new_caches["blocks"] = ncaches
    else:
        for g in range(n_groups):
            inp = {"params": jax.tree.map(lambda a: a[g], params["blocks"])}
            if caches is not None:
                inp["cache"] = jax.tree.map(lambda a: a[g], caches["blocks"])
            x, (aux, nc) = body(x, inp)
            aux_total += aux
            if new_caches is not None:
                new_caches.setdefault("_block_list", []).append(nc)
        if new_caches is not None and "_block_list" in new_caches:
            ncs = new_caches.pop("_block_list")
            new_caches["blocks"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *ncs)

    for i, spec in enumerate(suffix):
        c = caches.get(f"suffix{i}") if caches is not None else None
        x, aux, nc = block_apply_full(params[f"suffix{i}"], cfg, spec, x,
                                      positions, cache=c)
        aux_total += aux
        if new_caches is not None:
            new_caches[f"suffix{i}"] = nc

    logits = _logits(cfg, params, x)
    if new_caches is not None:
        new_caches["cur_len"] = jnp.full((tokens.shape[0],), t, jnp.int32)
    return logits, aux_total, new_caches


def chunked_xent(logits, labels, t_chunk: int = 512):
    """Mean next-token cross-entropy, chunked over the sequence so the fp32
    logit upcast never materializes [B, T, V] (vocab stays mesh-sharded;
    each chunk is [B, t_chunk, V])."""
    b, t, v = logits.shape
    tc = min(t_chunk, t)
    if t % tc:
        tc = t  # fall back for odd lengths (smoke shapes)
    lg = logits.reshape(b, t // tc, tc, v).transpose(1, 0, 2, 3)
    lb = labels.reshape(b, t // tc, tc).transpose(1, 0, 2)

    def one(args):
        lg_c, lb_c = args
        lg32 = lg_c.astype(jnp.float32)
        logz = jax.nn.logsumexp(lg32, axis=-1)
        gold = jnp.take_along_axis(lg32, lb_c[..., None], axis=-1)[..., 0]
        return (logz - gold).sum()

    per_chunk = lax.map(one, (lg, lb))
    return per_chunk.sum() / (b * t)


def loss_fn(cfg: ModelConfig, params, batch):
    """Mean next-token cross-entropy (+ MoE aux)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    logits, aux, _ = forward(cfg, params, tokens,
                             image_embeds=batch.get("image_embeds"))
    nll = chunked_xent(logits, labels)
    return nll + aux, {"nll": nll, "aux": aux}


def decode_step(cfg: ModelConfig, params, caches, tokens):
    """One decode step for the whole batch. tokens [B] int32; caches include
    cur_len [B]. Returns (logits [B, vocab], new_caches)."""
    prefix, pattern, n_groups, suffix = arch_blocks(cfg)
    cur_len = caches["cur_len"]
    x = _embed(cfg, params, tokens[:, None])
    new_caches = {}
    for i, spec in enumerate(prefix):
        x, nc = block_apply_decode(params[f"prefix{i}"], cfg, spec, x,
                                   caches[f"prefix{i}"], cur_len)
        new_caches[f"prefix{i}"] = nc

    def group_body(x, inp):
        gp, gc = inp["params"], inp["cache"]
        ncache = {}
        for j, spec in enumerate(pattern):
            x, nc = block_apply_decode(gp[f"b{j}"], cfg, spec, x,
                                       gc[f"b{j}"], cur_len)
            ncache[f"b{j}"] = nc
        return x, ncache

    if cfg.scan_layers and n_groups > 1:
        x, ncaches = lax.scan(
            group_body, x, {"params": params["blocks"], "cache": caches["blocks"]})
        new_caches["blocks"] = ncaches
    else:
        ncs = []
        for g in range(n_groups):
            inp = {"params": jax.tree.map(lambda a: a[g], params["blocks"]),
                   "cache": jax.tree.map(lambda a: a[g], caches["blocks"])}
            x, nc = group_body(x, inp)
            ncs.append(nc)
        new_caches["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)

    for i, spec in enumerate(suffix):
        x, nc = block_apply_decode(params[f"suffix{i}"], cfg, spec, x,
                                   caches[f"suffix{i}"], cur_len)
        new_caches[f"suffix{i}"] = nc

    logits = _logits(cfg, params, x)[:, 0]
    new_caches["cur_len"] = cur_len + 1
    return logits, new_caches
