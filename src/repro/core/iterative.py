"""ITERATIVE — the paper's Algorithm 2 (speculation + iteration), vectorized.

Execution model (faithful adaptation, DESIGN.md §2)
---------------------------------------------------
The paper runs Alg. 2's phase-1 loop with ``#pragma omp parallel for`` and
default *static* scheduling: each of ``P`` threads owns a contiguous block of
the pending set and colors it sequentially. In the canonical lockstep
("superstep") model of that execution, the vertices racing at any instant are
those at the same *offset* within their thread's block; a vertex sees the
committed colors of every vertex at a strictly smaller offset, and conflicts
can only arise between same-offset vertices.

We reproduce those semantics exactly on a SIMD machine. Per round:

  1. pending vertices get ``offset = rank % ceil(|U|/P)`` (rank = position in
     the pending set, matching OpenMP-static block assignment) —
     :func:`repro.core.engine.lockstep_offsets`;
  2. tentative colors are the fixpoint of the *dataflow equations over the
     offset-precedence DAG* —
         c[v] = mex{ c[w] : w adj v, committed(w) or offset(w) < offset(v) }
     reached by chaotic sweeps (depth(DAG) of them) via the shared
     :func:`repro.core.engine.fixpoint_sweep` — the SIMD equivalent of the
     threads advancing through their blocks in lockstep;
  3. conflict detection (Alg. 2 lines 11-14): monochromatic pending pairs
     (necessarily same-offset) queue the higher-index endpoint for the next
     round (:func:`repro.core.engine.speculation_conflicts`).

Limits: ``concurrency=1`` degenerates to serial greedy (0 conflicts,
colors == Alg. 1); ``concurrency >= |V|`` is the fully-concurrent limit (the
XMT's 16K-thread regime). Conflicts grow with ``concurrency`` — the paper's
Fig. 10(a) trend — and the pending set strictly shrinks every round (the
minimum-index vertex of each conflict cluster always survives), so the loop
terminates.

The first-fit inner loop is pluggable (``engine=``): ``"sort"`` (segmented
sort mex), ``"bitmap"`` (O(E) scatter-or forbidden bitmap) or
``"ell_pallas"`` (the Pallas kernel over the graph's ELL layout) — see
engine.py for the registry.

The round loop is two-phase (repro.core.frontier): round 0 sweeps the full
edge list; rounds >= 1 compact the pending tail and its incident edges
into a static active-set slab and sweep that instead — O(cap) per sweep
rather than O(E) — spilling back to the full path when the frontier
overflows its bucket. Bit-identical either way.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from .engine import (EngineSpec, SweepSpec, fixpoint_sweep,
                     lockstep_offsets, speculation_conflicts)
from .frontier import (compact_frontier, frontier_conflicts, frontier_counts,
                       frontier_sweep)
from .graph import DeviceGraph


@dataclasses.dataclass
class ColoringResult:
    colors: jnp.ndarray               # [V] int32, >= 1
    rounds: int                       # outer iterations (paper Fig. 10b)
    conflicts_per_round: jnp.ndarray  # [max_rounds] int32 (paper Fig. 10c)
    sweeps_per_round: jnp.ndarray     # [max_rounds] int32 inner sweeps

    # summaries are memoized: results get re-summarized in benchmark and
    # assertion loops, and colors.max() over a large coloring is not free
    @functools.cached_property
    def total_conflicts(self) -> int:
        return int(self.conflicts_per_round.sum())

    @functools.cached_property
    def sweeps(self) -> int:
        """Total inner dataflow sweeps across all rounds."""
        return int(self.sweeps_per_round.sum())

    @functools.cached_property
    def num_colors(self) -> int:
        from .metrics import num_colors as _distinct
        return _distinct(self.colors)


@functools.partial(
    jax.jit,
    static_argnames=("concurrency", "max_rounds", "max_sweeps", "backend",
                     "color_bound", "frontier_cap_v", "frontier_cap_e",
                     "seed_frontier"),
)
def _iterative_impl(g: DeviceGraph, colors0=None, pending0=None, *,
                    concurrency: int, max_rounds: int,
                    max_sweeps: int, backend, color_bound: int = 0,
                    frontier_cap_v: int = 0, frontier_cap_e: int = 0,
                    seed_frontier: bool = False):
    """The speculation round loop. ``colors0``/``pending0`` warm-start it
    from an existing partial coloring (the ``"recolor"`` strategy's
    detect-and-repair entry: committed colors + the conflicted seed set);
    ``None`` is the cold start (no colors, everything pending).
    ``seed_frontier`` lets round 0 take the compacted frontier path — off
    for cold starts (round 0 is all-pending by construction), on for
    seeded repairs, where round 0 IS the tiny conflicted tail."""
    V = g.num_vertices
    src, dst = g.src, g.dst
    max_colors = g.max_degree + 1
    if color_bound > 0:
        max_colors = min(max_colors, color_bound)
    mex = backend.bind(num_vertices=V, max_colors=max_colors,
                       ell_slot=g.ell_slot, ell_width=g.ell_width,
                       max_degree=g.max_degree)
    # frontier execution layer (repro.core.frontier): rounds >= 1 whose
    # pending set fits the static slab run compacted — O(cap) per sweep
    # instead of O(E) — with a bit-identical spill to the full path
    use_frontier = frontier_cap_v > 0 and g.has_frontier
    if use_frontier:
        mex_slab = backend.bind_slab(
            capacity=frontier_cap_v, max_colors=max_colors,
            ell_width=g.max_degree, max_degree=g.max_degree)

    def round_body(state):
        colors, pending, rnd, conf_hist, sweep_hist, front_hist = state
        # OpenMP-static lockstep offsets over the pending set
        offset = lockstep_offsets(pending, concurrency)
        ppad = jnp.concatenate([pending, jnp.zeros((1,), jnp.bool_)])
        opad = jnp.concatenate(
            [offset, jnp.full((1,), jnp.iinfo(jnp.int32).max, jnp.int32)])

        def full_round(colors):
            # neighbor forbids src iff committed, or pending at smaller offset
            forbids = ppad[src] & (~ppad[dst] | (opad[dst] < opad[src]))
            spec = SweepSpec(key_v=jnp.where(forbids, src, V),
                             dyn_idx=dst, dyn=forbids,
                             static_c=jnp.zeros_like(dst))

            # Phase 1 — fixpoint of the offset-precedence dataflow equations.
            colors, n_sweeps, _ = fixpoint_sweep(
                mex, spec, jnp.where(pending, 0, colors), pending,
                max_sweeps=max_sweeps)

            # Phase 2 — conflicts among same-round pairs; higher index
            # recolors.
            new_pending = speculation_conflicts(src, dst, colors, pending, V)
            return colors, n_sweeps, new_pending

        def frontier_round(colors):
            # same equations, compacted: the slab holds every pending vertex
            # and every constraint edge incident to one, so phase 1's
            # fixpoint and phase 2's conflict pass are bit-identical
            slab = compact_frontier(pending, g.inc_ptr, dst,
                                    frontier_cap_v, frontier_cap_e)
            forbid_e = ((slab.src < V)
                        & (~ppad[slab.dst] | (opad[slab.dst] < opad[slab.src])))
            cpad0 = (jnp.concatenate([colors, jnp.zeros((1,), jnp.int32)])
                     .at[slab.vert].set(0, mode="drop"))
            cpad, n_sweeps, _ = frontier_sweep(
                mex_slab,
                key_v=jnp.where(forbid_e, slab.owner, frontier_cap_v),
                dyn=forbid_e, dyn_idx=slab.dst,
                static_c=jnp.zeros_like(slab.dst), slot=slab.slot,
                write_vert=slab.vert, cpad0=cpad0, max_sweeps=max_sweeps)
            new_pending = frontier_conflicts(slab, cpad, ppad, V)
            return cpad[:V], n_sweeps, new_pending

        if use_frontier:
            nv, ne = frontier_counts(pending, g.inc_ptr)
            round_ok = jnp.asarray(True) if seed_frontier else (rnd > 0)
            fits = (round_ok & (nv <= frontier_cap_v)
                    & (ne <= frontier_cap_e))
            colors, n_sweeps, new_pending = lax.cond(
                fits, frontier_round, full_round, colors)
            front_hist = front_hist.at[rnd].set(jnp.where(fits, nv, 0))
        else:
            colors, n_sweeps, new_pending = full_round(colors)

        conf_hist = conf_hist.at[rnd].set(new_pending.sum(dtype=jnp.int32))
        sweep_hist = sweep_hist.at[rnd].set(n_sweeps)
        return colors, new_pending, rnd + 1, conf_hist, sweep_hist, front_hist

    def cond(state):
        _, pending, rnd, _, _, _ = state
        return jnp.logical_and(jnp.any(pending), rnd < max_rounds)

    init = (
        (jnp.zeros((V,), jnp.int32) if colors0 is None
         else jnp.asarray(colors0, jnp.int32)),
        (jnp.ones((V,), jnp.bool_) if pending0 is None
         else jnp.asarray(pending0, jnp.bool_)),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((max_rounds,), jnp.int32),
        jnp.zeros((max_rounds,), jnp.int32),
        jnp.zeros((max_rounds,), jnp.int32),
    )
    colors, pending, rnd, conf_hist, sweep_hist, front_hist = lax.while_loop(
        cond, round_body, init)
    return (colors, rnd, conf_hist, sweep_hist, front_hist,
            jnp.any(pending))


def color_iterative(
    g,
    concurrency: int = 64,
    max_rounds: int = 64,
    max_sweeps: int = 4096,
    engine: EngineSpec = "sort",
    color_bound: int = 0,
    model: str = "d1",
) -> ColoringResult:
    """Run ITERATIVE with ``concurrency`` lockstep virtual threads.

    ``g`` is a :class:`DeviceGraph` (model="d1" only), or a host
    :class:`repro.core.graph.Graph` / ``BipartiteGraph`` which is lowered
    per ``model``:

    * ``model="d1"``  distance-1 (adjacent vertices differ) — the default;
    * ``model="d2"``  distance-2 (two-hop pairs differ too; Graph input);
    * ``model="pd2"`` bipartite partial distance-2 (BipartiteGraph input;
      colors the left class).

    The speculation/conflict machinery is model-agnostic: richer models are
    purely a different constraint edge space (repro.core.distance2).

    ``engine`` selects the first-fit inner loop by name (``"sort"``,
    ``"bitmap"``, ``"ell_pallas"``) or takes a
    :class:`repro.core.engine.MexBackend` instance directly.
    ``color_bound`` optionally caps the table backends' color capacity
    below the provable Delta+1 bound (a caller-asserted bound — colors at
    or above it lose their forbids silently; see color_distributed).

    Back-compat shim over the registered ``"iterative"``
    :class:`repro.core.api.ColoringStrategy` — same arguments, same
    bit-exact results, legacy :class:`ColoringResult` return. Prefer
    ``repro.core.color(g, strategy="iterative", ...)`` (unified
    :class:`repro.core.api.ColoringReport`, ``ordering=`` support) or
    ``repro.core.compile_plan`` for compile-once reuse."""
    from .api import ColoringSpec, get_strategy  # lazy: api imports us
    spec = ColoringSpec(strategy="iterative", model=model, engine=engine,
                        concurrency=int(concurrency), max_rounds=max_rounds,
                        max_sweeps=max_sweeps, color_bound=int(color_bound))
    raw = get_strategy("iterative").oneshot(spec, g)
    if bool(raw.unconverged):
        raise RuntimeError(f"ITERATIVE did not converge in {max_rounds} rounds")
    return ColoringResult(colors=raw.colors, rounds=int(raw.rounds),
                          conflicts_per_round=raw.conflicts_per_round,
                          sweeps_per_round=raw.sweeps_per_round)
