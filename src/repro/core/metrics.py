"""Coloring validity / quality metrics (host + device variants), for every
coloring model: distance-1, distance-2, and bipartite partial distance-2."""
from __future__ import annotations

import numpy as np

from .graph import BipartiteGraph, Graph


def validate_coloring(graph: Graph, colors: np.ndarray) -> bool:
    """True iff every vertex is colored (>0) and no edge is monochromatic."""
    colors = np.asarray(colors)
    if colors.shape[0] < graph.num_vertices or (colors[: graph.num_vertices] <= 0).any():
        return False
    src, dst = graph.directed_edges()
    return not bool((colors[src] == colors[dst]).any())


def count_conflicts(graph: Graph, colors: np.ndarray) -> int:
    """Number of undirected monochromatic edges."""
    src, dst = graph.directed_edges()
    return int(((colors[src] == colors[dst]) & (src > dst)).sum())


def num_colors(colors) -> int:
    """Number of *distinct* positive colors in use.

    Not ``colors.max()``: repair paths (the ``"recolor"`` strategy, edge
    deletions freeing colors) legitimately leave gaps in the palette, and
    the max would overstate it. For a fresh first-fit coloring the two
    agree; for a patched one only the distinct count is the palette size."""
    colors = np.asarray(colors)
    if not colors.size:
        return 0
    return int(np.unique(colors[colors > 0]).size)


# ------------------------------------------------------------- D2 / PD2
def validate_d2_coloring(graph: Graph, colors: np.ndarray) -> bool:
    """True iff ``colors`` is a valid *distance-2* coloring: every vertex
    colored and no two vertices within two hops share a color. Checked on
    the wedge multiset directly (no G² materialization)."""
    from .distance2 import d2_pairs  # deferred: metrics stays light to import
    colors = np.asarray(colors)
    if colors.shape[0] < graph.num_vertices or (colors[: graph.num_vertices] <= 0).any():
        return False
    fsrc, fdst, _ = d2_pairs(graph)
    cpad = np.concatenate([colors[: graph.num_vertices], [0]])
    live = fsrc < graph.num_vertices
    return not bool((cpad[fsrc[live]] == cpad[fdst[live]]).any())


def count_d2_conflicts(graph: Graph, colors: np.ndarray) -> int:
    """Number of *distinct* unordered distance-<=2 pairs sharing a color
    (the D2 analogue of :func:`count_conflicts`)."""
    from .distance2 import square
    return count_conflicts(square(graph), np.asarray(colors))


def validate_pd2_coloring(bg: BipartiteGraph, colors: np.ndarray,
                          side: str = "left") -> bool:
    """True iff ``colors`` (one entry per ``side`` vertex) is a valid
    partial distance-2 coloring: every ``side`` vertex colored, and the
    neighbors of each opposite-class vertex have pairwise-distinct colors."""
    n = bg.num_left if side == "left" else bg.num_right
    ptr, idx = ((bg.r2l_ptr, bg.r2l_idx) if side == "left"
                else (bg.l2r_ptr, bg.l2r_idx))
    colors = np.asarray(colors)
    if colors.shape[0] < n or (colors[:n] <= 0).any():
        return False
    if not idx.size:
        return True
    rows = np.repeat(np.arange(ptr.shape[0] - 1), np.diff(ptr))
    vals = colors[idx]
    order = np.lexsort((vals, rows))
    r, v = rows[order], vals[order]
    return not bool(((r[1:] == r[:-1]) & (v[1:] == v[:-1])).any())


def count_pd2_conflicts(bg: BipartiteGraph, colors: np.ndarray,
                        side: str = "left") -> int:
    """Number of distinct same-class pairs that share a neighbor AND a
    color — the PD2 analogue of :func:`count_conflicts`."""
    from .distance2 import partial_square
    return count_conflicts(partial_square(bg, side), np.asarray(colors))


