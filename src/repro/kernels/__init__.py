"""Pallas TPU kernels for the coloring hot spots (+ jnp oracles).

firstfit    — bitmask first-fit over ELL neighbor-color slabs (Alg. 1 5-6)
conflict    — edge-parallel conflict detection (Alg. 2 line 13)
round_fused — detect→mex→assign in ONE slab read: the firstfit bitset and
              the Alg. 2 predicate fused per vertex tile (ROADMAP item 2);
              reaches drivers as ``engine="fused_pallas"``

The kernels reach the coloring drivers exclusively through the
:class:`~repro.core.engine.MexBackend` registry: ``EllPallasMexBackend``
(``engine="ell_pallas"``) binds :func:`firstfit` to a graph's ELL geometry
(``Graph.to_device(layout="ell")``, or device-side ``engine.edge_slots``
under the distributed driver) and scatters each round's ``SweepSpec``
contributions into the [V, D] slab the kernel consumes. Drivers never
hand-wire kernel closures; registering a different kernel is a new
``MexBackend`` subclass (DESIGN.md §Engine). Off-TPU the kernels run in
Pallas interpret mode (``ops.INTERPRET``).
"""
from .firstfit import firstfit
from .conflict import conflict_mask
from .ref import firstfit_ref, conflict_mask_ref
from .ops import ell_mex, ell_gather_colors, INTERPRET, resolve_interpret
from .round_fused import (round_fused, round_fused_ref, pack_entries,
                          tile_conflict_counts, COLOR_MASK, FORBID_BIT,
                          CONFLICT_BIT)

__all__ = [
    "firstfit", "conflict_mask", "firstfit_ref", "conflict_mask_ref",
    "ell_mex", "ell_gather_colors", "INTERPRET",
    "resolve_interpret", "round_fused", "round_fused_ref", "pack_entries",
    "tile_conflict_counts", "COLOR_MASK", "FORBID_BIT", "CONFLICT_BIT",
]
