"""Windowed serving metrics — the observability half of the serving layer.

One :class:`WindowedMetrics` instance rides every serving front end
(`repro.serve.coloring.AsyncColoringService`, the sync ``ColoringService``
keeps its legacy counters) and answers the three production questions:

* **latency** — p50/p99/mean/max over a sliding *time* window (a long-lived
  service must report "the last minute", not "since boot"), plus the max
  queue age ever observed (the deadline-flush guarantee is stated against
  it: no request waits past its budget plus one in-flight flush);
* **cache/compile health** — cumulative cache hit/miss counts (hit rate),
  and jit retrace count (a retrace in steady state means the plan-cache
  envelope quantization regressed);
* **flush accounting** — a histogram over :data:`FLUSH_REASONS`
  (``size`` = the micro-batch filled, ``deadline`` = the oldest request
  aged past the flush budget, ``drain`` = an explicit flush-everything).

**Atomicity contract.** All counters for one flush — request count,
latencies, cache hit, retraces, reason — commit in ONE
:meth:`record_flush` call under one lock. Updating them per enqueue races
the flush path (a reader between the latency append and the counter
increment sees requests != latency count); ``tests/test_serve_coloring.py``
pins the per-flush granularity with a deterministic clock.

**Restart contract.** :meth:`state_dict` / :meth:`load_state` round-trip
the cumulative counters as a flat array dict (checkpointable through
``repro.train.checkpoint``). Only :data:`RESTART_INVARIANT` counters are
*guaranteed* equal between a killed-and-restored run and an unkilled one
(pinned in ``tests/test_serve_faults.py``): retraces and cache misses are
process-local (a restored process recompiles once, legitimately), and
latency samples are wall-clock.

The clock is injectable (``clock=``) so deadline/window tests never sleep:
the tier-1 suite drives a fake monotonic clock (``tests/conftest.py``).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

FLUSH_REASONS = ("size", "deadline", "drain")

# counters a kill + checkpoint/restore cycle must NOT perturb (everything
# deterministic about what was served; excludes retraces/cache/latency,
# which are legitimately process-local)
RESTART_INVARIANT = ("requests", "flushes", "batched_requests",
                     "stream_deltas", "rejected")

_COUNTERS = RESTART_INVARIANT + ("cache_hits", "cache_misses", "retraces")


class WindowedMetrics:
    """Sliding-window latency percentiles + cumulative serving counters.

    window_s      time width of the percentile window;
    max_samples   hard cap on retained samples (memory bound for a
                  long-lived service under heavy rates);
    clock         monotonic float-seconds callable (injectable for tests).
    """

    def __init__(self, *, window_s: float = 60.0,
                 clock: Optional[Callable[[], float]] = None,
                 max_samples: int = 65536):
        self.window_s = float(window_s)
        self._clock = clock or time.perf_counter
        self._max_samples = int(max_samples)
        self._lock = threading.Lock()
        self._samples: deque = deque()  # (t, latency_s, queue_age_s)
        self._c = {k: 0 for k in _COUNTERS}
        self._flush_reasons = {r: 0 for r in FLUSH_REASONS}
        self._max_queue_age_s = 0.0
        self._exec_s = 0.0      # total in-flush execution time
        self._max_exec_s = 0.0  # longest single flush (the stall bound)

    # ------------------------------------------------------------- recording
    def record_flush(self, reason: str, *, latencies: Sequence[float],
                     queue_ages: Sequence[float], exec_s: float,
                     cache_hit: Optional[bool] = None, retraces: int = 0,
                     batched: bool = False, stream: bool = False) -> None:
        """Commit ONE flush atomically: n requests' latencies/queue ages,
        the flush reason, execution time, and (optional) plan-cache and
        retrace accounting — a single critical section, so a concurrent
        :meth:`snapshot` never observes a half-recorded flush."""
        if reason not in self._flush_reasons:
            raise ValueError(f"unknown flush reason {reason!r}; known: "
                             f"{FLUSH_REASONS}")
        now = self._clock()
        with self._lock:
            self._c["requests"] += len(latencies)
            self._c["flushes"] += 1
            self._flush_reasons[reason] += 1
            if batched:
                self._c["batched_requests"] += len(latencies)
            if stream:
                self._c["stream_deltas"] += len(latencies)
            if cache_hit is not None:
                self._c["cache_hits" if cache_hit else "cache_misses"] += 1
            self._c["retraces"] += int(retraces)
            self._exec_s += float(exec_s)
            self._max_exec_s = max(self._max_exec_s, float(exec_s))
            for lat, age in zip(latencies, queue_ages):
                self._samples.append((now, float(lat), float(age)))
                if age > self._max_queue_age_s:
                    self._max_queue_age_s = float(age)
            while len(self._samples) > self._max_samples:
                self._samples.popleft()

    def record_rejected(self, n: int = 1) -> None:
        """Admission-control rejections (queue full)."""
        with self._lock:
            self._c["rejected"] += int(n)

    # ------------------------------------------------------------- reporting
    def _prune(self, now: float) -> None:
        edge = now - self.window_s
        while self._samples and self._samples[0][0] < edge:
            self._samples.popleft()

    def snapshot(self) -> dict:
        """The exported metrics: window percentiles + cumulative counters.

        ``window``      p50/p99/mean/max latency and max queue age (ms)
                        over the last ``window_s`` seconds;
        ``cumulative``  lifetime counters, the flush-reason histogram,
                        total/max flush execution time, max queue age ever;
        ``cache_hit_rate``  lifetime hits / (hits + misses), or ``None``
                        before the first plan lookup.
        """
        now = self._clock()
        with self._lock:
            self._prune(now)
            lats = np.asarray([s[1] for s in self._samples], np.float64)
            ages = np.asarray([s[2] for s in self._samples], np.float64)
            c = dict(self._c)
            reasons = dict(self._flush_reasons)
            max_age, exec_s = self._max_queue_age_s, self._exec_s
            max_exec = self._max_exec_s
        window = {"count": int(lats.size)}
        if lats.size:
            window.update(
                p50_ms=float(np.percentile(lats, 50) * 1e3),
                p99_ms=float(np.percentile(lats, 99) * 1e3),
                mean_ms=float(lats.mean() * 1e3),
                max_ms=float(lats.max() * 1e3),
                max_queue_age_ms=float(ages.max() * 1e3))
        looked = c["cache_hits"] + c["cache_misses"]
        return {
            "window": window,
            "cumulative": {**c, "flush_reasons": reasons,
                           "exec_s": exec_s, "max_exec_s": max_exec,
                           "max_queue_age_s": max_age},
            "cache_hit_rate": (c["cache_hits"] / looked if looked else None),
        }

    # ---------------------------------------------------------- checkpointing
    def state_dict(self) -> dict:
        """Cumulative counters as a flat array dict (a
        ``repro.train.checkpoint`` pytree). Window samples are wall-clock
        and deliberately not checkpointed."""
        with self._lock:
            out = {k: np.int64(v) for k, v in self._c.items()}
            out.update({f"flush-{r}": np.int64(n)
                        for r, n in self._flush_reasons.items()})
            out["max-queue-age-s"] = np.float64(self._max_queue_age_s)
            out["exec-s"] = np.float64(self._exec_s)
        return out

    def load_state(self, state: dict) -> None:
        """Resume cumulative counters from :meth:`state_dict` output (the
        restored process keeps accumulating on top)."""
        with self._lock:
            for k in self._c:
                if k in state:
                    self._c[k] = int(state[k])
            for r in self._flush_reasons:
                key = f"flush-{r}"
                if key in state:
                    self._flush_reasons[r] = int(state[key])
            self._max_queue_age_s = float(state.get("max-queue-age-s", 0.0))
            self._exec_s = float(state.get("exec-s", 0.0))
