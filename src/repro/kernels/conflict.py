"""Pallas TPU kernel: edge-parallel conflict detection (Alg. 2, phase 2).

Consumes pre-gathered endpoint colors (the irregular gather is an XLA `take`
outside the kernel, per DESIGN.md §2) plus the endpoint ids, and emits the
per-edge conflict mask ``color[src] == color[dst] and src > dst and colored``
— the exact predicate of Alg. 2 line 13. Pure VPU compare/select work over
128-aligned edge tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tpu_compat import TPUCompilerParams


def _conflict_kernel(csrc_ref, cdst_ref, src_ref, dst_ref, out_ref):
    csrc = csrc_ref[...]
    cdst = cdst_ref[...]
    src = src_ref[...]
    dst = dst_ref[...]
    conf = (csrc == cdst) & (csrc > 0) & (src > dst)
    out_ref[...] = conf.astype(jnp.int32)


def vmem_estimate(*, block_e: int = 1024) -> int:
    """Per-grid-step VMEM footprint (bytes) of :func:`conflict_mask` for
    the analyzer's budget checker: four int32 input blocks, one output
    block, and the boolean compare intermediate."""
    return 4 * block_e * 5 + block_e


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def conflict_mask(
    colors_src: jnp.ndarray,
    colors_dst: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    *,
    block_e: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-edge conflict mask [E] int32 (1 = recolor the src endpoint)."""
    (e,) = colors_src.shape
    ep = -(-e // block_e) * block_e

    def pad(x, fill):
        return jnp.full((ep,), fill, jnp.int32).at[:e].set(x.astype(jnp.int32))

    # pad with src == dst so padding never reports a conflict
    args = (pad(colors_src, 0), pad(colors_dst, 0), pad(src, 0), pad(dst, 0))
    grid = (ep // block_e,)
    spec = pl.BlockSpec((block_e,), lambda i: (i,))
    out = pl.pallas_call(
        _conflict_kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((ep,), jnp.int32),
        compiler_params=TPUCompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    return out[:e]
