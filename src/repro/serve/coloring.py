"""Plan-cached batched coloring service — the serving front end over the
``ColoringSpec -> ColoringPlan -> ColoringReport`` front door.

The ROADMAP's "serve heavy traffic" path, made concrete: a
:class:`ColoringService` keeps an LRU cache of compiled
:class:`repro.core.api.ColoringPlan`s keyed by ``(spec, PlanShape)`` —
the *bucket envelope* of a request, not its raw shape, so every graph of a
family (edge counts quantized up the :func:`repro.core.graph.pad_bucket`
ladder, degree bounds up the same ladder) hits ONE compiled program.
Batched submissions micro-batch: same-key requests whose strategy supports
``plan.map`` ride one vmapped program; the rest loop over the cached plan.
Per-request latency and aggregate latency/throughput/cache stats are always
on (:meth:`ColoringService.stats`).

Smoke mode (mirrors ``repro.launch.serve``'s CLI):

    PYTHONPATH=src python -m repro.serve.coloring --smoke
    PYTHONPATH=src python -m repro.serve.coloring --scale 10 --requests 48 \\
        --batch 8 --engine bitmap --stream-batches 4

It serves a stream of same-family R-MAT requests through the cache (first
request compiles, the rest are cache hits; micro-batches go through
``plan.map``), then demos the streaming lane: a
:class:`repro.core.dynamic.DynamicColoring` absorbing edge-delta batches
with incremental ``"recolor"`` repairs.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import OrderedDict, deque
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from ..core.api import (ColoringPlan, ColoringReport, ColoringSpec,
                        PlanShape, _plan_shape, compile_plan)

Request = Union[object, Tuple[object, ColoringSpec]]  # graph | (graph, spec)


def _latency_summary(lat_s: Sequence[float]) -> dict:
    if not lat_s:
        return {"count": 0}
    a = np.asarray(lat_s, np.float64) * 1e3
    return {
        "count": int(a.size),
        "mean_ms": float(a.mean()),
        "p50_ms": float(np.percentile(a, 50)),
        "p95_ms": float(np.percentile(a, 95)),
        "max_ms": float(a.max()),
    }


@dataclasses.dataclass(frozen=True)
class ServedReport:
    """One served request: the report plus the service-side bookkeeping
    (which cache key it rode, whether the plan was compiled for it, and
    whether it went through a vmapped micro-batch)."""

    report: ColoringReport
    key: Tuple[ColoringSpec, PlanShape]
    cache_hit: bool
    batched: bool
    latency_s: float


class ColoringService:
    """An in-process coloring server with a compiled-plan LRU cache.

    cache_size   max resident plans; least-recently-used plans evict.
    default_spec spec applied to bare-graph requests (default:
                 ``ColoringSpec()`` — iterative/d1/sort).

    The cache key is the request's *bucket envelope*: vertex count exact,
    directed-edge capacity and max-degree bound rounded up the
    ``pad_bucket`` ladder. Same-family graphs therefore share one plan —
    and one jit trace — however their raw edge counts jitter.
    """

    def __init__(self, *, cache_size: int = 32,
                 default_spec: Optional[ColoringSpec] = None,
                 latency_window: int = 4096):
        if cache_size < 1:
            raise ValueError("cache_size must be >= 1")
        self.cache_size = int(cache_size)
        self.default_spec = default_spec or ColoringSpec()
        self._plans: "OrderedDict[Tuple[ColoringSpec, PlanShape], ColoringPlan]" = OrderedDict()
        # sliding latency window: a long-lived service must not grow one
        # float per request forever, and stats() must not re-percentile an
        # unbounded history — counters/throughput stay exact over the full
        # lifetime, percentiles cover the last `latency_window` requests
        self._lat: deque = deque(maxlen=int(latency_window))
        self._counters = dict(requests=0, cache_hits=0, cache_misses=0,
                              evictions=0, batched_requests=0,
                              micro_batches=0)
        self._t_serving = 0.0

    # ------------------------------------------------------------- the cache
    def envelope(self, spec: ColoringSpec, graph) -> PlanShape:
        """The bucket envelope a request is served under (== cache key
        shape): constraint-space vertex count, pad_bucket edge capacity,
        and the max-degree bound rounded up to a full power-of-two octave
        (floored at 8). Degree is quantized much more coarsely than edges
        on purpose: max-degree jitter across one graph family spans tens
        of percent (R-MAT hubs), and an oversized color table is cheap
        next to the retrace a fragmented cache key would cost.

        (Known cleanup: this lowers the constraint graph once for the key
        and the plan call lowers it again — under d2/pd2 that is two host
        squarings per request; folding a pre-lowered host graph through
        the plan call would halve the host cost for those models.)"""
        raw = _plan_shape(spec, graph)
        d = int(raw.max_degree)
        return PlanShape(
            num_vertices=raw.num_vertices,
            padded_edges=raw.padded_edges,
            max_degree=max(8, 1 << (d - 1).bit_length()) if d > 0 else d)

    def plan_for(self, spec: ColoringSpec, graph_or_shape) -> Tuple[ColoringPlan, bool]:
        """The cached plan serving ``(spec, envelope)`` — compiled on first
        use, LRU-refreshed on every hit. Returns (plan, was_cache_hit)."""
        shape = (graph_or_shape if isinstance(graph_or_shape, PlanShape)
                 else self.envelope(spec, graph_or_shape))
        key = (spec, shape)
        plan = self._plans.get(key)
        if plan is not None:
            self._plans.move_to_end(key)
            self._counters["cache_hits"] += 1
            return plan, True
        self._counters["cache_misses"] += 1
        plan = compile_plan(spec, shape)
        self._plans[key] = plan
        if len(self._plans) > self.cache_size:
            self._plans.popitem(last=False)
            self._counters["evictions"] += 1
        return plan, False

    # ----------------------------------------------------------- the serving
    def _norm(self, req: Request) -> Tuple[object, ColoringSpec]:
        if isinstance(req, tuple) and len(req) == 2 \
                and isinstance(req[1], ColoringSpec):
            return req
        return req, self.default_spec

    def color(self, graph, spec: Optional[ColoringSpec] = None,
              **runtime) -> ServedReport:
        """Serve one request (``runtime`` kwargs flow to the plan — e.g.
        the ``"recolor"`` strategy's ``colors=``/``seed=`` warm start)."""
        spec = spec or self.default_spec
        t0 = time.perf_counter()
        plan, hit = self.plan_for(spec, graph)
        report = plan(graph, **runtime)
        dt = time.perf_counter() - t0
        self._record(dt)
        return ServedReport(report=report, key=(spec, plan.statics),
                            cache_hit=hit, batched=False, latency_s=dt)

    def color_batch(self, requests: Sequence[Request]) -> list:
        """Serve a batch: requests sharing a cache key micro-batch through
        ONE vmapped ``plan.map`` program (strategies that support it);
        the rest loop over their cached plan. Results come back in
        submission order as :class:`ServedReport`s."""
        reqs = [self._norm(r) for r in requests]
        groups: "OrderedDict[tuple, list]" = OrderedDict()
        for i, (g, spec) in enumerate(reqs):
            key = (spec, self.envelope(spec, g))
            groups.setdefault(key, []).append(i)
        out: list = [None] * len(reqs)
        for key, idxs in groups.items():
            spec, shape = key
            t0 = time.perf_counter()
            plan, hit = self.plan_for(spec, shape)
            if plan.strategy.supports_map and len(idxs) > 1:
                reports = plan.map([reqs[i][0] for i in idxs])
                dt = time.perf_counter() - t0
                self._counters["micro_batches"] += 1
                self._counters["batched_requests"] += len(idxs)
                for i, rep in zip(idxs, reports):
                    self._record(dt / len(idxs), serving=False)
                    out[i] = ServedReport(report=rep, key=key,
                                          cache_hit=hit, batched=True,
                                          latency_s=dt / len(idxs))
                self._t_serving += dt
            else:
                for j, i in enumerate(idxs):
                    t1 = time.perf_counter()
                    rep = plan(reqs[i][0])
                    now = time.perf_counter()
                    # the group's first request carries the plan lookup /
                    # compile cost, matching color() and the map path —
                    # stats stay comparable across serving paths
                    d1 = (now - t0) if j == 0 else (now - t1)
                    self._record(d1)
                    out[i] = ServedReport(report=rep, key=key,
                                          cache_hit=hit, batched=False,
                                          latency_s=d1)
                    hit = True  # later loop iterations reuse the plan
        return out

    def _record(self, dt: float, *, serving: bool = True):
        self._counters["requests"] += 1
        self._lat.append(dt)
        if serving:
            self._t_serving += dt

    # -------------------------------------------------------------- the stats
    def stats(self) -> dict:
        """Aggregate service stats: request/cache counters, resident plan
        count, latency summary in ms (over the sliding ``latency_window``),
        and end-to-end throughput (over the full lifetime)."""
        s = dict(self._counters)
        s["resident_plans"] = len(self._plans)
        s["latency"] = _latency_summary(list(self._lat))
        s["throughput_gps"] = (self._counters["requests"] / self._t_serving
                               if self._t_serving > 0 else 0.0)
        return s


# ---------------------------------------------------------------- CLI smoke
def main(argv=None):
    ap = argparse.ArgumentParser(
        description="coloring service smoke: serve R-MAT requests through "
                    "the plan cache, then stream edge deltas")
    ap.add_argument("--smoke", action="store_true",
                    help="small preset (scale 8, 16 requests)")
    ap.add_argument("--family", default="RMAT-G",
                    choices=["RMAT-ER", "RMAT-G", "RMAT-B"])
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8,
                    help="micro-batch size submitted per color_batch call")
    ap.add_argument("--strategy", default="dataflow")
    ap.add_argument("--engine", default="sort")
    ap.add_argument("--cache-size", type=int, default=8)
    ap.add_argument("--stream-batches", type=int, default=4,
                    help="edge-delta batches for the streaming demo "
                         "(0 disables)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.scale, args.requests = min(args.scale, 8), min(args.requests, 16)

    from ..core import DynamicColoring, rmat, validate_coloring

    spec = ColoringSpec(strategy=args.strategy, engine=args.engine,
                        concurrency=64)
    svc = ColoringService(cache_size=args.cache_size, default_spec=spec)
    graphs = [rmat.paper_graph(args.family, scale=args.scale, seed=s)
              for s in range(args.requests)]
    print(f"[serve] family={args.family} scale={args.scale} "
          f"requests={args.requests} batch={args.batch} "
          f"strategy={args.strategy} engine={args.engine}")

    t0 = time.perf_counter()
    served = []
    for i in range(0, len(graphs), args.batch):
        served.extend(svc.color_batch(graphs[i:i + args.batch]))
    wall = time.perf_counter() - t0
    for s_, g in zip(served, graphs):
        assert validate_coloring(g, s_.report.colors)
    st = svc.stats()
    lat = st["latency"]
    print(f"[serve] served {st['requests']} requests in {wall:.2f}s "
          f"({st['requests'] / wall:.1f} graphs/s)")
    print(f"[serve] cache: {st['cache_hits']} hits / "
          f"{st['cache_misses']} misses / {st['resident_plans']} plans "
          f"resident; {st['batched_requests']} requests in "
          f"{st['micro_batches']} vmapped micro-batches")
    print(f"[serve] latency: mean={lat['mean_ms']:.1f}ms "
          f"p50={lat['p50_ms']:.1f}ms p95={lat['p95_ms']:.1f}ms "
          f"max={lat['max_ms']:.1f}ms (max includes the compile)")

    if args.stream_batches > 0:
        g = graphs[0]
        rng = np.random.default_rng(0)
        dyn = DynamicColoring(
            g, ColoringSpec(strategy="recolor", engine=args.engine,
                            concurrency=64))
        m = max(1, g.num_edges // 100)  # ~1% edge-delta batches
        print(f"[serve] streaming: {args.stream_batches} delta batches of "
              f"~{m} inserts + ~{m} deletes (1% of |E|)")
        for b in range(args.stream_batches):
            V = g.num_vertices
            ins = np.stack([rng.integers(0, V, m),
                            rng.integers(0, V, m)], 1)
            cur = dyn.graph.undirected_edges()
            dels = cur[rng.integers(0, cur.shape[0], m)]
            dr = dyn.apply_batch(inserts=ins, deletes=dels)
            assert validate_coloring(dyn.graph, dyn.colors)
            print(f"[serve]   batch {b}: +{dr.inserted}/-{dr.deleted} "
                  f"edges, seed={dr.seed_size}, repaired={dr.repaired}, "
                  f"colors={dyn.num_colors} (bound {dyn.color_bound}), "
                  f"{dr.wall_time_s * 1e3:.1f}ms")
        print(f"[serve] streaming done: colors={dyn.num_colors}, "
              f"plan retraces={dyn.plan.traces} (1 = zero-retrace repairs), "
              f"recompiles={dyn.recompiles}")
    return svc


if __name__ == "__main__":
    main()
