"""Quickstart: the paper in ~50 lines.

Generates the paper's three R-MAT graph families, colors each with the
serial oracle (Alg. 1), the speculative ITERATIVE algorithm (Alg. 2) and the
dataflow fixpoint (Alg. 3-5, TPU adaptation), and validates the results.

The first-fit inner loop is pluggable (``--engine sort|bitmap|ell_pallas``,
see repro.core.engine); the ELL kernel path just needs the graph built in
the ELL layout — no hand-wired kernel closures. The coloring model is
pluggable too (``--model d1|d2``, see repro.core.distance2): ``d2`` colors
so that even two-hop neighbors differ, validated against the serial
distance-2 oracle.

    PYTHONPATH=src python examples/quickstart.py [--scale 12] [--engine bitmap]
    PYTHONPATH=src python examples/quickstart.py --scale 8 --model d2
"""
import argparse

import numpy as np

from repro.core import (rmat, greedy_color, greedy_color_d2, color_iterative,
                        color_dataflow, validate_coloring,
                        validate_d2_coloring, num_colors, available_backends)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--concurrency", type=int, default=128)
    ap.add_argument("--engine", default="sort", choices=available_backends(),
                    help="first-fit mex backend for ITERATIVE/DATAFLOW")
    ap.add_argument("--model", default="d1", choices=["d1", "d2"],
                    help="coloring model: distance-1 or distance-2 "
                         "(d2 is denser — prefer --scale <= 9)")
    args = ap.parse_args()

    serial_fn = greedy_color if args.model == "d1" else greedy_color_d2
    valid_fn = validate_coloring if args.model == "d1" else validate_d2_coloring
    # D2 constraint graphs are ~avg-degree x denser: conflict rounds rise
    p = args.concurrency if args.model == "d1" else min(args.concurrency, 16)
    for name in ["RMAT-ER", "RMAT-G", "RMAT-B"]:
        g = rmat.paper_graph(name, scale=args.scale, seed=0)

        serial = serial_fn(g)
        it = color_iterative(g, concurrency=p, engine=args.engine,
                             model=args.model, max_rounds=256)
        df = color_dataflow(g, engine=args.engine, model=args.model)

        assert valid_fn(g, serial)
        assert valid_fn(g, np.asarray(it.colors))
        assert valid_fn(g, np.asarray(df.colors))
        exact = np.array_equal(np.asarray(df.colors), serial)

        s = g.stats()
        print(f"{name}: |V|={s['num_vertices']} |E|={s['num_edges']} "
              f"maxdeg={s['max_degree']} engine={args.engine} "
              f"model={args.model}")
        print(f"  serial greedy : {num_colors(serial):3d} colors")
        print(f"  ITERATIVE(P={p}): {it.num_colors:3d} colors, "
              f"{it.rounds} rounds, {it.total_conflicts} conflicts")
        print(f"  DATAFLOW      : {df.num_colors:3d} colors, "
              f"{df.sweeps} sweeps, identical to serial: {exact}")


if __name__ == "__main__":
    main()
