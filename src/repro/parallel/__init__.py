from .sharding import (
    Rules, DEFAULT_RULES, logical_to_spec, constrain,
    activation_rules, current_rules, rules_for_mesh, spec_for_array,
)

__all__ = [
    "Rules", "DEFAULT_RULES", "logical_to_spec",
    "constrain", "activation_rules", "current_rules", "rules_for_mesh",
    "spec_for_array",
]
