"""Typed findings — the analyzer's one output currency (DESIGN.md §Analysis).

Every check in :mod:`repro.analysis` reports :class:`Finding` values; nothing
prints, raises or warns on its own. Severity is three-valued:

* ``info``    — classified and benign by construction (idempotent constant
                stores, commutative scatter reductions, static-index writes).
                Never gates anything; the CLI shows them under ``-v``.
* ``warning`` — benign only under an argument the analyzer cannot make
                itself (the paper's speculate-then-resolve model, a
                distinctness-by-construction claim). Must be allowlisted in
                the committed baseline WITH a reason string, or CI fails.
* ``error``   — a genuine hazard (non-idempotent overlapping accumulation,
                a trace-time static-arg sentinel, a bit-field overflow).
                Also allowlistable — some hazards are accepted deliberately
                — but the default posture is: fix it.

The ``fingerprint`` (``CODE@site``) is what baselines match on. Sites are
``<package-relative file>:<function>`` with NO line numbers, so refactors
that move code within a function never invalidate the baseline, while
moving a race to a new function (a new benignity argument) does.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Tuple

SEVERITIES = ("info", "warning", "error")

# finding-code registry: code -> (default severity, one-line meaning).
# Codes are append-only — baselines reference them by string.
CODES = {
    # race classifier (races.py)
    "RACE101": ("info", "commutative-idempotent scatter reduction "
                        "(min/max/and/or): order-independent, benign"),
    "RACE102": ("info", "static-index store: indices derive from "
                        "constants/iota only — no data-driven overlap"),
    "RACE103": ("info", "idempotent constant store: overlapping writes all "
                        "write the same constant (the bitmap scatter-or)"),
    "RACE104": ("info", "single-site store: one update row, trivially "
                        "unique"),
    "RACE300": ("warning", "speculative overlapping store: data-driven "
                           "indices, last-writer-wins — benign ONLY under "
                           "the paper's conflict-detected speculation model "
                           "(allowlist with the argument)"),
    "RACE301": ("warning", "unique_indices asserted on data-driven indices: "
                           "undefined behavior if the assertion is ever "
                           "violated (allowlist with the distinctness "
                           "argument)"),
    "RACE201": ("error", "floating-point scatter-accumulate: "
                         "accumulation-order nondeterminism"),
    "RACE202": ("error", "non-idempotent overlapping accumulation "
                         "(add/mul): double-counts under speculative "
                         "replay"),
    # retrace-hazard lint (retrace.py)
    "RETRACE001": ("error", "static jit arg admits a None sentinel resolved "
                            "at trace time: the resolved value freezes into "
                            "the jit cache (the PR-6 interpret=None class)"),
    "RETRACE002": ("error", "static jit arg has a non-hashable default: "
                            "every call re-traces (or raises)"),
    "RETRACE003": ("error", "concrete data array baked into the trace as a "
                            "constant: a closure-captured value defeats the "
                            "plan envelope's zero-retrace guarantee"),
    # budget checker (budgets.py)
    "BIT001": ("error", "color bound collides with the packed-entry "
                        "FORBID/CONFLICT bits (color field is bits 0..27)"),
    "BIT002": ("error", "words= capacity override exceeds the packed-entry "
                        "color field"),
    "IDX001": ("error", "ELL slab addressing (V+1)*D overflows int32 index "
                        "arithmetic"),
    "IDX002": ("error", "edge-list capacity overflows int32 index "
                        "arithmetic"),
    "VMEM001": ("error", "kernel per-grid-step VMEM footprint estimate "
                         "exceeds the configured ceiling"),
    # SPMD collective safety (collectives.py)
    "COLL101": ("info", "unconditional collective in the shard program "
                        "(inventory: every device reaches it every round)"),
    "COLL102": ("info", "cond-guarded collectives verified safe: the "
                        "predicate is provably shard-uniform (derived from "
                        "a full-axis reduction), so every device takes the "
                        "same branch"),
    "COLL103": ("warning", "collectives under a predicate the analyzer "
                           "cannot prove shard-uniform: the branch pair "
                           "issues identical ordered collective sequences "
                           "(operationally safe TODAY, one edit from "
                           "deadlock — allowlist with the uniformity "
                           "argument)"),
    "COLL201": ("error", "cond branches issue mismatched collective "
                         "sequences under a predicate not provably "
                         "shard-uniform: devices taking different branches "
                         "block on different collectives (SPMD deadlock)"),
    "COLL202": ("error", "collective inside a loop whose continuation "
                         "predicate is not provably shard-uniform: devices "
                         "can exit on different rounds and leave peers "
                         "blocked in the collective (ragged-exit deadlock)"),
    "COLL203": ("error", "a loop-carried buffer patched from this round's "
                         "exchange is never read before being carried out: "
                         "the conflict pass consumes a stale snapshot"),
    # static wire-cost model (wirecost.py)
    "WIRE101": ("info", "per-round bytes-on-wire cost table entry "
                        "(machine-readable; the dist_scale benchmark "
                        "asserts measured bytes against it)"),
    "WIRE201": ("error", "a wire tier's traced per-round bytes diverge from "
                         "the closed-form accounting documented in "
                         "DESIGN.md §Perf (code/doc drift)"),
    "WIRE202": ("error", "per-round collective matches no documented wire "
                         "tier: unaccounted bytes on the wire"),
    "WIRE203": ("error", "pre-loop setup exchange diverges from the "
                         "one-time D*Bl*4 boundary-map gather accounting"),
    # halo exactness (halo.py)
    "HALO101": ("info", "halo exactness proof: every per-round payload is a "
                        "boundary/slab selection and raw gathered state "
                        "reaches no conflict compare or mex table except "
                        "through the snapshot patch"),
    "HALO201": ("error", "a per-round payload in the boundary-wire program "
                         "carries the full local state: interior entries "
                         "ship on the wire (the boundary selection was "
                         "bypassed)"),
    "HALO202": ("error", "raw gathered payload reaches a conflict "
                         "equality-compare or a non-snapshot table scatter "
                         "without passing the [Vp] snapshot patch: remote "
                         "interior state becomes referenceable"),
    # dead-code report (deadcode.py)
    "DEAD001": ("warning", "public export referenced nowhere outside its "
                           "defining module"),
    "DEAD100": ("info", "module carries a '# pending:' pragma: exports "
                        "exempt from DEAD001 until wired up"),
    # infrastructure
    "ANALYSIS000": ("warning", "a program could not be traced/analyzed; "
                               "the cell is unverified, not clean"),
}


class AnalysisError(RuntimeError):
    """Raised by ``verify="error"`` paths on non-allowlisted findings."""


@dataclasses.dataclass(frozen=True)
class Finding:
    """One typed analyzer result.

    code      registry key (CODES);
    site      ``<file>:<function>`` provenance (package-relative, no line
              numbers — the stable half of the fingerprint);
    message   human-readable specifics (shapes, values, dtypes);
    context   which plan produced it (``strategy/engine/model``), or the
              analysis pass name for non-plan findings. NOT part of the
              fingerprint: one allowlist entry covers every plan that
              shares the site.
    severity  defaults to the code's registry severity.
    """

    code: str
    site: str
    message: str
    context: str = ""
    severity: str = ""

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unregistered finding code {self.code!r}")
        if not self.severity:
            object.__setattr__(self, "severity", CODES[self.code][0])
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")

    @property
    def fingerprint(self) -> str:
        return f"{self.code}@{self.site}"

    def format(self) -> str:
        ctx = f" [{self.context}]" if self.context else ""
        return f"{self.severity:7s} {self.code} {self.site}{ctx}: {self.message}"


def dedupe(findings: Iterable[Finding]) -> List[Finding]:
    """Collapse findings sharing a fingerprint (the same site re-traced
    under many plans), keeping the first and folding the distinct contexts
    into it."""
    by_fp: dict = {}
    order: List[str] = []
    ctxs: dict = {}
    for f in findings:
        fp = f.fingerprint
        if fp not in by_fp:
            by_fp[fp] = f
            order.append(fp)
            ctxs[fp] = []
        if f.context and f.context not in ctxs[fp]:
            ctxs[fp].append(f.context)
    out = []
    for fp in order:
        f = by_fp[fp]
        merged = ctxs[fp]
        ctx = merged[0] if len(merged) == 1 else (
            f"{merged[0]} +{len(merged) - 1} more" if merged else f.context)
        out.append(dataclasses.replace(f, context=ctx))
    return out


def gating(findings: Iterable[Finding]) -> List[Finding]:
    """The findings that must be allowlisted or fixed (warning + error)."""
    return [f for f in findings if f.severity != "info"]


def split_by_severity(findings: Iterable[Finding]
                      ) -> Tuple[List[Finding], List[Finding], List[Finding]]:
    fs = list(findings)
    return ([f for f in fs if f.severity == "error"],
            [f for f in fs if f.severity == "warning"],
            [f for f in fs if f.severity == "info"])
