"""Frontier execution layer tests (repro.core.frontier): bit-parity vs
``frontier="off"`` across the strategy x engine x model matrix, the
spill-to-full fallback on slab overflow, the compaction-completeness
property (no active constraint edge is ever dropped), and the plan
zero-retrace guarantee with the frontier enabled (tier-1's regression pin
for the PR-3 contract)."""
import numpy as np
import pytest

from repro.core import (BipartiteGraph, ColoringSpec, Graph, PlanShape,
                        color, compile_plan, rmat, validate_coloring,
                        validate_d2_coloring, validate_pd2_coloring)
from repro.core.frontier import (compact_frontier, frontier_capacities,
                                 resolve_frontier)
from repro.core.graph import pad_bucket

STRATEGIES = ["iterative", "dataflow"]
ENGINES = ["sort", "bitmap", "ell_pallas"]
MODELS = ["d1", "d2", "pd2"]


def _graph(name="RMAT-G", scale=8, seed=1):
    return rmat.paper_graph(name, scale=scale, seed=seed)


def _bipartite(seed=0, L=120, R=80, m=600):
    rng = np.random.default_rng(seed)
    return BipartiteGraph.from_edges(
        L, R, np.stack([rng.integers(0, L, m), rng.integers(0, R, m)], 1))


def _assert_same_report(off, on):
    np.testing.assert_array_equal(off.colors, on.colors)
    assert off.rounds == on.rounds
    np.testing.assert_array_equal(off.conflicts_per_round,
                                  on.conflicts_per_round)
    np.testing.assert_array_equal(off.sweeps_per_round, on.sweeps_per_round)


# ------------------------------------------------------------- bit parity
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("model", MODELS)
def test_frontier_bit_parity_matrix(strategy, engine, model):
    """THE frontier guarantee: identical colors, rounds, conflict and sweep
    histories with the frontier on vs off, for every strategy x engine x
    model cell (square lowering — the frontier needs row-deduped CSR)."""
    g = _bipartite() if model == "pd2" else _graph(scale=8)
    base = dict(strategy=strategy, model=model, engine=engine,
                lowering="square", concurrency=8, max_rounds=256)
    off = color(g, ColoringSpec(frontier="off", **base))
    on = color(g, ColoringSpec(frontier="on", **base))
    _assert_same_report(off, on)
    valid = {"d1": validate_coloring, "d2": validate_d2_coloring,
             "pd2": validate_pd2_coloring}[model]
    assert valid(g, on.colors)


def test_frontier_engages_and_reports_sizes():
    """With a generous slab every round >= 1 runs compacted, and the report
    exposes the per-round frontier sizes (== the previous round's conflict
    count for ITERATIVE)."""
    g = _graph("RMAT-G", scale=10, seed=0)
    on = color(g, strategy="iterative", concurrency=64, max_rounds=256,
               frontier="on", frontier_capacity=1 << 10)
    off = color(g, strategy="iterative", concurrency=64, max_rounds=256,
                frontier="off")
    _assert_same_report(off, on)
    assert on.rounds > 1, "need a conflicted run to exercise the frontier"
    fs = on.frontier_sizes_per_round
    assert fs[0] == 0                      # round 0 always takes the full path
    np.testing.assert_array_equal(fs[1:], on.conflicts_per_round[:-1])
    assert off.frontier_sizes_per_round.sum() == 0


def test_frontier_dataflow_active_set_sweeps():
    """DATAFLOW's frontier compacts the changed-dependent active set per
    sweep; entry 0 of the frontier history counts the compacted sweeps."""
    g = _graph("RMAT-ER", scale=9, seed=2)
    on = color(g, strategy="dataflow", frontier="on",
               frontier_capacity=1 << 9)
    off = color(g, strategy="dataflow", frontier="off")
    np.testing.assert_array_equal(off.colors, on.colors)
    assert off.sweeps == on.sweeps
    assert int(on.frontier_sizes_per_round[0]) > 0
    assert int(off.frontier_sizes_per_round[0]) == 0


def test_frontier_overflow_spills_to_full_path():
    """A deliberately tiny slab forces the spill: rounds whose pending set
    overflows run the full path (frontier size 0), later rounds that fit
    run compacted — and the result is STILL bit-identical."""
    g = _graph("RMAT-B", scale=9, seed=0)
    off = color(g, strategy="iterative", concurrency=256, max_rounds=256,
                frontier="off")
    on = color(g, strategy="iterative", concurrency=256, max_rounds=256,
               frontier="on", frontier_capacity=8)
    _assert_same_report(off, on)
    fs = on.frontier_sizes_per_round
    conf = np.concatenate([[g.num_vertices], on.conflicts_per_round[:-1]])
    cap_v, cap_e = frontier_capacities(
        g.num_vertices, g.num_directed_edges, g.max_degree(), capacity=8)
    spilled = fs[1:][conf[1:] > cap_v]
    assert spilled.size and (spilled == 0).all(), \
        "overflowing rounds must take the full path"
    assert (fs[1:][fs[1:] > 0] <= cap_v).all()


def test_frontier_off_for_wedge_lowering_auto_and_raises_on():
    """The wedge multiset carries no incident-edge auxiliary: frontier
    'auto' silently runs full sweeps, 'on' refuses loudly."""
    g = _graph(scale=7)
    auto = color(g, model="d2", lowering="wedge", concurrency=8,
                 max_rounds=256)  # frontier defaults to "auto"
    assert auto.frontier_sizes_per_round.sum() == 0
    with pytest.raises(ValueError, match="frontier='on'"):
        color(g, model="d2", lowering="wedge", frontier="on",
              concurrency=8, max_rounds=256)
    with pytest.raises(ValueError, match="unknown frontier mode"):
        ColoringSpec(frontier="maybe")


# ------------------------------------------------------- compaction property
def _compaction_reference(g: Graph, active: np.ndarray):
    src, dst = g.directed_edges()
    keep = active[src]
    return sorted(zip(src[keep].tolist(), dst[keep].tolist()))


def _check_compaction(g: Graph, active: np.ndarray):
    dg = g.to_device()
    deg = np.diff(g.row_ptr)
    nv = int(active.sum())
    ne = int(deg[active].sum())
    cap_v = pad_bucket(max(nv, 1), min_bucket=8)
    cap_e = pad_bucket(max(ne, 1), min_bucket=8)
    slab = compact_frontier(np.asarray(active), dg.inc_ptr, dg.dst,
                            cap_v, cap_e)
    assert int(slab.nv) == nv and int(slab.ne) == ne
    vert = np.asarray(slab.vert)
    src_s, dst_s = np.asarray(slab.src), np.asarray(slab.dst)
    owner = np.asarray(slab.owner)
    live_v = vert < g.num_vertices
    np.testing.assert_array_equal(np.sort(vert[live_v]),
                                  np.flatnonzero(active))
    live_e = src_s < g.num_vertices
    got = sorted(zip(src_s[live_e].tolist(), dst_s[live_e].tolist()))
    assert got == _compaction_reference(g, active), \
        "compaction dropped or invented an active constraint edge"
    # owner/slot consistency: each slab edge sits in its owner's row
    np.testing.assert_array_equal(src_s[live_e], vert[owner[live_e]])


def test_compaction_explicit_cases():
    n = 12
    ring = Graph.from_edges(
        n, np.stack([np.arange(n), (np.arange(n) + 1) % n], 1))
    star = Graph.from_edges(
        n, np.stack([np.zeros(n - 1, np.int64), np.arange(1, n)], 1))
    for g in (ring, star):
        for mask in (np.zeros(n, bool),
                     np.ones(n, bool),
                     np.arange(n) % 3 == 0):
            _check_compaction(g, mask)


def test_compaction_overflow_reports_true_counts():
    """When the active set exceeds the slab, nv/ne still report the TRUE
    counts (the spill signal) and the slab stays well-formed."""
    n = 32
    rng = np.random.default_rng(0)
    g = Graph.from_edges(
        n, np.stack([rng.integers(0, n, 200), rng.integers(0, n, 200)], 1))
    dg = g.to_device()
    active = np.ones(n, bool)
    slab = compact_frontier(np.asarray(active), dg.inc_ptr, dg.dst, 8, 16)
    assert int(slab.nv) == n
    assert int(slab.ne) == g.num_directed_edges
    assert (np.asarray(slab.vert) < n).all()       # first 8 active vertices
    src_s = np.asarray(slab.src)
    dst_s = np.asarray(slab.dst)
    live = src_s < n
    ref = dict()
    gs, gd = g.directed_edges()
    for pair in zip(gs.tolist(), gd.tolist()):
        ref[pair] = ref.get(pair, 0) + 1
    for pair in zip(src_s[live].tolist(), dst_s[live].tolist()):
        assert pair in ref, "overflowed compaction fabricated an edge"


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is in requirements.txt
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def graph_and_mask(draw, max_v=24, max_e=80):
        n = draw(st.integers(2, max_v))
        m = draw(st.integers(0, max_e))
        edges = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        mask = draw(st.lists(st.booleans(), min_size=n, max_size=n))
        g = Graph.from_edges(n, np.array(edges or [[0, 0]], dtype=np.int64))
        return g, np.array(mask, bool)

    @settings(max_examples=40, deadline=None)
    @given(graph_and_mask())
    def test_compaction_never_drops_an_active_edge(gm):
        """Property: the slab edge multiset == every directed constraint
        edge whose src is active, exactly once, whenever the slab fits."""
        g, mask = gm
        _check_compaction(g, mask)


# --------------------------------------------------- plans: zero retrace
def test_frontier_plan_zero_retrace():
    """The PR-3 contract survives the frontier: a frontier-enabled plan
    serves same-bucket graphs with plan.traces pinned at one (capacities
    come from the static envelope, never from data)."""
    gs = [_graph("RMAT-G", scale=8, seed=s) for s in range(3)]
    shape = PlanShape(
        num_vertices=gs[0].num_vertices,
        padded_edges=pad_bucket(max(g.num_directed_edges for g in gs)),
        max_degree=max(g.max_degree() for g in gs))
    for mode in ["auto", "on"]:
        spec = ColoringSpec(strategy="iterative", engine="bitmap",
                            concurrency=64, frontier=mode,
                            frontier_capacity=1 << 10)
        plan = compile_plan(spec, shape)
        reports = [plan(g) for g in gs]
        assert plan.traces == 1, mode
        for g, rep in zip(gs, reports):
            assert validate_coloring(g, rep.colors)
            off = color(g, ColoringSpec(strategy="iterative", engine="bitmap",
                                        concurrency=64, frontier="off"))
            np.testing.assert_array_equal(rep.colors, off.colors)
        assert any(r.frontier_sizes_per_round.sum() > 0 for r in reports), \
            "plan runs never exercised the frontier path"


def test_frontier_distributed_parity_2dev():
    """The BSP driver's per-shard frontier (compacted local solve + the
    shrunken frontier-halo wire) is bit-identical to the full wire across
    a real multi-device mesh, and engages once per-device pending sets fit
    their slabs."""
    import json
    import os
    import subprocess
    import sys
    import textwrap
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = src
    code = textwrap.dedent("""
        import json, numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import rmat, color, validate_coloring
        mesh = Mesh(np.array(jax.devices()[:2]), ("x",))
        g = rmat.paper_graph("RMAT-B", scale=9, seed=3)
        off = color(g, strategy="distributed", mesh=mesh, max_sweeps=16384,
                    frontier="off")
        on = color(g, strategy="distributed", mesh=mesh, max_sweeps=16384,
                   frontier="on", frontier_capacity=1 << 8)
        print(json.dumps(dict(
            valid=bool(validate_coloring(g, on.colors)),
            same=bool(np.array_equal(off.colors, on.colors)),
            rounds=[int(off.rounds), int(on.rounds)],
            conf_same=bool(np.array_equal(off.conflicts_per_round,
                                          on.conflicts_per_round)),
            sweeps_same=bool(np.array_equal(off.sweeps_per_round,
                                            on.sweeps_per_round)),
            frontier=[int(x) for x in on.frontier_sizes_per_round],
            frontier_off=int(off.frontier_sizes_per_round.sum()))))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["valid"] and r["same"] and r["conf_same"] and r["sweeps_same"]
    assert r["rounds"][0] == r["rounds"][1]
    assert r["frontier_off"] == 0
    assert sum(r["frontier"]) > 0, "distributed frontier never engaged"


def test_resolve_frontier_modes():
    g = _graph(scale=7)
    dg = g.to_device()
    assert resolve_frontier("off", 0, num_vertices=dg.num_vertices,
                            padded_edges=dg.padded_edges,
                            max_degree=dg.max_degree, has_inc=True) == (0, 0)
    cv, ce = resolve_frontier("auto", 0, num_vertices=dg.num_vertices,
                              padded_edges=dg.padded_edges,
                              max_degree=dg.max_degree, has_inc=True)
    assert cv > 0 and ce >= cv
    # capacities ride the pad_bucket ladder (static-shape quantization)
    assert cv == pad_bucket(cv, min_bucket=8)
    assert ce == pad_bucket(ce, min_bucket=8)
    assert resolve_frontier("auto", 0, num_vertices=dg.num_vertices,
                            padded_edges=dg.padded_edges,
                            max_degree=dg.max_degree,
                            has_inc=False) == (0, 0)
