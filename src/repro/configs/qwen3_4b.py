"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936,
qk_norm. [hf:Qwen/Qwen3-8B family]"""
from ..models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", family="dense", num_layers=36, d_model=2560,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=9728, vocab_size=151936,
        qk_norm=True, rope_theta=1_000_000.0)


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense", num_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        qk_norm=True, rope_theta=1_000_000.0)
