"""The production train step: loss -> grad -> clip -> AdamW, with optional
microbatch gradient accumulation (scan), NaN-step rejection, and donated
buffers. Under pjit the DP gradient all-reduce is implicit in the batch
sharding; the optional int8-compressed explicit variant lives in
``compression.py`` (shard_map).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .. import models
from .optimizer import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1           # gradient accumulation steps
    donate: bool = True
    # mixed precision: compute with bf16 params (fp32 masters stay in the
    # optimizer domain). The bf16 cast happens on the FSDP-SHARDED params, so
    # every per-layer all-gather moves half the bytes (§Perf H-A1).
    bf16_compute_params: bool = False


def make_train_step(model_cfg, opt_cfg: AdamWConfig,
                    ts_cfg: TrainStepConfig = TrainStepConfig()):
    """Returns ``train_step(params, opt_state, batch) ->
    (params', opt_state', metrics)`` (pure; jit/lower it with shardings)."""

    def loss_for(p, mb):
        if ts_cfg.bf16_compute_params:
            p = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if x.dtype == jnp.float32 else x, p)
        loss, metrics = models.loss_fn(model_cfg, p, mb)
        return loss, metrics

    def grads_of(params, batch):
        if ts_cfg.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
            return loss, metrics, grads

        mb_count = ts_cfg.microbatches

        def reshape_mb(x):
            b = x.shape[0]
            assert b % mb_count == 0, (b, mb_count)
            return x.reshape(mb_count, b // mb_count, *x.shape[1:])

        mbs = jax.tree.map(reshape_mb, batch)

        def acc_body(carry, mb):
            loss_acc, g_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_for, has_aux=True)(params, mb)
            g_acc = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype), g_acc, g)
            return (loss_acc + loss, g_acc), metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), metrics = lax.scan(acc_body, (0.0, g0), mbs)
        grads = jax.tree.map(lambda g: g / mb_count, gsum)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / mb_count, metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        skip = ~jnp.isfinite(loss)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, params, opt_state, skip=skip)
        out = {"loss": loss, **{k: v for k, v in metrics.items()},
               **opt_metrics}
        return params, opt_state, out

    return train_step
