"""Batched serving driver: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --batch 8 --prompt-len 64 --gen 32

Serving-path features: prefill-then-decode cache contract (tested per arch),
greedy/temperature sampling, per-sequence cur_len, throughput report plus
per-token decode latency percentiles via the shared serving metrics
tracker (repro.serve.metrics.WindowedMetrics — the same instrument the
coloring service exports).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from .. import models


def sample(logits, key, temperature: float):
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params, _ = models.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, t = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vlm.num_image_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encdec.enc_seq, cfg.d_model)), jnp.float32)

    max_len = t + args.gen
    caches = models.init_cache(cfg, b, max_len)
    t0 = time.time()
    logits, _, caches = models.forward(cfg, params, batch, caches=caches)
    prefill_s = time.time() - t0
    step = jax.jit(lambda p, c, tok: models.decode_step(cfg, p, c, tok))
    key = jax.random.PRNGKey(1)
    tok = sample(logits[:, -1], key, args.temperature)

    from ..serve.metrics import WindowedMetrics
    metrics = WindowedMetrics()

    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        ts = time.perf_counter()
        key, sub = jax.random.split(key)
        logits_i, caches = step(params, caches, tok)
        tok = sample(logits_i, sub, args.temperature)
        tok.block_until_ready()
        dt = time.perf_counter() - ts
        # one decode step == one size-1 "batch" flush: the first step
        # carries the jit trace, which the max/p99 split makes visible
        metrics.record_flush("size", latencies=[dt], queue_ages=[0.0],
                             exec_s=dt, batched=True)
        out.append(tok)
    decode_s = time.time() - t0
    gen = np.stack([np.asarray(t_) for t_ in out], axis=1)
    win = metrics.snapshot()["window"]
    print(f"[serve] arch={cfg.name} batch={b} prompt={t} gen={args.gen}")
    print(f"[serve] prefill: {prefill_s:.2f}s ({b*t/max(prefill_s,1e-9):.0f} tok/s)")
    print(f"[serve] decode:  {decode_s:.2f}s ({b*(args.gen-1)/max(decode_s,1e-9):.1f} tok/s)")
    if win["count"]:
        print(f"[serve] decode step latency: p50={win['p50_ms']:.1f}ms "
              f"p99={win['p99_ms']:.1f}ms max={win['max_ms']:.1f}ms "
              f"(max = the jit trace)")
    print(f"[serve] sample row: {gen[0][:16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
