"""Serial greedy coloring — Algorithm 1 of the paper, used as the oracle.

Implements the exact first-fit formulation with the *vertex-stamped*
``forbiddenColors`` array (no per-vertex reinitialization; O(|V|+|E|) total),
which is the foundation of both parallel algorithms. numpy/host-side; this is
the reference the JAX implementations are validated against.
"""
from __future__ import annotations

import numpy as np

from .graph import Graph


def greedy_color(graph: Graph, order: np.ndarray | None = None) -> np.ndarray:
    """Color ``graph`` greedily visiting vertices in ``order``.

    Returns colors[V] (1-based; every vertex colored). With ``order=None``
    vertices are visited in natural index order — the order the parallel
    DATAFLOW algorithm reproduces exactly.
    """
    n = graph.num_vertices
    if order is None:
        order = np.arange(n, dtype=np.int64)
    colors = np.zeros(n, dtype=np.int32)
    # stamped with the vertex id being colored; init with a value not in V
    forbidden = np.full(graph.max_degree() + 2, -1, dtype=np.int64)
    row_ptr, col_idx = graph.row_ptr, graph.col_idx
    for v in order:
        nbrs = col_idx[row_ptr[v]:row_ptr[v + 1]]
        nc = colors[nbrs]
        forbidden[nc[nc > 0]] = v  # mark colors of colored neighbors
        # smallest positive index not stamped with v
        c = 1
        while forbidden[c] == v:
            c += 1
        colors[v] = c
    return colors


def num_colors(colors: np.ndarray) -> int:
    return int(colors.max()) if colors.size else 0
