"""Quickstart: the paper in ~50 lines, driven through the spec front door.

Generates the paper's three R-MAT graph families and colors each through
``repro.core.color`` with a single declarative ``ColoringSpec`` — strategy
(``--strategy iterative|dataflow|distributed``), first-fit mex backend
(``--engine sort|bitmap|ell_pallas|fused_pallas`` — the last runs the
fused detect→mex round kernel), coloring model (``--model d1|d2``)
and vertex ordering (``--ordering natural|random|largest_first|
smallest_last``) all compose without any per-driver dispatch — then
validates every result against the model's rules and serial oracle.

    PYTHONPATH=src python examples/quickstart.py [--scale 12] [--engine bitmap]
    PYTHONPATH=src python examples/quickstart.py --strategy dataflow \\
        --ordering largest_first
    PYTHONPATH=src python examples/quickstart.py --scale 8 --model d2
    PYTHONPATH=src python examples/quickstart.py --scale 8 --engine fused_pallas
    PYTHONPATH=src python examples/quickstart.py --scale 10 --stream 4

``--stream N`` additionally pushes N ~1%-edge delta batches through
``repro.core.DynamicColoring`` — the streaming lane: inserts/deletes are
repaired in place by the ``"recolor"`` strategy, seeded with only the
newly conflicting endpoints, with zero retrace across batches.
"""
import argparse

import numpy as np

from repro.core import (rmat, color, ColoringSpec, DynamicColoring,
                        available_backends, available_strategies,
                        greedy_color, greedy_color_d2, validate_coloring,
                        validate_d2_coloring, num_colors)
from repro.core.ordering import ORDERINGS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--concurrency", type=int, default=128)
    ap.add_argument("--strategy", default="iterative",
                    choices=available_strategies(),
                    help="registered coloring strategy (repro.core.api)")
    ap.add_argument("--engine", default="sort", choices=available_backends(),
                    help="first-fit mex backend (repro.core.engine)")
    ap.add_argument("--ordering", default="natural", choices=sorted(ORDERINGS),
                    help="vertex-visit ordering (paper §5.1); colors are "
                         "reported in original vertex ids regardless")
    ap.add_argument("--model", default="d1", choices=["d1", "d2"],
                    help="coloring model: distance-1 or distance-2 "
                         "(d2 is denser — prefer --scale <= 9)")
    ap.add_argument("--frontier", default="auto",
                    choices=["auto", "on", "off"],
                    help="active-set execution (repro.core.frontier): "
                         "compact rounds >= 1 into a fixed slab so they "
                         "cost O(frontier) instead of O(E); bit-identical "
                         "results either way")
    ap.add_argument("--stream", type=int, default=0, metavar="N",
                    help="after coloring, stream N ~1%%-edge delta batches "
                         "through repro.core.DynamicColoring: incremental "
                         "'recolor' repairs seeded by the newly conflicting "
                         "endpoints (d1 only)")
    args = ap.parse_args()

    serial_fn = greedy_color if args.model == "d1" else greedy_color_d2
    valid_fn = validate_coloring if args.model == "d1" else validate_d2_coloring
    # D2 constraint graphs are ~avg-degree x denser: conflict rounds rise
    p = args.concurrency if args.model == "d1" else min(args.concurrency, 16)
    # frontier="on" needs the square (row-deduped) lowering under d2;
    # "auto"/"off" keep the memory-lean default
    lowering = "square" if args.frontier == "on" else "auto"
    spec = ColoringSpec(strategy=args.strategy, model=args.model,
                        engine=args.engine, ordering=args.ordering,
                        concurrency=p, max_rounds=256,
                        frontier=args.frontier, lowering=lowering)
    for name in ["RMAT-ER", "RMAT-G", "RMAT-B"]:
        g = rmat.paper_graph(name, scale=args.scale, seed=0)

        serial = serial_fn(g)
        rep = color(g, spec)

        assert valid_fn(g, serial)
        assert valid_fn(g, rep.colors)

        s = g.stats()
        print(f"{name}: |V|={s['num_vertices']} |E|={s['num_edges']} "
              f"maxdeg={s['max_degree']} strategy={args.strategy} "
              f"engine={args.engine} model={args.model} "
              f"ordering={args.ordering}")
        frontier_rounds = int((rep.frontier_sizes_per_round > 0).sum())
        print(f"  serial greedy : {num_colors(serial):3d} colors")
        print(f"  {args.strategy:14s}: {rep.num_colors:3d} colors, "
              f"{rep.rounds} rounds, {rep.sweeps} sweeps, "
              f"{rep.total_conflicts} conflicts, "
              f"{frontier_rounds} frontier rounds, {rep.wall_time_s:.3f}s")
        if args.strategy == "dataflow" and args.ordering == "natural":
            # the dataflow fixpoint IS the serial greedy coloring
            assert np.array_equal(rep.colors, serial)
            print("                  (bit-identical to the serial oracle)")

        if args.stream > 0 and args.model != "d1":
            print("  (--stream skipped: streaming repair is d1 only — an "
                  "edge delta perturbs d2 constraints beyond its endpoints)")
        elif args.stream > 0:
            # streaming lane: ~1% edge-delta batches repaired in place by
            # the "recolor" strategy (repro.core.dynamic)
            dyn = DynamicColoring(
                g, ColoringSpec(strategy="recolor", engine=args.engine,
                                concurrency=p, max_rounds=256,
                                frontier=args.frontier))
            rng = np.random.default_rng(0)
            m = max(1, g.num_edges // 100)
            for _ in range(args.stream):
                ins = np.stack([rng.integers(0, g.num_vertices, m),
                                rng.integers(0, g.num_vertices, m)], 1)
                cur = dyn.graph.undirected_edges()
                dr = dyn.apply_batch(
                    inserts=ins,
                    deletes=cur[rng.integers(0, cur.shape[0], m)])
                assert valid_fn(dyn.graph, dyn.colors)
            print(f"  streamed {args.stream} delta batches (~{m} ins/del "
                  f"each): {dyn.num_colors} colors "
                  f"(bound {dyn.color_bound}), last seed "
                  f"{dr.seed_size}, retraces={dyn.plan.traces}, "
                  f"recompiles={dyn.recompiles}")


if __name__ == "__main__":
    main()
