"""Collective-safety pass — the SPMD-deadlock classifier (COLL codes).

Inside a ``shard_map`` program every device executes the same trace, so a
collective deadlocks exactly when control flow lets devices *reach
different collectives*: a ``lax.cond`` whose branches issue different
collective sequences under a predicate that can differ across shards, or
a ``lax.while_loop`` containing collectives whose continuation predicate
can differ (some devices exit, the rest block in the next gather).

The pass therefore needs a *shard-uniformity* analysis: a value is
**uniform** when every device provably holds the same value. Sources of
uniformity are literals/constants and the outputs of full-axis reducing
collectives (``psum``/``pmin``/``pmax``/``all_gather`` over every mesh
axis — replicated by construction); ``axis_index`` and the shard_map
operands are varying. Uniformity propagates through pure ops, through
``pjit`` bodies, through ``cond`` (uniform predicate + all-branch-uniform
outputs), and through ``while`` carriers by monotone fixpoint (a carrier
stays uniform only if its init AND its body image are uniform). This is
exactly how the shipping BSP program proves safe: the wire-selection
``all_fit`` vote is ``psum``-derived (COLL102), and the round loop's
``total > 0 & rnd < max_rounds`` predicate is uniform because ``total``
is the psum termination vote and ``rnd`` a uniformly-incremented carrier.

Checks emitted (codes in :mod:`.findings`):

* COLL101 info — unconditional collectives (inventory);
* COLL102 info — cond-guarded collectives under a proven-uniform
  predicate;
* COLL103 warning — unproven predicate, but identical ordered branch
  collective sequences (safe today, one edit from COLL201);
* COLL201 error — unproven predicate AND mismatched branch sequences;
* COLL202 error — collective inside a loop with an unproven continuation
  predicate (ragged-exit deadlock);
* COLL203 error — a loop carrier patched from this round's ``all_gather``
  payload is never read before being carried out (the conflict pass would
  be consuming a stale snapshot).
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

from .findings import Finding
from .jaxpr_walk import Literal, site_of
from .spmd import (COLLECTIVE_PRIMS, REPLICATING_PRIMS, collective_eqns,
                   collective_signature, cond_branches, find_shard_jaxprs,
                   is_full_axis, mesh_axis_names, sub_jaxpr, while_parts)

_MAX_FIXPOINT_ITERS = 64


def _fmt_sig(sig) -> str:
    prim, axes, ins, _ = sig
    shapes = ", ".join(f"{list(s)}:{d}" for s, d in ins)
    return f"{prim}[{','.join(axes)}]({shapes})"


class _UniformEnv:
    """var -> is-shard-uniform for one jaxpr scope (Literals are uniform)."""

    def __init__(self):
        self._u: Dict[object, bool] = {}

    def get(self, v) -> bool:
        return True if isinstance(v, Literal) else self._u.get(v, False)

    def set(self, v, uniform: bool) -> None:
        self._u[v] = bool(uniform)


def _propagate(jaxpr, in_uniform, mesh_axes, *, emit=None, env_out=None
               ) -> List[bool]:
    """Run the uniformity transfer over one jaxpr level, recursing into
    sub-jaxprs. ``in_uniform`` matches ``jaxpr.invars``; constvars are
    uniform (replicated host constants). Returns per-outvar uniformity.

    ``emit`` (a callback collecting findings) is only passed on the FINAL
    pass — while-loop fixpoint iterations re-run the transfer silently so
    findings are never duplicated. ``env_out`` optionally receives the
    scope's final env (the stale-snapshot check re-reads it)."""
    env = _UniformEnv()
    for v in jaxpr.constvars:
        env.set(v, True)
    for v, u in zip(jaxpr.invars, in_uniform):
        env.set(v, u)

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        ins = [env.get(v) for v in eqn.invars]

        if prim == "axis_index":
            outs = [False] * len(eqn.outvars)
        elif prim in COLLECTIVE_PRIMS:
            replicated = (prim in REPLICATING_PRIMS
                          and is_full_axis(eqn, mesh_axes))
            outs = [replicated] * len(eqn.outvars)
            if emit is not None:
                emit("collective", eqn, None)
        elif prim == "cond":
            outs = _do_cond(eqn, ins, mesh_axes, emit)
        elif prim == "while":
            outs = _do_while(eqn, ins, mesh_axes, emit)
        elif prim in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                      "custom_vjp_call", "remat", "checkpoint"):
            sub = sub_jaxpr(eqn.params.get("jaxpr",
                                           eqn.params.get("call_jaxpr")))
            if sub is None:
                outs = [all(ins)] * len(eqn.outvars)
            else:
                outs = _propagate(sub, ins, mesh_axes, emit=emit)
        elif prim == "scan":
            # conservative: recurse for findings with all-varying carries,
            # mark outputs varying (no scan in the shipping mesh program)
            sub = sub_jaxpr(eqn.params.get("jaxpr"))
            if sub is not None:
                _propagate(sub, [False] * len(sub.invars), mesh_axes,
                           emit=emit)
            outs = [False] * len(eqn.outvars)
        elif prim == "pallas_call":
            # device kernel: no collectives inside; uniform iff inputs are
            outs = [all(ins)] * len(eqn.outvars)
        else:
            # pure op (pvary included: it only re-tags the named-axis type)
            outs = [all(ins)] * len(eqn.outvars)

        for v, u in zip(eqn.outvars, outs):
            env.set(v, u)

    if env_out is not None:
        env_out.append(env)
    return [env.get(v) for v in jaxpr.outvars]


def _do_cond(eqn, in_uniform, mesh_axes, emit) -> List[bool]:
    branches = cond_branches(eqn)
    pred_uniform = in_uniform[0]
    operand_u = in_uniform[1:]
    # branch collectives are accounted by the cond-level COLL102/103/201
    # finding below, not the COLL101 unconditional inventory
    sub_emit = None if emit is None else (
        lambda kind, e, f: emit(kind, e, f) if kind == "finding" else None)
    branch_outs = [_propagate(b, list(operand_u), mesh_axes, emit=sub_emit)
                   for b in branches]
    sequences = [tuple(collective_signature(c) for c in collective_eqns(b))
                 for b in branches]
    has_colls = any(sequences)
    if has_colls and emit is not None:
        site = site_of(eqn)
        n = sum(len(s) for s in sequences)
        if pred_uniform:
            emit("finding", eqn, Finding(
                "COLL102", site,
                f"{n} collective(s) across {len(branches)} branch(es) under "
                f"a provably shard-uniform predicate — every device takes "
                f"the same branch"))
        elif all(s == sequences[0] for s in sequences[1:]):
            emit("finding", eqn, Finding(
                "COLL103", site,
                f"predicate not provably shard-uniform; the "
                f"{len(branches)} branches issue identical collective "
                f"sequences ({', '.join(_fmt_sig(s) for s in sequences[0])})"
                " — safe only while they stay identical"))
        else:
            rendered = " vs ".join(
                "[" + ", ".join(_fmt_sig(s) for s in seq) + "]"
                for seq in sequences)
            emit("finding", eqn, Finding(
                "COLL201", site,
                f"branch collective sequences mismatch under a predicate "
                f"not provably shard-uniform: {rendered}"))
    if not branch_outs:
        return [False] * len(eqn.outvars)
    if not pred_uniform:
        return [False] * len(eqn.outvars)
    return [all(bo[i] for bo in branch_outs)
            for i in range(len(eqn.outvars))]


def _do_while(eqn, in_uniform, mesh_axes, emit) -> List[bool]:
    cond_jaxpr, body_jaxpr, cn, bn = while_parts(eqn)
    cond_consts_u = in_uniform[:cn]
    body_consts_u = in_uniform[cn:cn + bn]
    carry_u = list(in_uniform[cn + bn:])

    # monotone fixpoint on carrier uniformity (silent iterations)
    for _ in range(_MAX_FIXPOINT_ITERS):
        out_u = _propagate(body_jaxpr, body_consts_u + carry_u, mesh_axes)
        new_u = [a and b for a, b in zip(carry_u, out_u)]
        if new_u == carry_u:
            break
        carry_u = new_u

    has_colls = bool(collective_eqns(body_jaxpr)) or \
        bool(collective_eqns(cond_jaxpr))
    if has_colls:
        pred_u = _propagate(cond_jaxpr, cond_consts_u + carry_u, mesh_axes)
        if emit is not None and not all(pred_u):
            emit("finding", eqn, Finding(
                "COLL202", site_of(eqn),
                "loop body issues collectives but the continuation "
                "predicate is not provably shard-uniform: devices can "
                "exit on different rounds (ragged-exit deadlock)"))

    if emit is not None:
        # final (finding-emitting) pass over the body with the stable env;
        # fixpoint iterations above ran silent so nothing duplicates
        _propagate(body_jaxpr, body_consts_u + carry_u, mesh_axes, emit=emit)
        _check_stale_carrier(eqn, body_jaxpr, emit)
    return [u for u in carry_u]


# ---------------------------------------------------------------------------
# COLL203: exchange-patched carriers must be read in-round
# ---------------------------------------------------------------------------
def _gather_derived_outputs(jaxpr) -> Tuple[Set[object], List[bool]]:
    """Forward taint from ``all_gather`` outputs through everything
    (scatters included — a patched buffer still derives from the payload).
    Returns (tainted vars at this level, per-outvar taint)."""
    return _gather_derived_outputs_with_inputs(
        jaxpr, [False] * len(jaxpr.invars))


def _gather_derived_outputs_with_inputs(jaxpr, in_taint
                                        ) -> Tuple[Set[object], List[bool]]:
    tainted: Set[object] = {v for v, t in zip(jaxpr.invars, in_taint) if t}

    def is_t(v):
        return (not isinstance(v, Literal)) and v in tainted

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "all_gather":
            tainted.update(eqn.outvars)
            continue
        if prim in ("psum", "pmin", "pmax"):
            # a reducing collective CONSUMES the payload: its output is a
            # fresh replicated aggregate (the termination vote), not an
            # exchanged buffer that still needs an in-round reader
            continue
        if prim == "cond":
            outs = [False] * len(eqn.outvars)
            for b in cond_branches(eqn):
                _, bouts = _gather_derived_outputs_with_inputs(
                    b, [is_t(v) for v in eqn.invars[1:]])
                outs = [a or bb for a, bb in zip(outs, bouts)]
            for v, t in zip(eqn.outvars, outs):
                if t:
                    tainted.add(v)
            continue
        if prim in ("pjit", "closed_call"):
            sub = sub_jaxpr(eqn.params.get("jaxpr"))
            if sub is not None:
                _, bouts = _gather_derived_outputs_with_inputs(
                    sub, [is_t(v) for v in eqn.invars])
                for v, t in zip(eqn.outvars, bouts):
                    if t:
                        tainted.add(v)
                continue
        if prim == "while":
            # nested loops (the fixpoint sweeps) hold no gathers in the
            # shipping program; if one ever does, taint all its outputs
            _, wbody, _, _ = while_parts(eqn)
            if wbody is not None and collective_eqns(wbody):
                tainted.update(eqn.outvars)
                continue
        if any(is_t(v) for v in eqn.invars):
            tainted.update(eqn.outvars)
    return tainted, [is_t(v) for v in jaxpr.outvars]


def _check_stale_carrier(while_eqn, body_jaxpr, emit) -> None:
    """COLL203: every body outvar that (a) is an array of more than one
    element and (b) derives from this round's ``all_gather`` payload must
    also be *read* by some body equation — otherwise the freshly-exchanged
    view only becomes visible next round and every in-round consumer (the
    conflict pass) saw stale state."""
    tainted, out_taint = _gather_derived_outputs(body_jaxpr)
    if not tainted:
        return
    # users: var -> equations consuming it (one level; sub-jaxpr consumers
    # count through their enclosing eqn's invars)
    uses: Dict[object, int] = {}
    for eqn in body_jaxpr.eqns:
        for v in eqn.invars:
            if not isinstance(v, Literal):
                uses[v] = uses.get(v, 0) + 1
    for v, t in zip(body_jaxpr.outvars, out_taint):
        if not t or isinstance(v, Literal):
            continue
        try:
            import numpy as np
            elems = int(np.prod(v.aval.shape)) if v.aval.shape else 1
        except Exception:
            elems = 1
        if elems <= 1:
            continue  # psum votes / counters: not snapshot buffers
        if uses.get(v, 0) == 0:
            emit("finding", while_eqn, Finding(
                "COLL203", site_of(while_eqn),
                f"loop carrier {v.aval.shape}:{v.aval.dtype} is patched "
                "from this round's exchange but never read before being "
                "carried out — in-round consumers see last round's state"))


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
def check_collectives(closed_jaxpr, *, context: str = "") -> List[Finding]:
    """Run the collective-safety pass over every shard_map program inside
    ``closed_jaxpr``. Programs without shard_map produce no findings."""
    findings: List[Finding] = []
    import dataclasses as _dc

    for shard_eqn, body in find_shard_jaxprs(closed_jaxpr):
        mesh_axes = mesh_axis_names(shard_eqn)
        pending: List[Finding] = []
        uncond_colls: List[object] = []

        def emit(kind, eqn, finding):
            if kind == "finding":
                pending.append(finding)
            elif kind == "collective":
                uncond_colls.append(eqn)

        # shard operands are per-device data: varying
        _propagate(body, [False] * len(body.invars), mesh_axes, emit=emit)

        # every collective reached during propagation that did NOT get
        # classified by a cond/while finding is structurally unconditional
        # within its scope — inventory them (deduped per site/signature)
        seen = set()
        for eqn in uncond_colls:
            sig = collective_signature(eqn)
            key = (site_of(eqn), sig)
            if key in seen:
                continue
            seen.add(key)
            pending.append(Finding(
                "COLL101", site_of(eqn),
                f"unconditional collective {_fmt_sig(sig)}"))
        findings.extend(_dc.replace(f, context=context) if context else f
                        for f in pending)
    return findings
