"""whisper-medium [audio]: 24L enc + 24L dec, d_model=1024 16H (MHA kv=16)
d_ff=4096 vocab=51865; conv frontend STUB (input_specs feeds precomputed
frame embeddings [B, 1500, 1024]). [arXiv:2212.04356]"""
from ..models.config import ModelConfig, EncDecConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="encdec", num_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=51865,
        act="gelu", tie_embeddings=True,
        encdec=EncDecConfig(enc_layers=24, enc_seq=1500))


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec", num_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        act="gelu", tie_embeddings=True,
        encdec=EncDecConfig(enc_layers=2, enc_seq=64))
