"""The pluggable speculative-coloring engine (DESIGN.md §2, §Engine).

The paper's central finding is that ONE scheme — speculate, then resolve —
spans radically different machines once two inner pieces are specialized per
architecture: the first-fit ("mex") inner loop and the conflict pass. The
seed hard-wired one mex formulation and re-implemented the speculative
fixpoint three times (iterative / dataflow / distributed). This module is
the extraction:

* :class:`MexBackend` — a named, registered first-fit engine. Four ship:

  - ``"sort"``       the segmented sort-based mex (O(E log E) per sweep,
                     :func:`repro.core.mex.segment_mex`) — works on any
                     edge-list layout, no color bound needed;
  - ``"bitmap"``     a dense per-vertex forbidden **bitmap** built with one
                     scatter-or over the edge list — O(E) per sweep plus an
                     O(V·C) free-bit scan (the Rokos-style cheap inner
                     loop, arXiv:1505.04086); needs a static color bound,
                     taken from the graph's max degree;
  - ``"ell_pallas"`` the Pallas TPU ``firstfit`` kernel over an ELL slab,
                     fed by an O(E) edge→(row, slot) scatter; needs the
                     graph built with ``to_device(layout="ell")`` (or a
                     device-side :func:`edge_slots` mapping);
  - ``"fused_pallas"`` the Pallas ``round_fused`` kernel: the same bitmask
                     mex fused with the Alg. 2 conflict predicate in one
                     slab read (ELL requirements as ``"ell_pallas"``).

* :class:`SweepSpec` — the per-round edge-space description every driver
  lowers its precedence semantics into: which edges forbid, and whether an
  edge's contribution tracks the live color vector (``dyn``) or is frozen
  for the round (``static_c`` — e.g. the distributed snapshot gather).

* :func:`fixpoint_sweep` — THE speculation inner loop: chaotic sweeps of
      c[v] <- mex{ contribution(e) : e forbids v }      (pending v only)
  until a fixpoint, shared by ITERATIVE's phase 1, DATAFLOW, and the
  distributed local solve. No algorithm module carries its own sweep loop.

Registering a new backend (a GPU segmented-scan, a multi-host variant, ...)
is ~20 lines: subclass :class:`MexBackend`, implement ``bind``, call
:func:`register_backend` — every driver then accepts it via ``engine=``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from .mex import segment_mex

# A bound mex engine: (key_v [M], key_c [M]) -> mex [V] int32 (>= 1).
# key_v[i] is the vertex the edge forbids (num_vertices = inert padding);
# key_c[i] the forbidden color (0 = no constraint).
MexFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]

# A slab-bound mex engine (the frontier path): (key_v [cap_e] slab rows,
# key_c [cap_e], slot [cap_e] within-row positions) -> mex [cap_v]. The
# extra ``slot`` operand carries the per-round ELL geometry that the
# full-graph bind closes over statically.
SlabMexFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]

_INT32_MAX = jnp.iinfo(jnp.int32).max


class SweepSpec(NamedTuple):
    """Per-round, edge-space description of 'who forbids whom with what'.

    key_v:    [M] int32 in [0, V]; V marks an inert edge this round.
    dyn_idx:  [M] int32 in [0, V]; gather index into the live (padded)
              color vector for dynamic contributions.
    dyn:      [M] bool; True = contribution re-read from the live colors
              every sweep, False = frozen at ``static_c`` for the round.
    static_c: [M] int32; the frozen contribution (distributed snapshot
              colors; 0 where unused).
    """

    key_v: jnp.ndarray
    dyn_idx: jnp.ndarray
    dyn: jnp.ndarray
    static_c: jnp.ndarray


def num_color_words(max_colors: int) -> int:
    """uint32 words needed so colors [1, max_colors] AND the next free
    candidate all fit: 32*words >= max_colors + 2."""
    return max(1, -(-(int(max_colors) + 2) // 32))


def _resolve_words(words: Optional[int], max_colors: int, name: str) -> int:
    """Shared words-capacity resolution for table-based backends. A color
    bound is always required — an unbounded table can silently drop forbids
    and corrupt colorings, so a ``words=`` override adjusts capacity above
    the bound rather than substituting for it."""
    if max_colors <= 0:
        raise ValueError(
            f"{name} engine needs a static color bound: build the graph "
            "via Graph.to_device() (it carries max_degree)")
    from ..kernels.round_fused import COLOR_MASK  # deferred: core importable solo
    if max_colors > COLOR_MASK:
        # a color value at 2^28 IS round_fused's FORBID bit: a packed entry
        # carrying it would forbid nothing and conflict with everything, so
        # no table backend accepts a bound the packed layout cannot encode
        raise ValueError(
            f"{name} engine: max_colors={max_colors} exceeds the packed-"
            f"entry color field (bits 0..27, max {COLOR_MASK}); "
            "colors that large alias the FORBID/CONFLICT predicate bits")
    if words is not None:
        words = int(words)
        if words < num_color_words(max_colors):
            raise ValueError(
                f"{name} engine: words={words} gives {32 * words} color "
                f"slots, below the graph's Delta+2 bound of "
                f"{max_colors + 2}; use words >= {num_color_words(max_colors)}"
                " (or omit words to derive it)")
        return words
    return num_color_words(max_colors)


# --------------------------------------------------------------------------
# backends
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MexBackend:
    """Base class: a named first-fit engine, bound per graph/partition.

    ``bind`` receives everything static a backend may specialize on:
      num_vertices  segment count (local V under the distributed driver);
      max_colors    a static upper bound on any color value that can appear
                    (graph max degree + 1, possibly capped by a
                    caller-asserted color_bound; 0 = unknown);
      ell_slot      [M] int32 per-edge slot within its vertex row, or None
                    (layouts that need it: build via Graph.to_device(
                    layout="ell") or device-side edge_slots());
      ell_width     static ELL slab width (max row length);
      max_degree    the graph's true max degree, independent of any
                    color_bound cap (-1 = unknown) — what ELL completeness
                    is checked against.
    It returns the per-sweep ``MexFn``.
    """

    name = "abstract"
    needs_ell = False          # True: bind() requires ell_slot/ell_width
    needs_color_bound = False  # True: bind() requires max_colors > 0; a
                               # words= override only raises capacity above
                               # that bound, it cannot substitute for it

    def bind(self, *, num_vertices: int, max_colors: int = 0,
             ell_slot: Optional[jnp.ndarray] = None,
             ell_width: int = 0, max_degree: int = -1) -> MexFn:
        raise NotImplementedError

    def bind_slab(self, *, capacity: int, max_colors: int = 0,
                  ell_width: int = 0, max_degree: int = -1) -> SlabMexFn:
        """Bind the backend to a fixed-capacity frontier slab
        (repro.core.frontier): segments are the ``capacity`` slab rows, not
        the graph's vertices — the bitmap backend's table shrinks from
        (V+1, C) to (capacity+1, C), the sort backend's segment space to
        ``capacity``. The returned callable takes a per-round ``slot``
        operand (each edge's position within its slab row) so ELL-style
        backends can scatter a compacted slab without a static geometry.

        The default adapter covers layout-free backends; ``needs_ell``
        backends override it."""
        if self.needs_ell:  # pragma: no cover - every needs_ell backend
            raise NotImplementedError(  # must provide its own slab bind
                f"mex backend {self.name!r} needs an ELL slab bind override")
        mex = self.bind(num_vertices=capacity, max_colors=max_colors,
                        max_degree=max_degree)
        return lambda key_v, key_c, slot: mex(key_v, key_c)


@dataclasses.dataclass(frozen=True)
class SortMexBackend(MexBackend):
    """Today's segmented-sort mex: O(E log E) per sweep, layout-free, no
    color bound required — the TPU-friendly default."""

    name = "sort"

    def bind(self, *, num_vertices: int, max_colors: int = 0,
             ell_slot=None, ell_width: int = 0, max_degree: int = -1) -> MexFn:
        V = num_vertices
        # synthetic (v, 0) pairs guarantee every segment is populated
        syn_v = jnp.arange(V, dtype=jnp.int32)
        syn_c = jnp.zeros((V,), jnp.int32)

        def mex(key_v, key_c):
            return segment_mex(
                jnp.concatenate([key_v, syn_v]),
                jnp.concatenate([key_c, syn_c]), V)

        return mex


@dataclasses.dataclass(frozen=True)
class BitmapMexBackend(MexBackend):
    """Dense forbidden-bitmap mex: one O(E) scatter-or over the edge list
    into a per-vertex forbidden table of C = 32*``words`` color slots, then
    an O(V*C) free-slot scan — no sort, the Rokos-style cheap inner loop.

    XLA has no bitwise-or scatter primitive, so the table holds one byte
    per color slot (the unpacked view of the Rokos uint32-word bitmap);
    duplicate forbids make the ``set`` idempotent, which is exactly the
    "or". ``words`` overrides the capacity derived from the graph's max
    degree (Delta+2 colors always suffice for greedy, so the derived bound
    is exact, never heuristic).
    """

    name = "bitmap"
    needs_color_bound = True
    words: Optional[int] = None

    def bind(self, *, num_vertices: int, max_colors: int = 0,
             ell_slot=None, ell_width: int = 0, max_degree: int = -1) -> MexFn:
        V = num_vertices
        words = _resolve_words(self.words, max_colors, self.name)
        C = 32 * words
        value = lax.broadcasted_iota(jnp.int32, (1, C), 1)

        def mex(key_v, key_c):
            # scatter-or: colors >= C land out of range and drop — they can
            # never lower a mex that (by the Delta+2 bound) stays < C
            forb = (jnp.zeros((V + 1, C), jnp.uint8)
                    .at[key_v, key_c].set(1, mode="drop"))
            cand = jnp.where((forb == 0) & (value > 0), value, _INT32_MAX)
            return cand.min(axis=1)[:V].astype(jnp.int32)

        return mex


@dataclasses.dataclass(frozen=True)
class EllPallasMexBackend(MexBackend):
    """The Pallas TPU ``firstfit`` bitmask kernel, fed by an O(E) scatter of
    the per-round edge contributions into the graph's ELL (row, slot)
    geometry. 'Regularize, then go fast' (DESIGN.md §2): the irregular part
    is one XLA scatter; the kernel consumes a dense [V, D] slab in VMEM.
    """

    name = "ell_pallas"
    needs_ell = True
    needs_color_bound = True
    words: Optional[int] = None
    interpret: Optional[bool] = None

    def bind(self, *, num_vertices: int, max_colors: int = 0,
             ell_slot=None, ell_width: int = 0, max_degree: int = -1) -> MexFn:
        from ..kernels import ops as kernel_ops  # deferred: keeps core importable solo

        if ell_slot is None:
            raise ValueError(
                "ell_pallas engine needs the ELL layout: build the graph "
                "with Graph.to_device(layout='ell') (or compute edge_slots "
                "for a custom partition)")
        # completeness is judged against the TRUE max degree (not the
        # possibly color_bound-capped max_colors): a truncated ELL layout
        # (to_device(ell_width=...) below the max degree) drops forbids in
        # the slab scatter and would silently corrupt colorings
        required = max_degree if max_degree >= 0 else max_colors - 1
        if required > 0 and ell_width < required:
            raise ValueError(
                f"ell_pallas engine: ELL width {ell_width} is below the "
                f"graph's max degree {required}; rebuild with "
                "Graph.to_device(layout='ell') (full width)")
        V = num_vertices
        D = max(1, int(ell_width))
        words = _resolve_words(self.words, max_colors, self.name)
        interp = kernel_ops.INTERPRET if self.interpret is None else self.interpret
        from ..kernels.firstfit import firstfit

        def mex(key_v, key_c):
            slab = (jnp.zeros((V + 1, D), jnp.int32)
                    .at[key_v, ell_slot].set(key_c, mode="drop"))
            return firstfit(slab[:V], words=words, interpret=interp)

        return mex

    def bind_slab(self, *, capacity: int, max_colors: int = 0,
                  ell_width: int = 0, max_degree: int = -1) -> SlabMexFn:
        """Frontier bind: the kernel consumes a compacted (capacity, D) ELL
        slab scattered through the per-round ``slot`` operand — no static
        ell_slot needed, the compaction computes row positions itself."""
        from ..kernels import ops as kernel_ops
        from ..kernels.firstfit import firstfit

        D = max(1, int(ell_width if ell_width > 0 else max_degree))
        if max_degree > D:
            raise ValueError(
                f"ell_pallas slab bind: width {D} is below the graph's max "
                f"degree {max_degree}; a frontier row would drop forbids")
        words = _resolve_words(self.words, max_colors, self.name)
        interp = kernel_ops.INTERPRET if self.interpret is None else self.interpret
        cap = int(capacity)

        def mex(key_v, key_c, slot):
            slab = (jnp.zeros((cap + 1, D), jnp.int32)
                    .at[key_v, slot].set(key_c, mode="drop"))
            return firstfit(slab[:cap], words=words, interpret=interp)

        return mex


@dataclasses.dataclass(frozen=True)
class FusedPallasMexBackend(MexBackend):
    """The Pallas ``round_fused`` kernel (kernels/round_fused.py, DESIGN.md
    §FusedRound): the ``firstfit`` bitmask mex PLUS the Alg. 2 conflict
    predicate in ONE read of the ELL slab. Per-round contributions scatter
    into the packed int32 entry slab (color | FORBID bit) exactly like the
    ``ell_pallas`` scatter — the engine protocol pre-masks sweeps by
    precedence, so the drivers consume only the mex lane here (the conflict
    lane stays inert: no CONFLICT bits are packed and ``own_colors`` is 0).
    The full detect→mex→assign fusion over live colors is exercised and
    measured by ``benchmarks/roofline.py --round``.

    Bit-identical to ``"bitmap"``/``"ell_pallas"`` by construction: same
    forbidden bitset (color 0 pre-set, out-of-range colors drop), same
    min-free-bit scan.
    """

    name = "fused_pallas"
    needs_ell = True
    needs_color_bound = True
    words: Optional[int] = None
    interpret: Optional[bool] = None

    def bind(self, *, num_vertices: int, max_colors: int = 0,
             ell_slot=None, ell_width: int = 0, max_degree: int = -1) -> MexFn:
        from ..kernels.ops import resolve_interpret  # deferred: core importable solo

        if ell_slot is None:
            raise ValueError(
                "fused_pallas engine needs the ELL layout: build the graph "
                "with Graph.to_device(layout='ell') (or compute edge_slots "
                "for a custom partition)")
        required = max_degree if max_degree >= 0 else max_colors - 1
        if required > 0 and ell_width < required:
            raise ValueError(
                f"fused_pallas engine: ELL width {ell_width} is below the "
                f"graph's max degree {required}; rebuild with "
                "Graph.to_device(layout='ell') (full width)")
        V = num_vertices
        D = max(1, int(ell_width))
        words = _resolve_words(self.words, max_colors, self.name)
        interp = resolve_interpret(self.interpret)
        from ..kernels.round_fused import FORBID_BIT, round_fused

        def mex(key_v, key_c):
            ent = (jnp.zeros((V + 1, D), jnp.int32)
                   .at[key_v, ell_slot].set(key_c | FORBID_BIT, mode="drop"))
            m, _ = round_fused(ent[:V], jnp.zeros((V,), jnp.int32),
                               words=words, interpret=interp)
            return m

        return mex

    def bind_slab(self, *, capacity: int, max_colors: int = 0,
                  ell_width: int = 0, max_degree: int = -1) -> SlabMexFn:
        """Frontier bind: the compacted (capacity, D) entry slab scatters
        through the per-round ``slot`` operand, mirroring the ell_pallas
        slab bind."""
        from ..kernels.ops import resolve_interpret
        from ..kernels.round_fused import FORBID_BIT, round_fused

        D = max(1, int(ell_width if ell_width > 0 else max_degree))
        if max_degree > D:
            raise ValueError(
                f"fused_pallas slab bind: width {D} is below the graph's max "
                f"degree {max_degree}; a frontier row would drop forbids")
        words = _resolve_words(self.words, max_colors, self.name)
        interp = resolve_interpret(self.interpret)
        cap = int(capacity)

        def mex(key_v, key_c, slot):
            ent = (jnp.zeros((cap + 1, D), jnp.int32)
                   .at[key_v, slot].set(key_c | FORBID_BIT, mode="drop"))
            m, _ = round_fused(ent[:cap], jnp.zeros((cap,), jnp.int32),
                               words=words, interpret=interp)
            return m

        return mex


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
_REGISTRY: Dict[str, MexBackend] = {}

EngineSpec = Union[str, MexBackend]


def register_backend(backend: MexBackend, *, overwrite: bool = False) -> MexBackend:
    """Register a backend instance under ``backend.name`` so every driver
    accepts it via ``engine="<name>"``."""
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"mex backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(engine: EngineSpec) -> MexBackend:
    """Resolve ``engine=`` — a registered name or a MexBackend instance."""
    if isinstance(engine, MexBackend):
        return engine
    try:
        return _REGISTRY[engine]
    except KeyError:
        raise ValueError(
            f"unknown mex backend {engine!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_backend(SortMexBackend())
register_backend(BitmapMexBackend())
register_backend(EllPallasMexBackend())
register_backend(FusedPallasMexBackend())


# --------------------------------------------------------------------------
# the shared speculation machinery
# --------------------------------------------------------------------------
def edge_slots(src: jnp.ndarray, num_vertices: int) -> jnp.ndarray:
    """Per-edge slot within its vertex row, for row-contiguous edge lists
    (CSR order — true of DeviceGraph edge lists and partition_graph slabs).

    Device-side counterpart of the host ELL construction; lets the
    distributed driver bind the ``ell_pallas`` engine to a local slab
    without materializing a host ELL."""
    m = src.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    first = (jnp.full((num_vertices + 1,), m, jnp.int32)
             .at[jnp.minimum(src, num_vertices)].min(idx))
    return idx - first[jnp.minimum(src, num_vertices)]


def fixpoint_iterate(update, x0, *, max_iters, wrap=lambda x: x):
    """Chaotic iteration x <- update(x) to a fixpoint (or ``max_iters``).

    ``wrap`` tags the loop-carried scalars for the execution context (the
    distributed driver passes ``lax.pvary`` so the carriers type-check
    under shard_map). Returns (x, iters, still_changing)."""

    def body(state):
        x, _, n = state
        xn = update(x)
        return xn, jnp.any(xn != x), n + 1

    def cond(state):
        _, changed, n = state
        return jnp.logical_and(changed, n < max_iters)

    x, changed, n = lax.while_loop(
        cond, body,
        (x0, wrap(jnp.asarray(True)), wrap(jnp.asarray(0, jnp.int32))))
    return x, n, changed


def fixpoint_sweep(mex: MexFn, spec: SweepSpec, colors0: jnp.ndarray,
                   pending: jnp.ndarray, *, max_sweeps: int,
                   wrap=lambda x: x):
    """THE speculative inner loop (paper Alg. 2 phase 1 / Alg. 3-5): sweep
        c[v] <- mex{ contribution(e) : e forbids v }     for pending v
    to its fixpoint. ITERATIVE, DATAFLOW and the distributed local solve
    all call this — their differences live entirely in ``spec``.

    Returns (colors, sweeps, still_changing).

    The padded color vector is loop-carried state: the phantom slot V is
    written once at entry and every sweep updates the V-prefix in place
    (one dynamic-update-slice), instead of re-materializing the [V+1]
    concatenation per iteration."""
    V = colors0.shape[0]

    def sweep(cpad):
        key_c = jnp.where(spec.dyn, cpad[spec.dyn_idx], spec.static_c)
        new = jnp.where(pending, mex(spec.key_v, key_c), cpad[:V])
        return cpad.at[:V].set(new)

    cpad0 = jnp.concatenate([colors0, jnp.zeros((1,), jnp.int32)])
    cpad, n, changed = fixpoint_iterate(sweep, cpad0, max_iters=max_sweeps,
                                        wrap=wrap)
    return cpad[:V], n, changed


def lockstep_offsets(pending: jnp.ndarray, concurrency: int) -> jnp.ndarray:
    """OpenMP-static superstep offsets over the pending set: rank within the
    pending set mod block size (paper Alg. 2's thread-block geometry)."""
    r = pending.sum(dtype=jnp.int32)
    bs = lax.div(r + concurrency - 1, concurrency)
    rank = jnp.cumsum(pending.astype(jnp.int32)) - 1
    return jnp.where(pending, rank % jnp.maximum(bs, 1), 0).astype(jnp.int32)


def speculation_conflicts(src: jnp.ndarray, dst: jnp.ndarray,
                          colors: jnp.ndarray, pending: jnp.ndarray,
                          num_vertices: int) -> jnp.ndarray:
    """Alg. 2 phase 2 on an edge list: monochromatic same-round pairs queue
    the higher-index endpoint. Returns the next round's pending mask.

    (The distributed driver keeps its own fused variant — its conflict view
    decodes from the packed wire gather, a genuinely per-machine
    specialization; see distributed.py §Perf H-C1.)"""
    cpad = jnp.concatenate([colors, jnp.zeros((1,), jnp.int32)])
    ppad = jnp.concatenate([pending, jnp.zeros((1,), jnp.bool_)])
    conf_e = ppad[src] & ppad[dst] & (cpad[src] == cpad[dst]) & (src > dst)
    return (jnp.zeros((num_vertices,), jnp.int32)
            .at[src].max(conf_e.astype(jnp.int32), mode="drop")
            .astype(jnp.bool_))
