"""repro.analysis: seeded-violation fixtures, budget/bind guards, baseline
semantics, and the clean-run pin.

Each violation class the analyzer exists to catch is *seeded* here as a
minimal program (a mutation-style fixture) and asserted to produce its
exact finding code — so a refactor that silently blinds a pass turns a
test red, not just the lint lane. The flip side is pinned too: the
shipping registry plus the committed baseline must verify clean
(``compile_plan(..., verify="error")`` is a no-op on every shipping plan).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (AnalysisConfig, AnalysisError, Finding,
                            analyze_spec, compare, dedupe, gating,
                            lint_tree, load_baseline, save_baseline,
                            split_by_severity, sweep_registry,
                            verify_findings, verify_plan)
from repro.analysis import budgets, deadcode, races, retrace
from repro.analysis.__main__ import main as analysis_main
from repro.core.api import ColoringSpec, PlanShape, compile_plan
from repro.core.engine import get_backend

sds = jax.ShapeDtypeStruct
SHAPE = PlanShape(num_vertices=48, padded_edges=512, max_degree=8)


def codes(findings):
    return [f.code for f in findings]


# --------------------------------------------------------------------------
# race classifier: one seeded jaxpr per class, exact code asserted
# --------------------------------------------------------------------------
def _scatter_codes(fn, *avals):
    return codes(races.classify_scatters(jax.make_jaxpr(fn)(*avals)))


class TestRaceClassifier:
    def test_float_accumulate_is_race201(self):
        got = _scatter_codes(lambda x, i, u: x.at[i].add(u),
                             sds((16,), jnp.float32), sds((4,), jnp.int32),
                             sds((4,), jnp.float32))
        assert got == ["RACE201"]

    def test_int_accumulate_is_race202(self):
        got = _scatter_codes(lambda x, i, u: x.at[i].add(u),
                             sds((16,), jnp.int32), sds((4,), jnp.int32),
                             sds((4,), jnp.int32))
        assert got == ["RACE202"]

    def test_commutative_reduction_is_race101(self):
        got = _scatter_codes(lambda x, i, u: x.at[i].min(u),
                             sds((16,), jnp.int32), sds((4,), jnp.int32),
                             sds((4,), jnp.int32))
        assert got == ["RACE101"]

    def test_static_iota_indices_are_race102(self):
        got = _scatter_codes(lambda x, u: x.at[jnp.arange(4)].set(u),
                             sds((16,), jnp.int32), sds((4,), jnp.int32))
        assert got == ["RACE102"]

    def test_single_update_row_is_race104(self):
        got = _scatter_codes(lambda x, i, u: x.at[i].set(u),
                             sds((16,), jnp.int32), sds((), jnp.int32),
                             sds((), jnp.int32))
        assert got == ["RACE104"]

    def test_idempotent_constant_store_is_race103(self):
        got = _scatter_codes(lambda x, i: x.at[i].set(1),
                             sds((16,), jnp.int32), sds((4,), jnp.int32))
        assert got == ["RACE103"]

    def test_unique_indices_assertion_is_race301(self):
        got = _scatter_codes(
            lambda x, i, u: x.at[i].set(u, unique_indices=True),
            sds((16,), jnp.int32), sds((4,), jnp.int32), sds((4,), jnp.int32))
        assert got == ["RACE301"]

    def test_speculative_lww_store_is_race300(self):
        # the paper's deliberately-racy store shape: data-driven indices,
        # data updates, no uniqueness claim — benign only via Alg. 2 phase 2
        got = _scatter_codes(lambda x, i, u: x.at[i].set(u),
                             sds((16,), jnp.int32), sds((4,), jnp.int32),
                             sds((4,), jnp.int32))
        assert got == ["RACE300"]

    def test_info_classes_never_gate(self):
        fs = [Finding("RACE101", "a:b", "m"), Finding("RACE104", "a:c", "m")]
        assert gating(fs) == []


# --------------------------------------------------------------------------
# retrace-hazard lint: AST pass + trace-constant pass
# --------------------------------------------------------------------------
_SRC_NONE_DEFAULT = """
import functools, jax
@functools.partial(jax.jit, static_argnames=("interpret",))
def f(x, interpret=None):
    return x
"""

_SRC_IS_NONE_BODY = """
import jax
@jax.jit(static_argnames=("mode",))
def g(x, mode="fast"):
    if mode is None:
        mode = "fast"
    return x
"""

_SRC_MUTABLE_DEFAULT = """
import functools, jax
@functools.partial(jax.jit, static_argnames=("opts",))
def h(x, opts=[]):
    return x
"""

_SRC_SANCTIONED = """
import functools, jax
@functools.partial(jax.jit, static_argnames=("interpret",))
def k(x, interpret=False):
    return x
"""


class TestRetraceLint:
    def test_none_default_static_arg_is_retrace001(self):
        got = retrace.lint_source(_SRC_NONE_DEFAULT, "fixture.py")
        assert codes(got) == ["RETRACE001"]
        assert "interpret" in got[0].message

    def test_is_none_test_in_body_is_retrace001(self):
        got = retrace.lint_source(_SRC_IS_NONE_BODY, "fixture.py")
        assert codes(got) == ["RETRACE001"]

    def test_mutable_default_is_retrace002(self):
        got = retrace.lint_source(_SRC_MUTABLE_DEFAULT, "fixture.py")
        assert codes(got) == ["RETRACE002"]

    def test_resolved_outside_jit_is_clean(self):
        assert retrace.lint_source(_SRC_SANCTIONED, "fixture.py") == []

    def test_closure_captured_data_is_retrace003(self):
        data = jnp.asarray(np.arange(256, dtype=np.int32) ** 2)
        closed = jax.make_jaxpr(lambda x: x + data)(sds((256,), jnp.int32))
        assert codes(retrace.check_trace_constants(closed)) == ["RETRACE003"]

    @pytest.mark.parametrize("const", [
        jnp.arange(256, dtype=jnp.int32),       # iota ramp
        jnp.full((256,), 7, jnp.int32),         # constant fill
        jnp.asarray(np.arange(8) ** 2),         # below the size threshold
    ], ids=["ramp", "fill", "small"])
    def test_envelope_derived_constants_exempt(self, const):
        closed = jax.make_jaxpr(lambda x: x + const)(
            sds(const.shape, const.dtype))
        assert retrace.check_trace_constants(closed) == []


# --------------------------------------------------------------------------
# budget checker: bit fields, int32 indexing, VMEM model
# --------------------------------------------------------------------------
class TestBudgets:
    def test_color_bound_past_bit28_is_bit001(self):
        got = budgets.check_spec_budgets(
            ColoringSpec(engine="sort", color_bound=1 << 28), SHAPE)
        assert codes(got) == ["BIT001"]
        assert got[0].severity == "error"

    def test_max_color_bound_is_accepted(self):
        got = budgets.check_spec_budgets(
            ColoringSpec(engine="sort", color_bound=(1 << 28) - 1), SHAPE)
        assert "BIT001" not in codes(got)

    def test_huge_max_degree_is_bit001(self):
        got = budgets.check_spec_budgets(
            ColoringSpec(engine="sort"), PlanShape(8, 512, 1 << 28))
        assert "BIT001" in codes(got)

    def test_ell_slab_overflow_is_idx001(self):
        got = budgets.check_spec_budgets(
            ColoringSpec(engine="ell_pallas"),
            PlanShape(2 ** 20, 1 << 20, 2 ** 12))
        assert "IDX001" in codes(got)

    def test_edge_capacity_overflow_is_idx002(self):
        got = budgets.check_spec_budgets(
            ColoringSpec(engine="sort"), PlanShape(48, 2 ** 31, 8))
        assert codes(got) == ["IDX002"]

    def test_high_degree_breaches_declared_vmem(self):
        # max_degree 4096 -> 129 forbidden-bitset words -> the fused
        # kernel's own closed-form model lands ~34 MB, over the 16 MiB
        # default ceiling, with no tracing involved
        got = budgets.check_spec_budgets(
            ColoringSpec(engine="fused_pallas"), PlanShape(512, 4096, 4096))
        assert codes(got) == ["VMEM001"]
        assert got[0].site == "kernels/round_fused.py:round_fused"

    def test_default_shape_fits_default_ceiling(self):
        for eng in ("ell_pallas", "fused_pallas"):
            got = budgets.check_spec_budgets(ColoringSpec(engine=eng), SHAPE)
            assert got == [], eng

    def test_traced_pallas_geometry_respects_ceiling_knob(self):
        # same plan, ceiling squeezed to 1 KiB: the traced pallas_call
        # geometry (real block shapes + scratch) must now breach
        fs = analyze_spec(ColoringSpec(strategy="iterative",
                                       engine="fused_pallas"), SHAPE,
                          config=AnalysisConfig(vmem_ceiling_bytes=1024))
        assert "VMEM001" in codes(fs)


class TestBindGuard:
    """Satellite: table backends reject a bound the packed entry cannot
    encode at bind time, not at first corrupt coloring."""

    def test_bitmap_bind_rejects_29bit_bound(self):
        with pytest.raises(ValueError, match="packed-entry color field"):
            get_backend("bitmap").bind(num_vertices=8, max_colors=1 << 28)

    def test_ell_bind_rejects_29bit_bound(self):
        with pytest.raises(ValueError, match="packed-entry color field"):
            get_backend("ell_pallas").bind(
                num_vertices=8, max_colors=1 << 28,
                ell_slot=jnp.zeros((16,), jnp.int32), ell_width=4,
                max_degree=3)

    def test_bind_accepts_the_field_maximum(self):
        get_backend("bitmap").bind(num_vertices=8,
                                   max_colors=(1 << 28) - 1)


# --------------------------------------------------------------------------
# findings / dedupe / baseline plumbing
# --------------------------------------------------------------------------
class TestFindings:
    def test_unregistered_code_rejected(self):
        with pytest.raises(ValueError, match="unregistered"):
            Finding("RACE999", "a:b", "m")

    def test_severity_defaults_from_registry(self):
        assert Finding("RACE300", "a:b", "m").severity == "warning"
        assert Finding("BIT001", "a:b", "m").severity == "error"
        assert Finding("RACE104", "a:b", "m").severity == "info"

    def test_fingerprint_excludes_context_and_message(self):
        a = Finding("RACE300", "core/x.py:f", "m1", "iterative/sort/d1")
        b = Finding("RACE300", "core/x.py:f", "m2", "dataflow/bitmap/d2")
        assert a.fingerprint == b.fingerprint == "RACE300@core/x.py:f"

    def test_dedupe_folds_contexts(self):
        a = Finding("RACE300", "core/x.py:f", "m", "ctx1")
        b = Finding("RACE300", "core/x.py:f", "m", "ctx2")
        c = Finding("RACE301", "core/y.py:g", "m", "ctx1")
        out = dedupe([a, b, c])
        assert len(out) == 2
        assert out[0].context == "ctx1 +1 more"
        assert out[1].context == "ctx1"

    def test_split_by_severity(self):
        fs = [Finding("BIT001", "a:b", "m"), Finding("RACE300", "a:c", "m"),
              Finding("RACE104", "a:d", "m")]
        errs, warns, infos = split_by_severity(fs)
        assert (codes(errs), codes(warns), codes(infos)) == (
            ["BIT001"], ["RACE300"], ["RACE104"])


class TestBaseline:
    def test_round_trip(self, tmp_path):
        path = str(tmp_path / "b.json")
        save_baseline({"RACE300@core/x.py:f": "distinct by construction"},
                      path)
        assert load_baseline(path) == {
            "RACE300@core/x.py:f": "distinct by construction"}

    def test_empty_reason_rejected(self, tmp_path):
        path = str(tmp_path / "b.json")
        path_doc = {"version": 1, "entries": [
            {"fingerprint": "RACE300@core/x.py:f", "reason": "  "}]}
        with open(path, "w") as f:
            json.dump(path_doc, f)
        with pytest.raises(ValueError, match="no reason string"):
            load_baseline(path)

    def test_version_mismatch_rejected(self, tmp_path):
        path = str(tmp_path / "b.json")
        with open(path, "w") as f:
            json.dump({"version": 99, "entries": []}, f)
        with pytest.raises(ValueError, match="unsupported version"):
            load_baseline(path)

    def test_compare_three_outcomes(self):
        fs = [Finding("RACE300", "core/x.py:f", "m"),       # allowlisted
              Finding("BIT001", "core/y.py:g", "m"),        # new
              Finding("RACE104", "core/z.py:h", "m")]       # info: ignored
        base = {"RACE300@core/x.py:f": "ok",
                "RACE301@core/gone.py:f": "stale entry"}
        new, allowed, stale = compare(fs, base)
        assert codes(new) == ["BIT001"]
        assert codes(allowed) == ["RACE300"]
        assert stale == ["RACE301@core/gone.py:f"]

    def test_committed_baseline_loads_with_reasons(self):
        base = load_baseline()
        assert base, "committed baseline must not be empty"
        for fp, reason in base.items():
            assert "@" in fp and reason.strip()


# --------------------------------------------------------------------------
# dead-export scan
# --------------------------------------------------------------------------
def _mini_repo(tmp_path, module_source, extra=None):
    """A throwaway repo layout: src/pkg/<mod>.py (+ optional extra files)."""
    pkg = tmp_path / "src" / "pkg"
    pkg.mkdir(parents=True)
    (pkg / "mod.py").write_text(module_source)
    for rel, text in (extra or {}).items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return str(pkg), str(tmp_path)


class TestDeadCode:
    def test_unreferenced_export_is_dead001(self, tmp_path):
        pkg, repo = _mini_repo(tmp_path, "def orphan_helper():\n    pass\n")
        got = deadcode.scan_package(pkg, repo)
        assert codes(got) == ["DEAD001"]
        assert got[0].site.endswith("mod.py:orphan_helper")

    def test_cross_file_reference_counts(self, tmp_path):
        pkg, repo = _mini_repo(
            tmp_path, "def live_helper():\n    pass\n",
            extra={"tests/test_mod.py":
                   "def test_it():\n    live_helper()\n"})
        assert deadcode.scan_package(pkg, repo) == []

    def test_reexport_plumbing_does_not_count(self, tmp_path):
        # an import statement elsewhere is NOT a reference (laundering rule)
        pkg, repo = _mini_repo(
            tmp_path, "def laundered():\n    pass\n",
            extra={"src/pkg/other.py": "from .mod import laundered\n"})
        assert codes(deadcode.scan_package(pkg, repo)) == ["DEAD001"]

    def test_pending_pragma_downgrades_to_dead100(self, tmp_path):
        pkg, repo = _mini_repo(
            tmp_path,
            "# pending: wire-up later\ndef dormant():\n    pass\n")
        got = deadcode.scan_package(pkg, repo)
        assert codes(got) == ["DEAD100"]
        assert got[0].severity == "info"
        assert "dormant" in got[0].message
        assert "wire-up later" in got[0].message

    def test_compression_wired_up_pragma_gone(self):
        # parallel/compression.py used to carry a "# pending: dist_scale
        # wire-up" pragma (DEAD100 downgrade); the boundary wire now
        # consumes its halo codec, so the pragma is gone and the repo-wide
        # scan must stay free of DEAD001 without it — every export is live.
        path = os.path.join(os.path.dirname(deadcode.__file__),
                            "..", "parallel", "compression.py")
        with open(path) as f:
            assert deadcode.PENDING_PRAGMA.search(f.read()) is None
        got = [f for f in lint_tree() if f.code.startswith("DEAD")]
        assert [f for f in got if f.code == "DEAD001"] == []


# --------------------------------------------------------------------------
# verify= front door + the clean-run pin
# --------------------------------------------------------------------------
class TestVerify:
    def test_seeded_bit001_raises_under_error(self):
        with pytest.raises(AnalysisError, match="BIT001"):
            verify_plan(ColoringSpec(engine="sort", color_bound=1 << 28),
                        SHAPE, mode="error")

    def test_seeded_bit001_warns_under_warn(self):
        with pytest.warns(UserWarning, match="BIT001"):
            verify_plan(ColoringSpec(engine="sort", color_bound=1 << 28),
                        SHAPE, mode="warn")

    def test_compile_plan_verify_error_rejects_seeded_violation(self):
        with pytest.raises(AnalysisError, match="BIT001"):
            compile_plan(ColoringSpec(engine="sort", color_bound=1 << 28),
                         SHAPE, verify="error")

    def test_bad_mode_rejected(self):
        with pytest.raises(ValueError, match="warn"):
            verify_plan(ColoringSpec(), SHAPE, mode="loud")

    def test_verify_findings_reports_stale(self, tmp_path):
        path = str(tmp_path / "b.json")
        save_baseline({"RACE300@core/gone.py:f": "code was deleted"}, path)
        new, allowed, stale = verify_findings(
            [], mode="warn", config=AnalysisConfig(baseline_path=path))
        assert (new, allowed) == ([], [])
        assert stale == ["RACE300@core/gone.py:f"]

    def test_shipping_plan_verifies_clean(self):
        # the acceptance pin: verify="error" is a no-op on a shipping plan
        plan = compile_plan(ColoringSpec(), SHAPE, verify="error")
        assert plan.statics == SHAPE

    @pytest.mark.parametrize("strategy,engine", [
        ("dataflow", "bitmap"), ("recolor", "fused_pallas")])
    def test_more_shipping_combos_verify_clean(self, strategy, engine):
        verify_plan(ColoringSpec(strategy=strategy, engine=engine), SHAPE,
                    mode="error")

    def test_source_tree_gating_findings_all_race_allowlisted(self):
        # the source passes (AST lint + dead exports) must contribute zero
        # gating findings of their own — the baseline holds only the race
        # benignity arguments
        assert gating(lint_tree()) == []


@pytest.mark.slow
class TestFullSweep:
    def test_registry_sweeps_clean_against_committed_baseline(self):
        from repro.analysis import sweep_distributed
        # the full lint lane: registry + distributed wire/scheme sweep
        findings = dedupe(sweep_registry() + sweep_distributed()
                          + lint_tree())
        baseline = load_baseline()
        new, allowed, stale = compare(findings, baseline)
        assert [f.format() for f in new] == []
        assert stale == []
        # every entry in the committed baseline is exercised
        assert {f.fingerprint for f in allowed} == set(baseline)
        # no combination fell back to ANALYSIS000 (unverified != clean)
        assert "ANALYSIS000" not in codes(findings)


# --------------------------------------------------------------------------
# CLI (the lint-lane entry point)
# --------------------------------------------------------------------------
class TestCli:
    def test_single_cell_sweep_is_clean_and_dumps_json(self, tmp_path):
        # a partial sweep exercises only a subset of the committed baseline,
        # so the lane's stale-entry rule would (correctly) trip; scope the
        # baseline to exactly this cell's gating fingerprints instead
        cell = dict(strategies=("iterative",), engines=("sort",),
                    models=("d1",))
        fps = {f.fingerprint: "scoped to the iterative/sort/d1 cell"
               for f in gating(sweep_registry(**cell))}
        assert fps, "the iterative/sort cell must have gating findings"
        base = str(tmp_path / "cell.json")
        save_baseline(fps, base)
        out = str(tmp_path / "findings.json")
        rc = analysis_main(["--strategies", "iterative", "--engines", "sort",
                            "--models", "d1", "--no-source",
                            "--baseline", base, "--json", out])
        assert rc == 0
        with open(out) as f:
            doc = json.load(f)
        assert doc["findings"] and all(d["code"] and d["site"]
                                       for d in doc["findings"])
        assert doc["summary"]["new"] == 0 and doc["summary"]["stale"] == 0
        assert doc["wire_cost"] == []  # populated only under --distributed

    def test_stale_baseline_exits_2(self, tmp_path):
        # drift-only (no new violations) is its own stable exit code so CI
        # can distinguish "fix your code" from "prune the baseline"
        cell = dict(strategies=("iterative",), engines=("sort",),
                    models=("d1",))
        fps = {f.fingerprint: "scoped" for f in gating(sweep_registry(**cell))}
        fps["RACE300@core/nowhere.py:f"] = "stale"
        base = str(tmp_path / "b.json")
        save_baseline(fps, base)
        rc = analysis_main(["--strategies", "iterative", "--engines", "sort",
                            "--models", "d1", "--no-source",
                            "--baseline", base])
        assert rc == 2

    def test_new_violation_exits_1(self, tmp_path):
        # an unbaselined gating finding dominates: exit 1 even when stale
        # entries are also present
        base = str(tmp_path / "b.json")
        save_baseline({"RACE300@core/nowhere.py:f": "stale"}, base)
        rc = analysis_main(["--strategies", "iterative", "--engines", "sort",
                            "--models", "d1", "--no-source",
                            "--baseline", base])
        assert rc == 1

    def test_distributed_flag_sweeps_clean_with_wire_cost(self, tmp_path):
        from repro.analysis import sweep_distributed
        fps = {f.fingerprint: "scoped to the distributed/sort cells"
               for f in gating(dedupe(
                   sweep_registry(strategies=("distributed",),
                                  engines=("sort",), models=("d1",))
                   + sweep_distributed(engines=("sort",))))}
        base = str(tmp_path / "cell.json")
        save_baseline(fps, base)
        out = str(tmp_path / "report.json")
        rc = analysis_main(["--strategies", "distributed",
                            "--engines", "sort", "--models", "d1",
                            "--distributed", "--no-source",
                            "--baseline", base, "--json", out])
        assert rc == 0
        with open(out) as f:
            doc = json.load(f)
        # one closed-form cost table per wire x scheme cell, each carrying
        # the tier accounting the dist_scale benchmark asserts against
        assert len(doc["wire_cost"]) == 6
        for t in doc["wire_cost"]:
            tiers = t["tiers"]
            if t["wire"] == "boundary":
                assert {"halo", "setup"} <= set(tiers)
            else:
                assert "spill" in tiers
        spmd = {d["code"] for d in doc["findings"]
                if d["code"].startswith(("COLL", "WIRE", "HALO"))}
        assert {"COLL101", "COLL102", "WIRE101", "HALO101"} <= spmd
