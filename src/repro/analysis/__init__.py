"""repro.analysis — static analyzer for compiled coloring plans.

Walks the jaxpr of every compiled :class:`~repro.core.api.ColoringPlan`
program (including Pallas kernel bodies) plus the package source, and
reports typed :class:`~repro.analysis.findings.Finding` values across
three passes:

* **race classifier** (:mod:`.races`) — every scatter/store, classified
  against the paper's benign-speculation model;
* **retrace-hazard lint** (:mod:`.retrace`) — trace-time static-arg
  sentinels, non-hashable statics, plan-envelope constant leaks;
* **budget checker** (:mod:`.budgets`) — packed-entry bit fields, int32
  index arithmetic, per-BlockSpec VMEM footprints.

Distributed (host-strategy) plans additionally run the SPMD verifier over
the traced mesh program:

* **collective safety** (:mod:`.collectives`) — branch-parity and
  shard-uniformity proofs for every collective under control flow;
* **wire-cost model** (:mod:`.wirecost`) — traced bytes-on-wire checked
  against the closed-form tier accounting (DESIGN.md §Perf);
* **halo exactness** (:mod:`.halo`) — dataflow proof that only
  boundary/slab selections cross the wire and raw payloads are read only
  through the ``[Vp]`` snapshot patch.

Three front doors:

* ``compile_plan(spec, shape, verify="warn"|"error")`` — per-plan gate
  (:func:`verify_plan` under the hood);
* ``python -m repro.analysis`` — full registry sweep against the
  committed baseline (:mod:`.__main__`);
* ``tools/lint_plans.py`` — the CI lane: sweep + source lint + dead-code
  scan + baseline-drift check.

Severity / baseline semantics live in :mod:`.findings` and
:mod:`.baseline`; DESIGN.md §Analysis is the narrative version.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Iterable, List, Optional, Sequence, Tuple

from .findings import (CODES, AnalysisError, Finding, dedupe, gating,
                       split_by_severity)
from .baseline import (compare, default_baseline_path, load_baseline,
                       save_baseline)
from . import budgets as _budgets
from . import collectives as _collectives
from . import deadcode as _deadcode
from . import halo as _halo
from . import races as _races
from . import retrace as _retrace
from . import wirecost as _wirecost
from .spmd import SpmdGeometry, distributed_geometry
from .collectives import check_collectives
from .halo import check_halo_exactness
from .wirecost import check_wire_cost, closed_form_table, wire_cost_table

__all__ = [
    "AnalysisConfig", "AnalysisError", "Finding", "CODES",
    "analyze_plan", "analyze_spec", "lint_tree", "sweep_registry",
    "sweep_distributed", "verify_findings", "verify_plan", "dedupe",
    "gating", "split_by_severity", "compare", "load_baseline",
    "save_baseline", "default_baseline_path", "SpmdGeometry",
    "distributed_geometry", "check_collectives", "check_wire_cost",
    "check_halo_exactness", "closed_form_table", "wire_cost_table",
]

# the registry axes a sweep covers by default (every shipping combination)
SWEEP_STRATEGIES = ("iterative", "dataflow", "distributed", "recolor")
SWEEP_ENGINES = ("sort", "bitmap", "ell_pallas", "fused_pallas")
SWEEP_MODELS = ("d1", "d2", "pd2")
# the distributed-sweep axes (--distributed): every wire x partition cell
SWEEP_WIRES = ("boundary", "full", "auto")
SWEEP_SCHEMES = ("1d", "2d")


@dataclasses.dataclass(frozen=True)
class AnalysisConfig:
    """Knobs shared by every front door.

    vmem_ceiling_bytes  per-grid-step Pallas VMEM budget (None = 16 MiB,
                        or the REPRO_ANALYSIS_VMEM_CEILING env var);
    baseline_path       allowlist location (None = the committed
                        ``repro/analysis/baseline.json``).
    """

    vmem_ceiling_bytes: Optional[int] = None
    baseline_path: Optional[str] = None


def _abstract_device_graph(statics, *, needs_ell: bool):
    """A :class:`~repro.core.graph.DeviceGraph` of ``ShapeDtypeStruct``
    leaves matching the plan envelope — enough to ``jax.make_jaxpr`` the
    plan program without any concrete graph. ``inc_ptr`` is always present
    so the frontier execution path (where the interesting scatters live)
    is part of the traced program."""
    import jax
    import jax.numpy as jnp
    from ..core.graph import DeviceGraph

    V = int(statics.num_vertices)
    E = int(statics.padded_edges)
    D = max(1, int(statics.max_degree))
    sds = jax.ShapeDtypeStruct
    return DeviceGraph(
        num_vertices=V, num_directed_edges=E,
        src=sds((E,), jnp.int32), dst=sds((E,), jnp.int32),
        max_degree=int(statics.max_degree),
        ell_slot=sds((E,), jnp.int32) if needs_ell else None,
        ell_width=D if needs_ell else 0,
        inc_ptr=sds((V + 1,), jnp.int32))


def trace_plan_program(spec, statics):
    """``ClosedJaxpr`` of the program a plan with this spec/envelope would
    compile — device strategies via their ``device_program`` over an
    abstract DeviceGraph, the distributed host strategy via its slab-shaped
    mesh program (mirroring ``DistributedStrategy.compile``)."""
    import jax
    import jax.numpy as jnp
    from ..core.api import get_strategy
    from ..core.engine import get_backend

    strategy = get_strategy(spec.strategy)
    backend = get_backend(spec.engine)
    V = int(statics.num_vertices)
    sds = jax.ShapeDtypeStruct

    if strategy.wants == "host":
        from ..jax_compat import set_mesh
        # one geometry derivation shared with the SPMD passes' closed-form
        # expectations (spmd.distributed_geometry), so the traced program
        # and the accounting can never disagree about the envelope. The
        # boundary program is traced with a non-empty halo slab even when
        # the envelope carries none (the sweep mesh is 1 device, where Bl
        # is always 0): the wire code is shape-generic, and the classifier
        # must see the scatters a real multi-device plan compiles. Floor 2,
        # not 1 — a width-1 slab is a single update row, which the race
        # classifier would (correctly for THAT shape, wrongly for the
        # fleet's) discharge as unable to self-collide
        g = distributed_geometry(spec, statics)
        mesh = strategy._mesh(spec)
        fn = strategy._build(spec, mesh, verts_local=g.verts_local,
                             edges_local=g.edges_local,
                             max_colors=g.max_colors,
                             ell_width=int(statics.max_degree),
                             wire=g.wire, wire_colors=g.wire_colors)
        shaped = sds((g.num_devices, g.edges_local), jnp.int32)
        bshaped = sds((g.num_devices, max(1, g.boundary_cap)), jnp.int32)
        with set_mesh(mesh):
            return jax.make_jaxpr(fn)(shaped, shaped, bshaped)

    prog = strategy.device_program(spec, backend)
    dg = _abstract_device_graph(statics, needs_ell=backend.needs_ell)
    if spec.strategy == "recolor":
        return jax.make_jaxpr(prog)(dg, sds((V,), jnp.int32),
                                    sds((V,), jnp.bool_))
    return jax.make_jaxpr(prog)(dg)


def analyze_spec(spec, statics, *, config: Optional[AnalysisConfig] = None,
                 context: Optional[str] = None) -> List[Finding]:
    """All plan-scoped passes for one spec/envelope: spec-level budgets,
    then trace the program and run the race classifier, the envelope-leak
    check, and the traced-geometry VMEM audit. Distributed (host) plans
    additionally run the SPMD verifier: collective safety, the static
    wire-cost model, and the halo-exactness proof. An untraceable
    combination yields ANALYSIS000 (the cell is *unverified*, not
    clean)."""
    from ..core.api import _plan_shape, get_strategy
    from ..core.engine import get_backend

    config = config or AnalysisConfig()
    statics = _plan_shape(spec, statics)
    ctx = context if context is not None else \
        f"{spec.strategy}/{spec.engine if isinstance(spec.engine, str) else get_backend(spec.engine).name}/{spec.model}"
    findings = _budgets.check_spec_budgets(
        spec, statics, vmem_ceiling=config.vmem_ceiling_bytes, context=ctx)
    if statics.num_vertices == 0 or statics.padded_edges == 0:
        return findings  # degenerate envelope: no program exists to trace
    try:
        closed = trace_plan_program(spec, statics)
    except Exception as e:  # noqa: BLE001 — any trace failure is a finding
        findings.append(Finding(
            "ANALYSIS000", f"plan:{spec.strategy}",
            f"program could not be traced: {type(e).__name__}: {e}", ctx))
        return findings
    findings += _races.classify_scatters(closed, context=ctx)
    findings += _retrace.check_trace_constants(
        closed, context=ctx, site=f"plan:{spec.strategy}")
    findings += _budgets.check_pallas_vmem(
        closed, vmem_ceiling=config.vmem_ceiling_bytes, context=ctx)
    if get_strategy(spec.strategy).wants == "host":
        g = distributed_geometry(spec, statics)
        findings += _collectives.check_collectives(closed, context=ctx)
        findings += _wirecost.check_wire_cost(closed, g, context=ctx)
        findings += _halo.check_halo_exactness(closed, g, context=ctx)
    return findings


def analyze_plan(plan, *, config: Optional[AnalysisConfig] = None
                 ) -> List[Finding]:
    """:func:`analyze_spec` over an already-compiled plan's envelope."""
    return analyze_spec(plan.spec, plan.statics, config=config)


def _package_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_tree(package_root: Optional[str] = None,
              repo_root: Optional[str] = None) -> List[Finding]:
    """Source-level passes (no tracing): the retrace AST lint over every
    module in the package, plus the dead-export scan."""
    pkg = package_root or _package_root()
    repo = repo_root or os.path.dirname(os.path.dirname(pkg))
    findings = _retrace.lint_package(pkg)
    findings += _deadcode.scan_package(pkg, repo)
    return findings


def sweep_registry(statics=None, *,
                   strategies: Sequence[str] = SWEEP_STRATEGIES,
                   engines: Sequence[str] = SWEEP_ENGINES,
                   models: Sequence[str] = SWEEP_MODELS,
                   config: Optional[AnalysisConfig] = None,
                   progress=None) -> List[Finding]:
    """Analyze every strategy x engine x model combination, deduped by
    fingerprint (a site shared by many plans folds to one finding).

    Plan programs operate on the *constraint* graph, so the model axis
    only changes the host-side lowering — the traced program for
    (strategy, engine) is model-independent and traced once; the model
    axis still runs the (cheap) spec-budget pass per combination."""
    from ..core.api import ColoringSpec, PlanShape

    config = config or AnalysisConfig()
    statics = statics or PlanShape(num_vertices=48, padded_edges=512,
                                   max_degree=8)
    findings: List[Finding] = []
    for strat in strategies:
        for eng in engines:
            for i, model in enumerate(models):
                spec = ColoringSpec(strategy=strat, engine=eng, model=model)
                ctx = f"{strat}/{eng}/{model}"
                if progress is not None:
                    progress(ctx)
                if i == 0:
                    findings += analyze_spec(spec, statics, config=config,
                                             context=ctx)
                else:
                    findings += _budgets.check_spec_budgets(
                        spec, statics,
                        vmem_ceiling=config.vmem_ceiling_bytes, context=ctx)
    return dedupe(findings)


def sweep_distributed(statics=None, *,
                      wires: Sequence[str] = SWEEP_WIRES,
                      schemes: Sequence[str] = SWEEP_SCHEMES,
                      engines: Sequence[str] = SWEEP_ENGINES,
                      config: Optional[AnalysisConfig] = None,
                      progress=None) -> List[Finding]:
    """The distributed sweep (``--distributed``): every wire x partition
    scheme x engine cell of the host strategy, deduped by fingerprint.

    The partition scheme only changes host-side graph partitioning and
    ``wire="auto"`` traces the same boundary program as
    ``wire="boundary"`` — so the mesh program is traced once per
    (engine, resolved-wire) pair; the remaining cells still run the
    (cheap) spec-budget pass so every combination is covered."""
    from ..core.api import ColoringSpec, PlanShape

    config = config or AnalysisConfig()
    statics = statics or PlanShape(num_vertices=48, padded_edges=512,
                                   max_degree=8)
    findings: List[Finding] = []
    traced = set()
    for wire in wires:
        for scheme in schemes:
            for eng in engines:
                spec = ColoringSpec(strategy="distributed", engine=eng,
                                    wire=wire, partition=scheme)
                ctx = f"distributed/{eng}/wire={wire}/{scheme}"
                if progress is not None:
                    progress(ctx)
                cell = (eng, "full" if wire == "full" else "boundary")
                if cell in traced:
                    findings += _budgets.check_spec_budgets(
                        spec, statics,
                        vmem_ceiling=config.vmem_ceiling_bytes, context=ctx)
                else:
                    traced.add(cell)
                    findings += analyze_spec(spec, statics, config=config,
                                             context=ctx)
    return dedupe(findings)


def verify_findings(findings: Iterable[Finding], *, mode: str = "warn",
                    config: Optional[AnalysisConfig] = None
                    ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Compare findings against the committed baseline and enforce ``mode``:
    ``"warn"`` emits a Python warning per new violation, ``"error"``
    raises :class:`AnalysisError`. Returns (new, allowlisted, stale)."""
    if mode not in ("warn", "error"):
        raise ValueError(f'verify mode must be "warn" or "error", '
                         f'got {mode!r}')
    config = config or AnalysisConfig()
    baseline = load_baseline(config.baseline_path)
    new, allowed, stale = compare(findings, baseline)
    if new:
        text = "\n".join(f.format() for f in new)
        if mode == "error":
            raise AnalysisError(
                f"{len(new)} non-allowlisted finding(s):\n{text}")
        warnings.warn(f"repro.analysis: {len(new)} non-allowlisted "
                      f"finding(s):\n{text}", stacklevel=3)
    return new, allowed, stale


def verify_plan(spec, statics, *, mode: str = "warn",
                config: Optional[AnalysisConfig] = None) -> List[Finding]:
    """The ``compile_plan(..., verify=...)`` gate: analyze one plan's
    spec/envelope and enforce the baseline. Returns the (deduped) findings
    when it does not raise."""
    findings = dedupe(analyze_spec(spec, statics, config=config))
    verify_findings(findings, mode=mode, config=config)
    return findings
