"""Wire-payload compression for the distributed layers.

Two independent codecs live here:

* **Halo payload bit-packing** (lossless — the distributed coloring wire,
  DESIGN.md §Distributed): each boundary vertex contributes one
  ``(color, pending)`` entry per BSP round. A color is provably
  ``<= Delta + 1`` (first-fit mex over at most ``Delta`` forbids), so the
  entry needs exactly ``bit_length(bound) + 1`` bits, not the 16 the H-C1
  packed-int16 wire spends. :func:`pack_halo` packs entries into int32
  words (``32 // bits`` entries per word) with pure reshape/shift/sum ops
  — no scatter, so nothing for the race classifier to prove — and
  :func:`unpack_halo` inverts it exactly. On the paper's graphs
  (``<= 143`` colors, 9-bit entries) the boundary payload shrinks a
  further ~1.8x on top of the boundary-only selection. Round-trip
  exactness is a test invariant (tests/test_dist_wire.py), because the
  boundary wire must stay bit-identical to the full gather.

* **int8 gradient all-reduce** (lossy, stochastic rounding —
  :func:`compressed_psum`): the distributed-optimization trick for
  bandwidth-bound DP syncs; quantize a gradient leaf to int8 with an fp32
  scale, ``psum`` the int32-accumulated payload, dequantize. Unbiased but
  NOT exact — never used for the coloring wire, where bit parity is the
  contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


# --------------------------------------------------------------------------
# lossless halo payload packing (the distributed coloring wire)
# --------------------------------------------------------------------------
def halo_bits(color_bound: int) -> int:
    """Bits per halo entry: a color in ``[0, color_bound]`` plus one
    pending flag. ``color_bound`` is the inclusive max color (``Delta+1``
    for the coloring wire; 0 is the uncolored sentinel, included free)."""
    return max(1, int(color_bound).bit_length()) + 1


def halo_words(n: int, color_bound: int) -> int:
    """int32 words :func:`pack_halo` produces for ``n`` entries. Above
    15-bit colors one word holds a single entry — correct but wider than
    the int16 full wire; the paper's regime (<= 143 colors) packs 3+
    entries per word."""
    if n <= 0:
        return 0
    k = max(1, 32 // halo_bits(color_bound))
    return -(-n // k)


def halo_bytes(n: int, color_bound: int, num_devices: int = 1) -> int:
    """Per-round bytes the packed boundary halo puts on the wire:
    ``D * halo_words(n, bound) * 4`` — each device gathers every peer's
    word slab. This is the runtime half of the H-C4 accounting; the SPMD
    verifier (``repro.analysis.wirecost``) re-derives the same closed
    form independently from DESIGN.md §Perf, and drift between the two
    is a WIRE201 lint error."""
    return num_devices * halo_words(n, color_bound) * 4


def pack_halo(colors, pending, color_bound: int):
    """Bit-pack ``(colors [..., n] int, pending [..., n] bool)`` into
    ``[..., halo_words(n, color_bound)]`` int32 words — losslessly, as
    long as every color is ``<= color_bound`` (the distributed driver
    passes the provable ``Delta + 1``). Entry layout within a word is
    little-endian: entry ``i`` occupies bits ``[(i % k)*bits, ...)`` of
    word ``i // k``."""
    bits = halo_bits(color_bound)
    k = max(1, 32 // bits)
    n = colors.shape[-1]
    W = -(-n // k) if n else 0
    entries = ((colors.astype(jnp.uint32) << 1)
               | pending.astype(jnp.uint32))
    pad = [(0, 0)] * (entries.ndim - 1) + [(0, W * k - n)]
    entries = jnp.pad(entries, pad)
    entries = entries.reshape(*entries.shape[:-1], W, k)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * jnp.uint32(bits))
    # disjoint bit fields: the sum IS the bitwise-or of the shifted lanes
    words = (entries << shifts).sum(axis=-1, dtype=jnp.uint32)
    return words.astype(jnp.int32)


def unpack_halo(words, n: int, color_bound: int):
    """Exact inverse of :func:`pack_halo`: ``[..., W] int32`` words back to
    ``(colors [..., n] int32, pending [..., n] bool)``."""
    bits = halo_bits(color_bound)
    k = max(1, 32 // bits)
    mask = jnp.uint32((1 << bits) - 1)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * jnp.uint32(bits))
    lanes = (words.astype(jnp.uint32)[..., None] >> shifts) & mask
    flat = lanes.reshape(*words.shape[:-1], -1)[..., :n]
    return ((flat >> 1).astype(jnp.int32), (flat & 1).astype(jnp.bool_))


# --------------------------------------------------------------------------
# lossy int8 gradient psum (DP sync; never the coloring wire)
# --------------------------------------------------------------------------
def _quantize(x, key):
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    y = x32 / scale
    lo = jnp.floor(y)
    frac = y - lo
    rnd = jax.random.uniform(key, x.shape)
    q = (lo + (rnd < frac)).astype(jnp.int32)
    q = jnp.clip(q, -127, 127)
    return q.astype(jnp.int8), scale


def compressed_psum(x, axis_name, key):
    """Quantized psum of one tensor across ``axis_name``."""
    q, scale = _quantize(x, key)
    # int8 payload accumulates in int32; scales reduce with max (conservative
    # shared scale keeps dequantization linear)
    scale_max = lax.pmax(scale, axis_name)
    # requantize against the shared scale so the sum is exact in int32
    requant = jnp.clip(
        jnp.round(q.astype(jnp.float32) * (scale / scale_max)),
        -127, 127).astype(jnp.int32)
    total = lax.psum(requant, axis_name)
    return total.astype(jnp.float32) * scale_max
