"""Coloring-driven collective scheduling (the in-framework application)."""
import numpy as np
import pytest

from repro.core import schedule_transfers
from repro.core.comm_schedule import moe_all_to_all_transfers


def _assert_conflict_free(transfers, sch):
    t = np.asarray(transfers)
    seen = []
    for r in sch.rounds:
        assert len(set(t[r, 0])) == len(r), "round shares a source"
        assert len(set(t[r, 1])) == len(r), "round shares a destination"
        seen += list(r)
    assert sorted(seen) == list(range(len(transfers)))


def test_schedule_simple():
    transfers = [(0, 1), (0, 2), (1, 2), (3, 1)]
    sch = schedule_transfers(transfers)
    _assert_conflict_free(transfers, sch)
    assert sch.lower_bound == 2
    assert sch.num_rounds <= 3


def test_schedule_full_permutation_one_round():
    transfers = [(i, (i + 1) % 8) for i in range(8)]
    sch = schedule_transfers(transfers)
    assert sch.num_rounds == 1


def test_schedule_moe_dispatch():
    rng = np.random.default_rng(1)
    counts = rng.integers(0, 4, size=(16, 16))
    transfers = moe_all_to_all_transfers(counts)
    sch = schedule_transfers(transfers)
    _assert_conflict_free(transfers, sch)
    # greedy on a union of cliques stays near the port-degree lower bound
    assert sch.num_rounds <= 2 * sch.lower_bound


def test_schedule_device_engine_matches_validity():
    transfers = [(i, j) for i in range(6) for j in range(6) if i != j]
    sch = schedule_transfers(transfers, use_device=True)
    _assert_conflict_free(transfers, sch)
    assert sch.num_rounds >= sch.lower_bound


def test_empty_schedule():
    sch = schedule_transfers([])
    assert sch.num_rounds == 0 and sch.rounds == []
