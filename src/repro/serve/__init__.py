"""Serving layer.

Two lanes:

* **Coloring service** (``repro.serve.coloring``): serving over the
  spec/plan front door. The sync :class:`ColoringService` keeps PR 5's
  API (LRU plan cache keyed by ``(spec, PlanShape)`` bucket envelope,
  vmapped micro-batching, flush-atomic stats). The production shape is
  :class:`AsyncColoringService`: bounded admission onto per-tenant
  queues, deficit-round-robin fairness, deadline-aware micro-batch
  flushing (size OR age), per-tenant edge-delta streams, and
  checkpoint/restore of the whole serving state (bit-identical resume —
  ``tests/test_serve_faults.py``). Observability rides
  :class:`repro.serve.metrics.WindowedMetrics` (windowed p50/p99, cache
  hit rate, retraces, flush-reason histogram). CLI smoke:
  ``PYTHONPATH=src python -m repro.serve --smoke``.
* **LM serving**: the family-dispatched cache/decode primitives live in
  ``repro.models`` (`cache_spec`, `init_cache`, `decode_step`,
  `forward(..., caches=)`) so each architecture's cache layout sits next
  to its math; this package re-exports them as the serving API and hosts
  the batched driver (`repro.launch.serve`). Cache sharding
  (sequence-sharded KV with LSE-combine collectives, ring buffers for
  local attention, O(1) recurrent states) is documented in DESIGN.md §6.
"""
from ..models import cache_spec, init_cache, decode_step, forward

_COLORING = ("ColoringService", "ServedReport", "PlanCache",
             "AsyncColoringService", "AsyncServed", "ServeHandle",
             "AdmissionError")
_METRICS = ("WindowedMetrics", "FLUSH_REASONS", "RESTART_INVARIANT")

__all__ = ["cache_spec", "init_cache", "decode_step", "forward",
           *_COLORING, *_METRICS]


def __getattr__(name):
    # lazy (PEP 562): keeps `python -m repro.serve.coloring` free of the
    # runpy double-import warning and the package import light
    if name in _COLORING:
        from . import coloring
        return getattr(coloring, name)
    if name in _METRICS:
        from . import metrics
        return getattr(metrics, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
