"""Engine-layer tests: the MexBackend registry, the parity matrix across all
registered backends, and the shared fixpoint machinery.

The key invariant: every backend computes the *same exact function* (the
per-vertex minimum excluded color), so swapping backends must not merely
keep colorings valid — ITERATIVE must produce bit-identical colors, round
counts and conflict histories, and DATAFLOW must equal serial greedy, under
every backend.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (Graph, rmat, greedy_color, color_iterative,
                        color_dataflow, validate_coloring,
                        available_backends, get_backend, register_backend)
from repro.core.engine import (BitmapMexBackend, MexBackend, SortMexBackend,
                               edge_slots, lockstep_offsets, num_color_words)

GRAPHS = ["RMAT-ER", "RMAT-G", "RMAT-B"]
ENGINES = ["sort", "bitmap", "ell_pallas"]


def _graph(name, scale=9, seed=1):
    return rmat.paper_graph(name, scale=scale, seed=seed)


def _device(g, engine):
    layout = ("edges", "ell") if get_backend(engine).needs_ell else "edges"
    return g.to_device(layout=layout)


# ----------------------------------------------------------------- registry
def test_default_backends_registered():
    assert set(ENGINES) <= set(available_backends())


def test_get_backend_by_name_and_instance():
    assert get_backend("sort") is get_backend("sort")
    inst = BitmapMexBackend(words=4)
    assert get_backend(inst) is inst
    with pytest.raises(ValueError, match="unknown mex backend"):
        get_backend("no-such-engine")


def test_register_custom_backend():
    from repro.core import engine as engine_mod

    class Doubler(SortMexBackend):
        name = "sort-alias"

    register_backend(Doubler())
    try:
        assert "sort-alias" in available_backends()
        g = _graph("RMAT-ER", scale=8)
        res = color_iterative(g.to_device(), concurrency=8,
                              engine="sort-alias")
        assert validate_coloring(g, np.asarray(res.colors))
    finally:
        # keep the process-global registry hermetic for later tests
        engine_mod._REGISTRY.pop("sort-alias", None)


def test_register_duplicate_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_backend(SortMexBackend())


def test_ell_backend_requires_ell_layout():
    g = _graph("RMAT-ER", scale=8)
    with pytest.raises(ValueError, match="ELL layout"):
        color_iterative(g.to_device(), engine="ell_pallas")


def test_ell_backend_rejects_truncated_width():
    """A truncated ELL layout drops forbids in the slab scatter; the backend
    must refuse it rather than silently return an invalid coloring — and a
    caller-asserted color_bound must not mask the truncation check."""
    g = _graph("RMAT-ER", scale=8)
    dg = g.to_device(layout=("edges", "ell"), ell_width=2)
    with pytest.raises(ValueError, match="below the graph's max degree"):
        color_iterative(dg, engine="ell_pallas")
    with pytest.raises(ValueError, match="below the graph's max degree"):
        color_iterative(dg, engine="ell_pallas", color_bound=2)


def test_bitmap_backend_requires_color_bound():
    with pytest.raises(ValueError, match="color bound"):
        get_backend("bitmap").bind(num_vertices=8, max_colors=0)


def test_undersized_words_override_rejected():
    """An undersized words= override would drop forbids and silently corrupt
    colorings (e.g. a 40-clique needs 42 slots, words=1 gives 32)."""
    n = 40
    edges = np.array([[i, j] for i in range(n) for j in range(i + 1, n)])
    g = Graph.from_edges(n, edges)
    with pytest.raises(ValueError, match="below the graph's Delta"):
        color_iterative(g.to_device(), engine=BitmapMexBackend(words=1))
    # a sufficient override is accepted
    res = color_iterative(g.to_device(), engine=BitmapMexBackend(words=2))
    assert validate_coloring(g, np.asarray(res.colors))
    assert res.num_colors == n


# ------------------------------------------------------------ parity matrix
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("name", GRAPHS)
def test_all_backends_valid_coloring(name, engine):
    """Every registered backend yields a valid coloring on every family."""
    g = _graph(name)
    res = color_iterative(_device(g, engine), concurrency=16, engine=engine)
    assert validate_coloring(g, np.asarray(res.colors))


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("name", GRAPHS)
def test_dataflow_equals_serial_under_every_backend(name, engine):
    """DATAFLOW's fixpoint is the serial greedy coloring regardless of how
    the inner mex is computed."""
    g = _graph(name)
    res = color_dataflow(_device(g, engine), engine=engine)
    np.testing.assert_array_equal(np.asarray(res.colors), greedy_color(g))


@pytest.mark.parametrize("name", GRAPHS)
@pytest.mark.parametrize("concurrency", [4, 64])
def test_sort_bitmap_identical_histories(name, concurrency):
    """sort and bitmap compute the same mex, so at fixed concurrency the
    speculation is deterministic: identical colors, rounds, and per-round
    conflict/sweep counts."""
    g = _graph(name)
    dg = g.to_device()
    a = color_iterative(dg, concurrency=concurrency, engine="sort")
    b = color_iterative(dg, concurrency=concurrency, engine="bitmap")
    np.testing.assert_array_equal(np.asarray(a.colors), np.asarray(b.colors))
    assert a.rounds == b.rounds
    np.testing.assert_array_equal(np.asarray(a.conflicts_per_round),
                                  np.asarray(b.conflicts_per_round))
    np.testing.assert_array_equal(np.asarray(a.sweeps_per_round),
                                  np.asarray(b.sweeps_per_round))


def test_backend_instance_as_engine():
    """Drivers take MexBackend instances directly (parameterized words)."""
    g = _graph("RMAT-ER", scale=8)
    res = color_iterative(g.to_device(), concurrency=8,
                          engine=BitmapMexBackend(words=4))
    assert validate_coloring(g, np.asarray(res.colors))


def test_color_bound_caps_table_capacity():
    """A caller-asserted color_bound shrinks the table backends below the
    provable Delta+1 bound without changing the result (true chromatic
    usage is far below the cap on R-MAT)."""
    g = _graph("RMAT-B")  # skewed: max_degree >> colors used
    dg = g.to_device()
    full = color_iterative(dg, concurrency=16, engine="bitmap")
    capped = color_iterative(dg, concurrency=16, engine="bitmap",
                             color_bound=64)
    np.testing.assert_array_equal(np.asarray(full.colors),
                                  np.asarray(capped.colors))
    df_capped = color_dataflow(dg, engine="bitmap", color_bound=64)
    np.testing.assert_array_equal(np.asarray(df_capped.colors),
                                  greedy_color(g))


# --------------------------------------------------------------- primitives
def test_num_color_words():
    assert num_color_words(1) == 1
    assert num_color_words(30) == 1
    assert num_color_words(31) == 2  # 31+2 > 32
    assert num_color_words(500) == 16


def test_bitmap_mex_matches_python_oracle():
    """The scatter-or bitmap mex == straightforward python mex."""
    rng = np.random.default_rng(0)
    V, M = 17, 200
    key_v = rng.integers(0, V + 1, M).astype(np.int32)  # V = inert
    key_c = rng.integers(0, 40, M).astype(np.int32)
    mex_fn = BitmapMexBackend().bind(num_vertices=V, max_colors=64)
    got = np.asarray(mex_fn(jnp.asarray(key_v), jnp.asarray(key_c)))
    for v in range(V):
        present = {int(c) for vv, c in zip(key_v, key_c) if vv == v} | {0}
        mex = 1
        while mex in present:
            mex += 1
        assert got[v] == mex, v


def test_edge_slots_matches_host_ell_positions():
    g = _graph("RMAT-G", scale=8)
    src, _dst = g.directed_edges()
    slots = np.asarray(edge_slots(jnp.asarray(src), g.num_vertices))
    want = np.arange(src.shape[0], dtype=np.int64) - g.row_ptr[src]
    np.testing.assert_array_equal(slots, want)


def test_lockstep_offsets_matches_block_assignment():
    pending = jnp.asarray([True, False, True, True, False, True, True])
    # 5 pending vertices, 2 threads -> block size 3; offsets 0,1,2,0,1
    off = np.asarray(lockstep_offsets(pending, 2))
    np.testing.assert_array_equal(off, [0, 0, 1, 2, 0, 0, 1])


# ----------------------------------------------------------- layout surface
def test_to_device_layouts():
    g = _graph("RMAT-ER", scale=8)
    dg = g.to_device()
    assert not dg.has_csr and not dg.has_ell and dg.max_degree == g.max_degree()
    dg = g.to_device(layout=("edges", "csr", "ell"))
    assert dg.has_csr and dg.has_ell
    assert dg.ell_width == max(1, g.max_degree())
    np.testing.assert_array_equal(np.asarray(dg.row_ptr), g.row_ptr)
    np.testing.assert_array_equal(np.asarray(dg.col_idx), g.col_idx)
    with pytest.raises(ValueError, match="unknown layout"):
        g.to_device(layout="csc")


def test_device_graph_is_pytree():
    import jax
    g = _graph("RMAT-ER", scale=8)
    dg = g.to_device(layout=("edges", "ell"))
    leaves = jax.tree.leaves(dg)
    assert len(leaves) == 4  # src, dst, ell_slot, inc_ptr (frontier aux)
    dg2 = jax.tree.map(lambda x: x, dg)
    assert dg2.num_vertices == dg.num_vertices
    assert dg2.max_degree == dg.max_degree
    assert dg2.ell_width == dg.ell_width


def test_from_edges_lexsort_dedup():
    """Duplicates / reversed duplicates / self-loops collapse identically to
    the old linear-index dedup."""
    edges = np.array([[0, 1], [1, 0], [0, 1], [2, 2], [1, 2], [2, 1], [3, 0]])
    g = Graph.from_edges(4, edges)
    assert g.num_edges == 3  # (0,1), (1,2), (0,3)
    src, dst = g.directed_edges()
    assert sorted(zip(src.tolist(), dst.tolist())) == [
        (0, 1), (0, 3), (1, 0), (1, 2), (2, 1), (3, 0)]
