"""Config registry: one module per assigned architecture (+ the paper's own
rmat_coloring workload). Each module exposes ``get_config()`` (exact assigned
dims) and ``get_smoke_config()`` (same family switches, tiny dims).

Usage: ``from repro.configs import get_config; cfg = get_config("qwen3-4b")``
or via launchers: ``--arch qwen3-4b``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

_ARCH_MODULES: Dict[str, str] = {
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen3-4b": "qwen3_4b",
    "starcoder2-3b": "starcoder2_3b",
    "gemma2-2b": "gemma2_2b",
    "mamba2-130m": "mamba2_130m",
    "whisper-medium": "whisper_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "grok-1-314b": "grok1_314b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "rmat-coloring": "rmat_coloring",
}

ARCH_IDS: List[str] = [a for a in _ARCH_MODULES if a != "rmat-coloring"]


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)


def get_config(arch: str):
    return _module(arch).get_config()


def get_smoke_config(arch: str):
    return _module(arch).get_smoke_config()
