"""repro.core — the paper's contribution: parallel greedy distance-1 coloring.

Public API:
  Graph / DeviceGraph            containers (host CSR + layout-aware device
                                 arrays: edge list / CSR / ELL)
  rmat.generate / paper_graph    R-MAT test-graph generation (paper §4)
  greedy_color                   serial oracle (Alg. 1)
  color_iterative                speculation+iteration (Alg. 2), JAX
  color_dataflow                 dataflow fixpoint (Alg. 3-5 on TPU), JAX
  dataflow_levels                DAG depth / wavefront profile
  color_distributed              shard_map BSP coloring (Bozdag-style)
  engine                         pluggable first-fit backends: MexBackend,
                                 register_backend, fixpoint_sweep;
                                 engine="sort" | "bitmap" | "ell_pallas"
  comm_schedule                  coloring -> conflict-free collective rounds
"""
from .graph import Graph, DeviceGraph
from . import rmat, ordering, engine
from .engine import (MexBackend, available_backends, get_backend,
                     register_backend)
from .greedy_ref import greedy_color
from .iterative import color_iterative, ColoringResult
from .dataflow import color_dataflow, dataflow_levels, DataflowResult
from .metrics import validate_coloring, count_conflicts, num_colors
from .distributed import color_distributed
from .comm_schedule import schedule_transfers, CommSchedule

__all__ = [
    "Graph", "DeviceGraph", "rmat", "ordering", "engine", "greedy_color",
    "MexBackend", "available_backends", "get_backend", "register_backend",
    "color_iterative", "ColoringResult", "color_dataflow", "dataflow_levels",
    "DataflowResult", "validate_coloring", "count_conflicts", "num_colors",
    "color_distributed", "schedule_transfers", "CommSchedule",
]
