"""repro.core — the paper's contribution: parallel greedy graph coloring,
generalized to a family of coloring models behind one engine.

Public API:
  color(graph, spec)             THE front door: one-shot spec -> report
  ColoringSpec / compile_plan /  declarative spec; compiled plan serving
  ColoringPlan / ColoringReport  same-bucket graphs with zero retrace
                                 (plan.map batches via vmap); one unified
                                 result type for every strategy (api.py)
  ColoringStrategy /             the algorithm registry: "iterative" |
  register_strategy              "dataflow" | "distributed" ship; a new
                                 algorithm is a subclass + one register call
  Graph / BipartiteGraph /       containers (host CSR, bipartite two-sided
  DeviceGraph                    CSR, layout-aware device arrays: edge
                                 list / CSR / ELL)
  rmat.generate / paper_graph    R-MAT test-graph generation (paper §4)
  greedy_color                   serial distance-1 oracle (Alg. 1)
  greedy_color_d2 / _pd2         serial distance-2 / partial-D2 oracles
  color_iterative                speculation+iteration (Alg. 2), JAX
  color_dataflow                 dataflow fixpoint (Alg. 3-5 on TPU), JAX
  dataflow_levels                DAG depth / wavefront profile
  color_distributed              shard_map BSP coloring (Bozdag-style)
  model="d1"|"d2"|"pd2"          coloring model on every driver: distance-1,
                                 distance-2, bipartite partial distance-2
                                 (distance2.py lowers them into the
                                 engine's edge space)
  engine                         pluggable first-fit backends: MexBackend,
                                 register_backend, fixpoint_sweep;
                                 engine="sort" | "bitmap" | "ell_pallas"
  frontier                       active-set execution: rounds >= 1 sweep a
                                 compacted pending slab (O(active), not
                                 O(E)); frontier="auto"|"on"|"off" on every
                                 spec, bit-identical results either way
  DynamicColoring / DeltaReport  streaming graphs (dynamic.py): edge
                                 insert/delete batches repaired in place
                                 by seeding the frontier with the newly
                                 conflicting endpoints — the registered
                                 "recolor" strategy's warm start
  distance2                      the model layer: square, partial_square,
                                 d2_device_graph, pd2_device_graph
  validate_coloring / _d2 / _pd2 per-model validity + conflict counting
  comm_schedule                  coloring -> conflict-free collective rounds
"""
from .graph import Graph, BipartiteGraph, DeviceGraph
from . import rmat, ordering, engine, distance2, frontier
from .engine import (MexBackend, available_backends, get_backend,
                     register_backend)
from .distance2 import square, partial_square
from .greedy_ref import greedy_color, greedy_color_d2, greedy_color_pd2
from .iterative import color_iterative, ColoringResult
from .dataflow import color_dataflow, dataflow_levels, DataflowResult
from .metrics import (validate_coloring, count_conflicts, num_colors,
                      validate_d2_coloring, count_d2_conflicts,
                      validate_pd2_coloring, count_pd2_conflicts)
from .distributed import color_distributed
from .comm_schedule import schedule_transfers, CommSchedule
from . import api
from .api import (ColoringPlan, ColoringReport, ColoringSpec,
                  ColoringStrategy, PlanShape, available_strategies, color,
                  compile_plan, get_strategy, register_strategy)
from . import dynamic
from .dynamic import DeltaReport, DynamicColoring

__all__ = [
    "api", "color", "compile_plan", "ColoringSpec", "ColoringPlan",
    "ColoringReport", "ColoringStrategy", "PlanShape",
    "register_strategy", "get_strategy", "available_strategies",
    "Graph", "BipartiteGraph", "DeviceGraph", "rmat", "ordering", "engine",
    "distance2", "frontier", "dynamic", "DynamicColoring", "DeltaReport",
    "square", "partial_square",
    "greedy_color", "greedy_color_d2", "greedy_color_pd2",
    "MexBackend", "available_backends", "get_backend", "register_backend",
    "color_iterative", "ColoringResult", "color_dataflow", "dataflow_levels",
    "DataflowResult", "validate_coloring", "count_conflicts", "num_colors",
    "validate_d2_coloring", "count_d2_conflicts",
    "validate_pd2_coloring", "count_pd2_conflicts",
    "color_distributed", "schedule_transfers", "CommSchedule",
]
