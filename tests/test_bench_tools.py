"""Tools-level tests for the benchmark harness (benchmarks/run.py):
the per-family atomic JSON flush — a crashing family must never lose the
rows already produced by completed families — and the family registry's
CLI surface staying in sync."""
import importlib.util
import json
import os
import sys

import pytest


def _load_run():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "run.py")
    spec = importlib.util.spec_from_file_location("bench_run", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture()
def bench():
    mod = _load_run()
    yield mod
    sys.modules.pop("bench_run", None)


class _Args:
    scale = None
    ell = False
    json = None


def test_json_flushes_per_family(bench, tmp_path, monkeypatch):
    """One crashing family loses only its own rows: the artifact on disk
    holds every completed family's rows, written atomically."""
    out = tmp_path / "bench.json"

    def fam_ok(args, scale):
        bench._row("ok/row", 1.0, "d=1", extra=7)

    def fam_boom(args, scale):
        bench._row("boom/partial", 2.0, "d=2")
        raise RuntimeError("family crashed mid-run")

    monkeypatch.setattr(bench, "FAMILIES", {
        "fam_ok": (fam_ok, 1), "fam_boom": (fam_boom, 1)})
    with pytest.raises(RuntimeError, match="crashed"):
        bench.run_families(["fam_ok", "fam_boom"], _Args(),
                           json_path=str(out))
    payload = json.loads(out.read_text())
    assert payload["families"] == ["fam_ok"]  # completed families only
    names = [r["name"] for r in payload["rows"]]
    assert "ok/row" in names
    assert payload["rows"][0]["extra"] == 7
    assert not os.path.exists(str(out) + ".tmp")  # rename, not partial write


def test_json_flush_is_atomic_rewrite(bench, tmp_path):
    out = tmp_path / "bench.json"

    def fam(n):
        def run(args, scale):
            bench._row(f"f{n}/row", float(n), f"d={n}")
        return run

    bench.FAMILIES = {"a": (fam(1), 1), "b": (fam(2), 1)}
    bench.run_families(["a", "b"], _Args(), json_path=str(out))
    payload = json.loads(out.read_text())
    assert payload["families"] == ["a", "b"]
    assert len(payload["rows"]) == 2
    assert payload["schema"] == 1


def test_stream_compare_registered(bench):
    assert "stream_compare" in bench.FAMILIES
    assert bench.FAMILIES["stream_compare"][1] == 10
    # the module docstring table and the registry can't drift silently
    for fam in bench.FAMILIES:
        assert fam in bench.__doc__
