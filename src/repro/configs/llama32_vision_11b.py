"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attn image layers every 5th block; patch-embedding
frontend STUB (input_specs feeds pre-projected image tokens [B, 1601, 4096]).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from ..models.config import ModelConfig, VLMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm", num_layers=40, d_model=4096,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=128256,
        rope_theta=500_000.0,
        vlm=VLMConfig(cross_every=5, num_image_tokens=1601))


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke", family="vlm", num_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        rope_theta=500_000.0,
        vlm=VLMConfig(cross_every=2, num_image_tokens=17))
