"""ITERATIVE — the paper's Algorithm 2 (speculation + iteration), vectorized.

Execution model (faithful adaptation, DESIGN.md §2)
---------------------------------------------------
The paper runs Alg. 2's phase-1 loop with ``#pragma omp parallel for`` and
default *static* scheduling: each of ``P`` threads owns a contiguous block of
the pending set and colors it sequentially. In the canonical lockstep
("superstep") model of that execution, the vertices racing at any instant are
those at the same *offset* within their thread's block; a vertex sees the
committed colors of every vertex at a strictly smaller offset, and conflicts
can only arise between same-offset vertices.

We reproduce those semantics exactly on a SIMD machine. Per round:

  1. pending vertices get ``offset = rank % ceil(|U|/P)`` (rank = position in
     the pending set, matching OpenMP-static block assignment);
  2. tentative colors are the fixpoint of the *dataflow equations over the
     offset-precedence DAG* —
         c[v] = mex{ c[w] : w adj v, committed(w) or offset(w) < offset(v) } —
     reached by chaotic sweeps (depth(DAG) of them), which is the SIMD
     equivalent of the threads advancing through their blocks in lockstep;
  3. conflict detection (Alg. 2 lines 11-14): monochromatic pending pairs
     (necessarily same-offset) queue the higher-index endpoint for the next
     round.

Limits: ``concurrency=1`` degenerates to serial greedy (0 conflicts,
colors == Alg. 1); ``concurrency >= |V|`` is the fully-concurrent limit (the
XMT's 16K-thread regime). Conflicts grow with ``concurrency`` — the paper's
Fig. 10(a) trend — and the pending set strictly shrinks every round (the
minimum-index vertex of each conflict cluster always survives), so the loop
terminates.

The first-fit engine is the segmented sort-based mex (O(E log E) per sweep,
TPU-friendly); the Pallas ``firstfit`` kernel offers the bitmask variant for
the ELL path (see kernels/).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from .graph import DeviceGraph
from .mex import segment_mex


@dataclasses.dataclass
class ColoringResult:
    colors: jnp.ndarray               # [V] int32, >= 1
    rounds: int                       # outer iterations (paper Fig. 10b)
    conflicts_per_round: jnp.ndarray  # [max_rounds] int32 (paper Fig. 10c)
    sweeps: int                       # total inner dataflow sweeps

    @property
    def total_conflicts(self) -> int:
        return int(self.conflicts_per_round.sum())

    @property
    def num_colors(self) -> int:
        return int(self.colors.max())


@functools.partial(
    jax.jit,
    static_argnames=("num_vertices", "concurrency", "max_rounds", "max_sweeps",
                     "mex_fn"),
)
def _iterative_impl(src, dst, *, num_vertices: int, concurrency: int,
                    max_rounds: int, max_sweeps: int, mex_fn=None):
    V = num_vertices
    P = concurrency
    syn_v = jnp.arange(V, dtype=jnp.int32)
    syn_c = jnp.zeros((V,), jnp.int32)

    def phase1(colors, pending, offset):
        """Fixpoint of the offset-precedence dataflow equations."""
        ppad = jnp.concatenate([pending, jnp.zeros((1,), jnp.bool_)])
        opad = jnp.concatenate([offset, jnp.full((1,), jnp.iinfo(jnp.int32).max, jnp.int32)])
        src_pending = ppad[src]
        # neighbor forbids src iff committed, or pending at smaller offset
        forbids = src_pending & (~ppad[dst] | (opad[dst] < opad[src]))
        key_v_base = jnp.where(forbids, src, V)

        def sweep(state):
            c, _, n = state
            if mex_fn is not None:
                mex = mex_fn(c, pending, offset)
            else:
                cpad = jnp.concatenate([c, jnp.zeros((1,), jnp.int32)])
                key_c = jnp.where(forbids, cpad[dst], 0)
                mex = segment_mex(
                    jnp.concatenate([key_v_base, syn_v]),
                    jnp.concatenate([key_c, syn_c]), V)
            c_new = jnp.where(pending, mex, c)
            return c_new, jnp.any(c_new != c), n + 1

        def cond(state):
            _, changed, n = state
            return jnp.logical_and(changed, n < max_sweeps)

        c0 = jnp.where(pending, 0, colors)
        c, _, n = lax.while_loop(cond, sweep, (c0, jnp.asarray(True), jnp.asarray(0, jnp.int32)))
        return c, n

    def round_body(state):
        colors, pending, rnd, conf_hist, sweeps = state
        # OpenMP-static lockstep offsets over the pending set
        r = pending.sum(dtype=jnp.int32)
        bs = lax.div(r + P - 1, P)  # block size = supersteps this round
        rank = jnp.cumsum(pending.astype(jnp.int32)) - 1
        offset = jnp.where(pending, rank % jnp.maximum(bs, 1), 0).astype(jnp.int32)

        colors, n_sweeps = phase1(colors, pending, offset)

        # Phase 2 — conflicts among same-round pairs; higher index recolors.
        cpad = jnp.concatenate([colors, jnp.zeros((1,), jnp.int32)])
        ppad = jnp.concatenate([pending, jnp.zeros((1,), jnp.bool_)])
        conf_e = ppad[src] & ppad[dst] & (cpad[src] == cpad[dst]) & (src > dst)
        new_pending = (jnp.zeros((V,), jnp.int32)
                       .at[src].max(conf_e.astype(jnp.int32), mode="drop")
                       .astype(jnp.bool_))
        conf_hist = conf_hist.at[rnd].set(new_pending.sum(dtype=jnp.int32))
        return colors, new_pending, rnd + 1, conf_hist, sweeps + n_sweeps

    def cond(state):
        _, pending, rnd, _, _ = state
        return jnp.logical_and(jnp.any(pending), rnd < max_rounds)

    init = (
        jnp.zeros((V,), jnp.int32),
        jnp.ones((V,), jnp.bool_),
        jnp.asarray(0, jnp.int32),
        jnp.zeros((max_rounds,), jnp.int32),
        jnp.asarray(0, jnp.int32),
    )
    colors, pending, rnd, conf_hist, sweeps = lax.while_loop(cond, round_body, init)
    return colors, rnd, conf_hist, sweeps, jnp.any(pending)


def color_iterative(
    g: DeviceGraph,
    concurrency: int = 64,
    max_rounds: int = 64,
    max_sweeps: int = 4096,
    mex_fn=None,
) -> ColoringResult:
    """Run ITERATIVE with ``concurrency`` lockstep virtual threads.

    ``mex_fn(colors, pending, offset)`` optionally replaces the sort-based
    first-fit engine (e.g. the Pallas ELL kernel path from kernels/ops.py)."""
    colors, rnd, conf_hist, sweeps, left = _iterative_impl(
        g.src, g.dst, num_vertices=g.num_vertices,
        concurrency=int(concurrency), max_rounds=max_rounds, max_sweeps=max_sweeps,
        mex_fn=mex_fn,
    )
    if bool(left):
        raise RuntimeError(f"ITERATIVE did not converge in {max_rounds} rounds")
    return ColoringResult(colors=colors, rounds=int(rnd),
                          conflicts_per_round=conf_hist, sweeps=int(sweeps))
