"""Pallas TPU kernels for the coloring hot spots (+ jnp oracles).

firstfit — bitmask first-fit over ELL neighbor-color slabs (Alg. 1 lines 5-6)
conflict — edge-parallel conflict detection (Alg. 2 line 13)

The kernels plug into the coloring drivers through the mex-backend registry
(``repro.core.engine``, ``engine="ell_pallas"``) rather than hand-wired
closures.
"""
from .firstfit import firstfit
from .conflict import conflict_mask
from .ref import firstfit_ref, conflict_mask_ref
from .ops import ell_mex, ell_gather_colors, count_conflicts_kernel, INTERPRET

__all__ = [
    "firstfit", "conflict_mask", "firstfit_ref", "conflict_mask_ref",
    "ell_mex", "ell_gather_colors", "count_conflicts_kernel", "INTERPRET",
]
