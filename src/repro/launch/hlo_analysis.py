"""Post-SPMD HLO analysis for the roofline terms.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scanned-layers program under-reports FLOPs/bytes by ~n_layers (verified
empirically — see EXPERIMENTS.md §Dry-run methodology). This module walks the
optimized per-device HLO text instead and computes, with while-loop
trip-count multipliers folded through the call graph:

  * dot_flops       — 2 x prod(result dims) x prod(contracting dims) per
                      ``dot`` (incl. dots inside fusion computations: they
                      still occupy the MXU);
  * boundary_bytes  — operand+result bytes of *top-level* ops in the entry /
                      while bodies / conditional branches (fusion interiors
                      excluded: only fusion boundaries touch HBM) — the HBM
                      traffic model;
  * collective bytes by kind — result-shape bytes of all-reduce/all-gather/
                      reduce-scatter/all-to-all/collective-permute ops.

All shapes in the SPMD module are per-device shard shapes, so every number
is per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _dims(dim_str: str) -> List[int]:
    return [int(d) for d in dim_str.split(",") if d]


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class HloStats:
    dot_flops: float
    boundary_bytes: float
    collective_bytes_by_kind: Dict[str, float]
    collective_counts: Dict[str, int]
    while_trip_counts: List[int]

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective_bytes_by_kind.values())


# --------------------------------------------------------------- parsing
def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        # computation headers end with "{" and contain "->"; params may nest
        # parens (tuple types), so don't regex the arg list
        if stripped.endswith("{") and "->" in stripped and "=" not in stripped.split("(")[0]:
            toks = stripped.split()
            name = toks[1] if toks[0] == "ENTRY" else toks[0]
            cur = name.split("(")[0].lstrip("%")
            comps[cur] = []
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None and stripped and not stripped.startswith("//"):
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> Optional[int]:
    consts = {}
    for ln in cond_lines:
        m = re.match(r"%?([\w\.\-]+)\s*=\s*[su]32\[\]\s*constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if "compare(" in ln:
            args = re.search(r"compare\(([^)]*)\)", ln)
            if args:
                for a in args.group(1).split(","):
                    name = a.strip().split(" ")[-1].lstrip("%")
                    if name in consts:
                        return consts[name]
    if consts:
        return max(consts.values())
    return None


_CALL_REFS = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)"
    r"|branch_computations=\{([^}]*)\}")


def _analyze_structure(comps: Dict[str, List[str]]):
    """Returns (edges: caller -> [(callee, mult)], fusion_targets, trip_counts)."""
    edges: Dict[str, List[Tuple[str, int]]] = {c: [] for c in comps}
    fusion_targets = set()
    apply_targets = set()
    trips = []
    for cname, lines in comps.items():
        for ln in lines:
            is_while = re.search(r"\bwhile\(", ln) is not None
            tc = 1
            if is_while:
                # XLA annotates optimized whiles with the known trip count
                mk = re.search(r'known_trip_count[":{]+n[":]+(\d+)', ln)
                if mk:
                    tc = int(mk.group(1))
                else:
                    mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                    if mc:
                        t = _trip_count(comps.get(mc.group(1), []))
                        tc = t if t else 1
                trips.append(tc)
            for m in _CALL_REFS.finditer(ln):
                if m.group(1):
                    callees = [m.group(1)]
                else:
                    callees = [c.strip().lstrip("%") for c in m.group(2).split(",")]
                for callee in callees:
                    if callee not in comps:
                        continue
                    k = tc if (is_while and "body=" in ln and
                               f"body=%{callee}" in ln or
                               is_while and f"body={callee}" in ln) else (tc if is_while else 1)
                    edges[cname].append((callee, k))
                    if "calls=" in ln and f"calls=%{callee}" in ln or f"calls={callee}" in ln:
                        fusion_targets.add(callee)
                    if "to_apply=" in ln and (f"to_apply=%{callee}" in ln or f"to_apply={callee}" in ln):
                        apply_targets.add(callee)
    return edges, fusion_targets, apply_targets, trips


def _multipliers(comps, edges, entry_hint="main"):
    entry = None
    for name in comps:
        if name.startswith(entry_hint) or name.startswith("jit_"):
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))
    mult: Dict[str, float] = {c: 0.0 for c in comps}

    import sys
    sys.setrecursionlimit(10000)

    def dfs(c, m, depth=0):
        if depth > 50:
            return
        mult[c] = mult.get(c, 0.0) + m
        for callee, k in edges.get(c, []):
            dfs(callee, m * k, depth + 1)

    if entry is not None:
        dfs(entry, 1.0)
    # computations never reached from entry (shouldn't happen) get 1x
    for c in mult:
        if mult[c] == 0.0:
            mult[c] = 1.0
    return mult, entry


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^={]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))")


def _defs_of(lines: List[str]) -> Dict[str, str]:
    """name -> result-type string for every instruction in a computation."""
    defs = {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            defs[m.group(1)] = m.group(2)
    return defs


def _dot_flops_of_line(ln: str, defs: Dict[str, str]) -> float:
    """2 x prod(result) x prod(contracting dims) for a dot op. Operand types
    come from the computation's symbol table (optimized HLO doesn't inline
    them)."""
    m_res = _DEF_RE.match(ln)
    if not m_res:
        return 0.0
    ms = _SHAPE_RE.search(m_res.group(2))
    if not ms:
        return 0.0
    result_dims = _dims(ms.group(2))
    args = re.search(r"\bdot\(([^)]*)\)", ln)
    if not args:
        return 0.0
    arg_str = args.group(1)
    # operand types may be inlined ("f32[8,128]{1,0} %lhs, ...") — naive
    # comma-splitting would cut inside the dims, so take the first shape
    # before the first operand name instead
    head = arg_str.split("%")[0] if "%" in arg_str else arg_str
    mt = _SHAPE_RE.search(head)
    if mt:
        lhs_dims = _dims(mt.group(2))
    else:
        lhs_type = defs.get(arg_str.split(",")[0].strip().split(" ")[-1].lstrip("%"), "")
        mt = _SHAPE_RE.search(lhs_type)
        if not mt:
            return 0.0
        lhs_dims = _dims(mt.group(2))
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
    contract = 1
    if mc:
        for d in _dims(mc.group(1)):
            if d < len(lhs_dims):
                contract *= lhs_dims[d]
    mb = re.search(r"lhs_batch_dims=\{([\d,]*)\}", ln)
    n = 1
    for d in result_dims:
        n *= d
    return 2.0 * n * contract


_SLICE_HINT = re.compile(r"dynamic-slice\(|\bgather\(|dynamic_slice|\bslice\(")
_DUS_HINT = re.compile(r"dynamic-update-slice\(|dynamic_update_slice|\bscatter\(")


def _op_boundary_bytes(ln: str, defs: Dict[str, str]) -> int:
    """Operand + result bytes of one top-level op (HBM traffic proxy:
    every fusion-boundary value is written once and read once).

    Slice-like ops only touch the sliced region, not the whole buffer:
    dynamic-slice/gather cost ~2x result; dynamic-update-slice/scatter cost
    ~2x the update (smallest tensor operand). Detected from the op itself or
    the fusion's op_name metadata."""
    m = _DEF_RE.match(ln)
    result_b = _shape_bytes(m.group(2)) if m else 0
    arg_bytes = []
    args = re.search(r"\w[\w\-\$]*\(([^)]*)\)", ln.split("=", 1)[-1])
    if args:
        for a in args.group(1).split(","):
            name = a.strip().split(" ")[-1].lstrip("%")
            if name in defs:
                arg_bytes.append(_shape_bytes(defs[name]))
    if _DUS_HINT.search(ln):
        nz = [b for b in arg_bytes if b > 0]
        upd = min(nz) if nz else result_b
        return 2 * min(upd, result_b if result_b else upd)
    if _SLICE_HINT.search(ln):
        return 2 * result_b
    return result_b + sum(arg_bytes)


_SKIP_BYTES_OPS = re.compile(
    r"=\s*(?:\w+\[[\d,]*\](?:\{[^}]*\})?|\([^)]*\))\s*"
    r"(parameter|constant|iota|get-tuple-element|tuple|bitcast|copy-start|copy-done)\b")


def analyze_hlo(hlo: str, entry_hint: str = "main") -> HloStats:
    comps = _split_computations(hlo)
    edges, fusion_targets, apply_targets, trips = _analyze_structure(comps)
    mult, entry = _multipliers(comps, edges, entry_hint)

    interior = fusion_targets | apply_targets
    dot_flops = 0.0
    boundary_bytes = 0.0
    coll_bytes = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0 for k in _COLLECTIVES}

    for cname, lines in comps.items():
        m = mult.get(cname, 1.0)
        is_interior = cname in interior
        defs = _defs_of(lines)
        for ln in lines:
            if " dot(" in ln:
                dot_flops += _dot_flops_of_line(ln, defs) * m
            if not is_interior:
                if not _SKIP_BYTES_OPS.search(ln):
                    boundary_bytes += _op_boundary_bytes(ln, defs) * m
                for kind in _COLLECTIVES:
                    if re.search(rf"=\s*[^=]*\b{kind}(?:-start)?\(", ln):
                        type_str = ln.split("=", 1)[1].split(kind)[0]
                        coll_bytes[kind] += _shape_bytes(type_str) * m
                        coll_counts[kind] += 1
                        break
    return HloStats(
        dot_flops=dot_flops,
        boundary_bytes=boundary_bytes,
        collective_bytes_by_kind=coll_bytes,
        collective_counts=coll_counts,
        while_trip_counts=trips,
    )
