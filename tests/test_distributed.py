"""Distributed coloring + sharding tests. Multi-device cases run in a
subprocess with XLA_FLAGS host-device override so the main pytest process
keeps a single device."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str, devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_distributed_coloring_valid_8dev():
    res = _run_subprocess(textwrap.dedent("""
        import json, numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import rmat, color_distributed, validate_coloring, greedy_color
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        out = {}
        for name in ["RMAT-ER", "RMAT-B"]:
            g = rmat.paper_graph(name, scale=10, seed=3)
            colors, rounds, conf = color_distributed(g, mesh)
            out[name] = dict(valid=bool(validate_coloring(g, colors)),
                             colors=int(colors.max()),
                             serial=int(greedy_color(g).max()),
                             rounds=int(rounds),
                             conflicts=[int(c) for c in conf[:rounds]])
        print(json.dumps(out))
    """))
    for name, r in res.items():
        assert r["valid"], name
        assert r["colors"] <= r["serial"] + 4
        assert r["rounds"] <= 12
        # conflicts decay monotonically-ish; last round zero
        assert r["conflicts"][-1] == 0


def test_distributed_engine_parity():
    """color_distributed accepts every registered mex backend and produces
    identical colors (the backends compute the same mex function)."""
    res = _run_subprocess(textwrap.dedent("""
        import json, numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import rmat, color_distributed, validate_coloring
        g = rmat.paper_graph("RMAT-G", scale=8, seed=5)
        mesh = Mesh(np.array(jax.devices()[:2]), ("x",))
        out = {}
        ref = None
        for engine in ["sort", "bitmap", "ell_pallas"]:
            colors, rounds, _ = color_distributed(g, mesh, engine=engine)
            if ref is None:
                ref = colors
            out[engine] = dict(valid=bool(validate_coloring(g, colors)),
                               rounds=int(rounds),
                               same=bool(np.array_equal(colors, ref)))
        print(json.dumps(out))
    """), devices=2)
    for engine, r in res.items():
        assert r["valid"] and r["same"], (engine, r)


def test_distributed_d2_and_pd2_models():
    """model="d2"/"pd2" through the BSP driver: the constraint-graph
    lowering feeds the same machinery (two-hop halos ride the existing
    full-vector gather), and results validate against the host oracles."""
    res = _run_subprocess(textwrap.dedent("""
        import json, numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import (rmat, color_distributed, BipartiteGraph,
                                validate_d2_coloring, validate_pd2_coloring,
                                greedy_color_d2, greedy_color_pd2)
        mesh = Mesh(np.array(jax.devices()[:4]), ("x",))
        g = rmat.paper_graph("RMAT-G", scale=8, seed=2)
        colors, rounds, conf = color_distributed(g, mesh, model="d2")
        out = dict(d2=dict(valid=bool(validate_d2_coloring(g, colors)),
                           colors=int(colors.max()),
                           serial=int(greedy_color_d2(g).max()),
                           rounds=int(rounds)))
        rng = np.random.default_rng(0)
        edges = np.stack([rng.integers(0, 96, 600),
                          rng.integers(0, 64, 600)], 1)
        bg = BipartiteGraph.from_edges(96, 64, edges)
        colors, rounds, conf = color_distributed(bg, mesh, model="pd2")
        out["pd2"] = dict(valid=bool(validate_pd2_coloring(bg, colors)),
                          n=int(colors.shape[0]),
                          colors=int(colors.max()),
                          serial=int(greedy_color_pd2(bg).max()),
                          rounds=int(rounds))
        print(json.dumps(out))
    """), devices=4)
    assert res["d2"]["valid"] and res["pd2"]["valid"]
    assert res["pd2"]["n"] == 96  # colors only the left class
    # speculative quality stays near the serial oracle, as in the D1 case
    assert res["d2"]["colors"] <= int(1.3 * res["d2"]["serial"]) + 4
    assert res["pd2"]["colors"] <= int(1.3 * res["pd2"]["serial"]) + 4


def test_distributed_matches_across_device_counts():
    """BSP coloring stays valid at different mesh sizes (elastic)."""
    res = _run_subprocess(textwrap.dedent("""
        import json, numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import rmat, color_distributed, validate_coloring
        g = rmat.paper_graph("RMAT-G", scale=9, seed=1)
        out = {}
        for d in [2, 4, 8]:
            mesh = Mesh(np.array(jax.devices()[:d]), ("x",))
            colors, rounds, _ = color_distributed(g, mesh)
            out[str(d)] = dict(valid=bool(validate_coloring(g, colors)),
                               rounds=int(rounds))
        print(json.dumps(out))
    """))
    assert all(v["valid"] for v in res.values())


def test_sharded_train_step_2x2():
    """Sharded train step on a 2x2 host mesh: loss finite, params update,
    and the result matches the single-device step."""
    res = _run_subprocess(textwrap.dedent("""
        import json, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro import models
        from repro.train import AdamWConfig, init_opt_state, make_train_step
        from repro.parallel.sharding import (DEFAULT_RULES, rules_for_mesh,
                                             activation_rules)
        from repro.launch import specs as S

        cfg = get_smoke_config("qwen3-4b")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = rules_for_mesh(mesh)
        params, axes = models.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 33)), jnp.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1)
        opt = init_opt_state(params, opt_cfg)
        step = make_train_step(cfg, opt_cfg)

        # single device reference
        p_ref, o_ref, m_ref = jax.jit(step)(params, opt, batch)

        p_sh = S.tree_shardings(jax.eval_shape(lambda: params), axes, rules, mesh)
        params_dev = jax.tree.map(jax.device_put, params, p_sh)
        def fn(p, o, b):
            with activation_rules(rules):
                return step(p, o, b)
        from repro.jax_compat import set_mesh
        with set_mesh(mesh):
            p2, o2, m = jax.jit(fn, in_shardings=(p_sh, None, None))(params_dev, opt, batch)
        diff = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
                   for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)))
        print(json.dumps(dict(loss=float(m["loss"]), ref=float(m_ref["loss"]),
                              maxdiff=diff)))
    """), devices=4)
    assert abs(res["loss"] - res["ref"]) < 1e-2
    assert res["maxdiff"] < 5e-2


def test_compressed_psum_multidevice():
    res = _run_subprocess(textwrap.dedent("""
        import json, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.jax_compat import shard_map
        from repro.parallel.compression import compressed_psum
        mesh = jax.make_mesh((4,), ("d",))
        x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64)), jnp.float32)
        def f(x):
            return compressed_psum(x[0], "d", jax.random.PRNGKey(0))
        y = jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                              out_specs=P()))(x)
        exact = np.asarray(x).sum(0)
        err = float(np.abs(np.asarray(y) - exact).max())
        scale = float(np.abs(np.asarray(x)).max() / 127 * 4)
        print(json.dumps(dict(err=err, tol=scale * 1.5)))
    """), devices=4)
    assert res["err"] <= res["tol"]
