"""Deterministic synthetic data pipeline with coloring-scheduled prefetch.

Determinism contract (fault tolerance): the batch for (seed, step, host) is
a pure function — restarting from a checkpoint at step k replays exactly the
same stream (``skip-to-step`` is free). Tokens follow a Zipf-ish skew so MoE
routing and vocab shards see realistic imbalance.

Shard scheduling: when many input shards contend on sources (same file
server / disk), ``plan_prefetch_waves`` builds the conflict graph and uses
the paper's coloring to emit contention-free prefetch waves (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import Graph, greedy_color


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    family: str = "dense"           # adds frames/image_embeds stubs
    d_model: int = 0
    enc_seq: int = 0
    num_image_tokens: int = 0


def _rng_for(cfg: DataConfig, step: int, host: int = 0):
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host, 0xDA7A]))


def batch_for_step(cfg: DataConfig, step: int, host: int = 0,
                   hosts: int = 1) -> Dict[str, np.ndarray]:
    """Host-local slice of the global batch for ``step`` (deterministic)."""
    assert cfg.global_batch % hosts == 0
    b = cfg.global_batch // hosts
    rng = _rng_for(cfg, step, host)
    z = rng.zipf(cfg.zipf_a, size=(b, cfg.seq_len + 1))
    toks = (z % (cfg.vocab_size - 1) + 1).astype(np.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (b, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = rng.standard_normal(
            (b, cfg.num_image_tokens, cfg.d_model)).astype(np.float32)
    return batch


def data_config_for(model_cfg, shape) -> DataConfig:
    return DataConfig(
        vocab_size=model_cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, family=model_cfg.family,
        d_model=model_cfg.d_model,
        enc_seq=model_cfg.encdec.enc_seq if model_cfg.encdec else 0,
        num_image_tokens=model_cfg.vlm.num_image_tokens if model_cfg.vlm else 0)


# ------------------------------------------------- coloring-scheduled waves
def plan_prefetch_waves(shard_sources: Sequence[int]) -> List[List[int]]:
    """Group shards into waves such that no wave reads one source twice.

    ``shard_sources[i]`` = source id (file server / disk) of shard i.
    Returns waves (lists of shard indices) — greedy distance-1 coloring of
    the same-source conflict cliques (the paper's abstraction of §1)."""
    src = np.asarray(shard_sources)
    n = src.shape[0]
    edges = []
    for s in np.unique(src):
        members = np.nonzero(src == s)[0]
        if len(members) > 1:
            ii, jj = np.triu_indices(len(members), k=1)
            edges.append(np.stack([members[ii], members[jj]], 1))
    if edges:
        g = Graph.from_edges(n, np.concatenate(edges, 0))
    else:
        g = Graph.from_edges(n, np.zeros((0, 2), np.int64))
    colors = greedy_color(g)
    return [list(np.nonzero(colors == c)[0])
            for c in range(1, int(colors.max()) + 1)]
