"""Graph containers for the coloring engine.

Two representations:

* :class:`Graph` — host-side (numpy) CSR + directed edge list. Construction,
  dedup, symmetrization, stats live here.
* :class:`DeviceGraph` — fixed-shape jnp arrays consumed by the JAX coloring
  algorithms (directed edge list, optionally padded ELL for the Pallas path).

Conventions
-----------
* Vertices are ``int32`` ids in ``[0, V)``.
* The *directed* edge list contains both ``(u, v)`` and ``(v, u)`` for every
  undirected edge, so per-vertex reductions over ``src`` see every neighbor.
* Colors are positive ints; ``0`` means "uncolored".
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Graph:
    """Host-side undirected graph in CSR form (numpy)."""

    num_vertices: int
    row_ptr: np.ndarray  # [V+1] int64
    col_idx: np.ndarray  # [2E]  int32, neighbors sorted per row

    # ---------------------------------------------------------- construction
    @staticmethod
    def from_edges(num_vertices: int, edges: np.ndarray) -> "Graph":
        """Build from an [M, 2] array of (possibly duplicated, possibly
        self-looped, possibly one-directional) edges — mirrors the paper's
        post-processing of R-MAT output (dup/self-loop removal)."""
        edges = np.asarray(edges, dtype=np.int64)
        if edges.size == 0:
            return Graph(num_vertices,
                         np.zeros(num_vertices + 1, np.int64),
                         np.zeros(0, np.int32))
        u, v = edges[:, 0], edges[:, 1]
        keep = u != v  # drop self loops
        u, v = u[keep], v[keep]
        # symmetrize, dedup via linear index
        src = np.concatenate([u, v])
        dst = np.concatenate([v, u])
        lin = src * num_vertices + dst
        lin = np.unique(lin)
        src = (lin // num_vertices).astype(np.int32)
        dst = (lin % num_vertices).astype(np.int32)
        # lin is sorted => src sorted, dst sorted within src
        counts = np.bincount(src, minlength=num_vertices).astype(np.int64)
        row_ptr = np.zeros(num_vertices + 1, np.int64)
        np.cumsum(counts, out=row_ptr[1:])
        return Graph(num_vertices, row_ptr, dst)

    # ---------------------------------------------------------------- stats
    @property
    def num_directed_edges(self) -> int:
        return int(self.col_idx.shape[0])

    @property
    def num_edges(self) -> int:
        return self.num_directed_edges // 2

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr).astype(np.int64)

    def max_degree(self) -> int:
        d = self.degrees()
        return int(d.max()) if d.size else 0

    def degree_variance(self) -> float:
        d = self.degrees()
        return float(d.var()) if d.size else 0.0

    def isolated_fraction(self) -> float:
        d = self.degrees()
        return float((d == 0).mean()) if d.size else 0.0

    def stats(self) -> dict:
        """The columns of the paper's Table 2 / Table 4."""
        return {
            "num_vertices": self.num_vertices,
            "num_edges": self.num_edges,
            "avg_degree": (2.0 * self.num_edges / max(1, self.num_vertices)),
            "max_degree": self.max_degree(),
            "degree_variance": self.degree_variance(),
            "pct_isolated": 100.0 * self.isolated_fraction(),
        }

    # ------------------------------------------------------------ transforms
    def directed_edges(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) with both directions present; src is sorted."""
        src = np.repeat(
            np.arange(self.num_vertices, dtype=np.int32),
            np.diff(self.row_ptr).astype(np.int64),
        )
        return src, self.col_idx.astype(np.int32)

    def relabel(self, perm: np.ndarray) -> "Graph":
        """Relabel vertices: new id of old vertex i is ``perm[i]``."""
        src, dst = self.directed_edges()
        new_src = perm[src].astype(np.int64)
        new_dst = perm[dst].astype(np.int64)
        half = new_src < new_dst
        return Graph.from_edges(
            self.num_vertices, np.stack([new_src[half], new_dst[half]], 1)
        )

    def to_device(self, *, pad_edges_to: Optional[int] = None) -> "DeviceGraph":
        src, dst = self.directed_edges()
        e = src.shape[0]
        pad = (pad_edges_to or e) - e
        if pad < 0:
            raise ValueError(f"pad_edges_to={pad_edges_to} < num edges {e}")
        if pad:
            # padding edges point at a phantom vertex V with src=V so they are
            # inert in segment reductions over [0, V)
            src = np.concatenate([src, np.full(pad, self.num_vertices, np.int32)])
            dst = np.concatenate([dst, np.full(pad, self.num_vertices, np.int32)])
        return DeviceGraph(
            num_vertices=self.num_vertices,
            num_directed_edges=e,
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
        )

    def to_ell(self, max_degree: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Padded ELL adjacency: ([V, D] int32 neighbor ids, [V] degrees).

        Pad slots hold ``V`` (phantom vertex). Used by the Pallas firstfit
        path, which wants a dense regular slab.
        """
        deg = self.degrees()
        d_max = int(max_degree if max_degree is not None else (deg.max() if deg.size else 0))
        ell = np.full((self.num_vertices, max(1, d_max)), self.num_vertices, np.int32)
        src, dst = self.directed_edges()
        # position of each edge within its row
        pos = np.arange(src.shape[0], dtype=np.int64) - self.row_ptr[src]
        ok = pos < d_max
        ell[src[ok], pos[ok]] = dst[ok]
        return ell, deg.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """Fixed-shape directed edge list on device."""

    num_vertices: int
    num_directed_edges: int
    src: jnp.ndarray  # [E2p] int32 in [0, V]; V = padding
    dst: jnp.ndarray  # [E2p] int32 in [0, V]

    @property
    def padded_edges(self) -> int:
        return int(self.src.shape[0])
