"""Batched serving example: prefill + autoregressive decode with KV caches
(ring buffers for local-attention layers, recurrent state for SSM/hybrid).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b --smoke
"""
import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (big; default is smoke)")
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--batch", "8", "--prompt-len", "64",
            "--gen", "32", "--temperature", "0.8"]
    if not args.full:
        argv.append("--smoke")
    serve.main(argv)


if __name__ == "__main__":
    main()
