"""Async coloring-service tests (repro.serve.coloring.AsyncColoringService):
bounded admission, deficit-round-robin tenant fairness, deadline-aware
micro-batch flushing, windowed metrics — all on a fake clock (no sleeps) —
plus the hypothesis property that ANY interleaving of multi-tenant
requests and stream deltas through the async scheduler equals serial
per-tenant execution."""
import numpy as np
import pytest

from conftest import FakeClock
from repro.core import ColoringSpec, color, rmat, validate_coloring
from repro.core.dynamic import DynamicColoring
from repro.core.graph import Graph
from repro.serve.coloring import AdmissionError, AsyncColoringService
from repro.serve.metrics import WindowedMetrics


def _g(scale=7, seed=0):
    return rmat.paper_graph("RMAT-G", scale=scale, seed=seed)


def _svc(clock, **kw):
    kw.setdefault("default_spec", ColoringSpec(strategy="dataflow"))
    return AsyncColoringService(clock=clock, **kw)


# --------------------------------------------------------------- admission
def test_admission_bound_rejects_and_recovers(fake_clock):
    svc = _svc(fake_clock, max_queue_depth=4, max_delay_s=0.0)
    g = _g()
    hs = [svc.submit(g) for _ in range(4)]
    with pytest.raises(AdmissionError):
        svc.submit(g)
    assert svc.metrics.snapshot()["cumulative"]["rejected"] == 1
    assert svc.backlog == 4
    svc.drain()
    assert svc.backlog == 0
    for h in hs:
        assert validate_coloring(g, h.result().report.colors)
    # capacity is freed by the flush: admission works again
    svc.submit(g)
    svc.drain()


def test_handle_result_before_flush_raises(fake_clock):
    svc = _svc(fake_clock, max_delay_s=10.0)
    h = svc.submit(_g())
    assert not h.done
    with pytest.raises(RuntimeError, match="not served yet"):
        h.result()
    svc.drain()
    assert h.done and h.result().flush_reason == "drain"


# ---------------------------------------------------------------- fairness
def test_deficit_round_robin_interleaves_a_flooding_tenant(fake_clock):
    """Tenant A floods 6 requests before B's 2 arrive; with quantum 1 and
    batch 2, every scheduler turn admits one request per backlogged
    tenant, so B's work rides the FIRST two flushes instead of queueing
    behind all of A's (what FIFO admission would do)."""
    svc = _svc(fake_clock, tenant_quantum=1, max_batch=2, max_delay_s=10.0)
    g = _g()
    ha = [svc.submit(g, tenant="A") for _ in range(6)]
    hb = [svc.submit(g, tenant="B") for _ in range(2)]
    svc.pump()  # turn 1: admits A0+B0 -> size flush
    assert sum(h.done for h in ha) == 1 and sum(h.done for h in hb) == 1
    svc.pump()  # turn 2: admits A1+B1 -> size flush; B fully served
    assert all(h.done for h in hb) and sum(h.done for h in ha) == 2
    served = svc.drain()
    assert served == 4 and all(h.done for h in ha)
    assert svc.tenant_served == {"A": 6, "B": 2}


# ---------------------------------------------------------- deadline flush
def test_deadline_flush_fires_on_age_not_size(fake_clock):
    svc = _svc(fake_clock, max_batch=8, max_delay_s=1.0)
    g = _g()
    h1, h2 = svc.submit(g), svc.submit(g)
    assert svc.pump() == 0          # age 0 < 1s: batch stays open
    fake_clock.tick(0.5)
    assert svc.pump() == 0          # still under budget
    fake_clock.tick(0.6)
    assert svc.pump() == 2          # 1.1s > 1s: deadline flush
    for h in (h1, h2):
        r = h.result()
        assert r.flush_reason == "deadline"
        assert r.queue_age_s == pytest.approx(1.1)
    snap = svc.metrics.snapshot()
    assert snap["cumulative"]["flush_reasons"]["deadline"] == 1
    assert snap["cumulative"]["max_queue_age_s"] == pytest.approx(1.1)
    # the fake clock makes the window percentiles exact too
    assert snap["window"]["p50_ms"] == pytest.approx(1100.0)


def test_size_flush_fires_immediately(fake_clock):
    svc = _svc(fake_clock, max_batch=2, max_delay_s=10.0)
    g = _g()
    h1, h2 = svc.submit(g), svc.submit(g)
    assert svc.pump() == 2
    assert h1.result().flush_reason == "size"
    assert h1.result().batched and h2.result().batched  # one vmapped map
    assert svc.metrics.snapshot()["cumulative"]["batched_requests"] == 2


def test_mixed_keys_flush_independently(fake_clock):
    """Different (spec, envelope) keys open different batches: a full
    batch for one key must not flush another key's open batch."""
    svc = _svc(fake_clock, max_batch=2, max_delay_s=10.0)
    g7, g8 = _g(7), _g(8)  # different V -> different envelope keys
    ha = [svc.submit(g7) for _ in range(2)]
    hb = svc.submit(g8)
    assert svc.pump() == 2  # only the full g7 batch flushes
    assert all(h.done for h in ha) and not hb.done
    svc.drain()
    assert hb.result().flush_reason == "drain"


# ----------------------------------------------------------------- metrics
def test_windowed_metrics_prunes_by_time():
    clk = FakeClock()
    m = WindowedMetrics(window_s=10.0, clock=clk)
    m.record_flush("size", latencies=[0.001] * 3, queue_ages=[0.0] * 3,
                   exec_s=0.003)
    clk.tick(5.0)
    m.record_flush("deadline", latencies=[0.009], queue_ages=[0.004],
                   exec_s=0.001)
    assert m.snapshot()["window"]["count"] == 4
    clk.tick(6.0)  # first flush's samples age out (t=0 < 11-10)
    snap = m.snapshot()
    assert snap["window"]["count"] == 1
    assert snap["window"]["p50_ms"] == pytest.approx(9.0)
    # cumulative counters never prune
    assert snap["cumulative"]["requests"] == 4
    assert snap["cumulative"]["flush_reasons"] == {
        "size": 1, "deadline": 1, "drain": 0}


def test_windowed_metrics_state_roundtrip():
    clk = FakeClock()
    m = WindowedMetrics(clock=clk)
    m.record_flush("size", latencies=[0.001, 0.002], queue_ages=[0.0, 0.001],
                   exec_s=0.002, cache_hit=False, retraces=1, batched=True)
    m.record_rejected(2)
    m2 = WindowedMetrics(clock=clk)
    m2.load_state(m.state_dict())
    a, b = m.snapshot()["cumulative"], m2.snapshot()["cumulative"]
    for k in ("requests", "flushes", "batched_requests", "stream_deltas",
              "rejected", "flush_reasons", "max_queue_age_s"):
        assert a[k] == b[k], k


# ------------------------------------------------- the interleaving property
_SPEC = ColoringSpec(strategy="dataflow")
_STREAM_SPEC = ColoringSpec(strategy="recolor", concurrency=16)
_V = 24


def _graph_from(seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, _V, size=(3 * _V, 2))
    return Graph.from_edges(_V, e[e[:, 0] != e[:, 1]])


def _delta_from(seed, graph):
    rng = np.random.default_rng(1000 + seed)
    ins = np.stack([rng.integers(0, _V, 6), rng.integers(0, _V, 6)], 1)
    base = graph.undirected_edges()
    dels = base[rng.integers(0, base.shape[0], 4)] if base.shape[0] else None
    return ins, dels


def _check_tape(tape):
    """The core serving property, for one op tape: whatever the arrival
    interleaving, micro-batch grouping, DRR admission order and pump
    timing, (a) every coloring request returns exactly the front-door
    plan result and (b) each tenant's stream ends bit-identical to
    applying its deltas serially through a private DynamicColoring."""
    ops, max_batch, quantum = tape
    clk = FakeClock()
    svc = AsyncColoringService(default_spec=_SPEC, max_batch=max_batch,
                               tenant_quantum=quantum, max_delay_s=10.0,
                               clock=clk)
    base = {t: _graph_from({"A": 7, "B": 8}[t]) for t in ("A", "B")}
    for t in ("A", "B"):
        svc.open_stream(t, base[t], _STREAM_SPEC)
    ref = {t: DynamicColoring(base[t], _STREAM_SPEC) for t in ("A", "B")}

    handles = []
    for tenant, kind, pseed, do_pump in ops:
        if kind == "color":
            g = _graph_from(100 + pseed)
            handles.append((svc.submit(g, tenant=tenant), g))
        else:
            # deltas derive from the tenant's CURRENT reference graph —
            # the service applies them in the same per-tenant order, so
            # both sides see identical payloads
            ins, dels = _delta_from(pseed, ref[tenant].graph)
            svc.submit_delta(tenant, inserts=ins, deletes=dels)
            ref[tenant].apply_batch(inserts=ins, deletes=dels)
        if do_pump:
            clk.tick(0.001)
            svc.pump()
    svc.drain()

    for h, g in handles:
        r = h.result()
        assert validate_coloring(g, r.report.colors)
        np.testing.assert_array_equal(color(g, _SPEC).colors,
                                      r.report.colors)
    for t in ("A", "B"):
        dyn = svc.stream(t)
        assert validate_coloring(dyn.graph, dyn.colors)
        np.testing.assert_array_equal(
            dyn.graph.undirected_edges(), ref[t].graph.undirected_edges())
        np.testing.assert_array_equal(dyn.colors, ref[t].colors)


def _random_tape(rng):
    n = int(rng.integers(2, 11))
    ops = [(("A", "B")[rng.integers(2)],
            ("color", "delta")[rng.integers(2)],
            int(rng.integers(0, 6)),
            bool(rng.integers(2)))  # pump after this op?
           for _ in range(n)]
    return ops, int(rng.integers(1, 4)), int(rng.integers(1, 3))


@pytest.mark.parametrize("seed", range(4))
def test_async_interleaving_equals_serial_seeded(seed):
    """Deterministic tier-1 coverage of the interleaving property: four
    fixed random tapes (hypothesis widens the search below when
    installed)."""
    _check_tape(_random_tape(np.random.default_rng(seed)))


try:  # hypothesis widens the tape search where dev deps are installed;
    # absence skips ONLY the property test (the seeded tapes above always
    # run), matching tests/test_property.py's convention
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @st.composite
    def interleavings(draw):
        """A multi-tenant op tape: per-op (tenant, kind, payload seed),
        plus a pump after any op, plus scheduler knobs."""
        n = draw(st.integers(2, 10))
        ops = [(draw(st.sampled_from(["A", "B"])),
                draw(st.sampled_from(["color", "delta"])),
                draw(st.integers(0, 5)),
                draw(st.booleans()))  # pump after this op?
               for _ in range(n)]
        return (ops,
                draw(st.integers(1, 3)),   # max_batch
                draw(st.integers(1, 2)))   # tenant_quantum

    @settings(max_examples=12, deadline=None)
    @given(interleavings())
    def test_async_interleaving_equals_serial_property(tape):
        _check_tape(tape)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_async_interleaving_equals_serial_property():
        pass
