"""Roofline report builder: reads the dry-run JSON records and renders the
EXPERIMENTS.md §Roofline table (per arch x shape x mesh: three terms,
dominant bottleneck, MODEL_FLOPS ratio, roofline fraction).

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
        [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "mistral-nemo-12b", "qwen3-4b", "starcoder2-3b", "gemma2-2b",
    "mamba2-130m", "whisper-medium", "recurrentgemma-2b",
    "llama-3.2-vision-11b", "grok-1-314b", "deepseek-v2-lite-16b",
    "rmat-coloring",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "coloring"]


def load(dir_: str, tag: str = "baseline"):
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, f"*__{tag}.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    recs.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99,
                             SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 99,
                             len(r["mesh"])))
    return recs


def one_liner(r):
    rf = r.get("roofline", {})
    mesh = "x".join(str(d) for d in r["mesh"])
    dom = rf.get("dominant", "?").replace("_s", "")
    frac = r.get("roofline_fraction", 0.0)
    ratio = r.get("useful_flops_ratio", 0.0)
    return (f"{r['arch']:22s} {r['shape']:12s} {mesh:8s} "
            f"C={rf.get('compute_s', 0):9.3e} M={rf.get('memory_s', 0):9.3e} "
            f"X={rf.get('collective_s', 0):9.3e} dom={dom:10s} "
            f"useful={ratio:5.2f} frac={frac:6.3f}")


def markdown_table(recs):
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | useful FLOPs | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        rf = r.get("roofline", {})
        mesh = "x".join(str(d) for d in r["mesh"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {rf.get('compute_s', 0):.3e} | {rf.get('memory_s', 0):.3e} "
            f"| {rf.get('collective_s', 0):.3e} "
            f"| {rf.get('dominant', '?').replace('_s', '')} "
            f"| {r.get('useful_flops_ratio', 0):.2f} "
            f"| {r.get('roofline_fraction', 0):.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir, args.tag)
    if args.markdown:
        print(markdown_table(recs))
        return
    for r in recs:
        print(one_liner(r))
    print(f"\n{len(recs)} cells")


if __name__ == "__main__":
    main()
