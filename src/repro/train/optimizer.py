"""AdamW with fully-sharded optimizer state.

Moments inherit the *parameter* sharding (ZeRO: every state shard lives with
its param shard) and may be stored in bf16 (``moment_dtype``) — that is what
fits grok-1-314B in 16 GB/chip (DESIGN.md §6). Schedule: linear warmup +
cosine decay. All update math in fp32 regardless of storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"   # "bfloat16" halves optimizer HBM


def _is_leaf(x):
    return hasattr(x, "shape") and hasattr(x, "dtype")


def init_opt_state(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(param_shapes, cfg: AdamWConfig):
    """ShapeDtypeStruct tree (dry-run lowering, no allocation)."""
    mdt = jnp.dtype(cfg.moment_dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, mdt)
    return {
        "m": jax.tree.map(sds, param_shapes, is_leaf=_is_leaf),
        "v": jax.tree.map(sds, param_shapes, is_leaf=_is_leaf),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, params, state, *, skip=None):
    """One AdamW step. ``skip`` (bool scalar) freezes params/state (NaN-step
    rejection, DESIGN.md §6 fault tolerance)."""
    step = state["step"]
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.asarray(1.0)
    if skip is None:
        skip = jnp.asarray(False)
    skip = jnp.logical_or(skip, ~jnp.isfinite(gnorm))
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, p, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * u
        # jnp.where (not arithmetic blend): 0 * NaN would poison the params
        p_out = jnp.where(skip, p.astype(jnp.float32), p_new).astype(p.dtype)
        m_out = jnp.where(skip, m.astype(jnp.float32), m32).astype(mdt)
        v_out = jnp.where(skip, v.astype(jnp.float32), v32).astype(mdt)
        return p_out, m_out, v_out

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, p, m, v) for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(tdef, [o[1] for o in out]),
        "v": jax.tree.unflatten(tdef, [o[2] for o in out]),
        "step": step + jnp.where(skip, 0, 1).astype(jnp.int32),
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm,
                                   "skipped": skip.astype(jnp.int32)}
