# Launchers: mesh construction, multi-pod dry-run, training and serving
# drivers. dryrun.py must be executed as __main__ (it sets XLA_FLAGS before
# importing jax); the other modules are importable.
