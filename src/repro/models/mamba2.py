"""Mamba-2 (SSD — state-space duality) blocks, chunk-parallel.

The SSD algorithm splits the sequence into chunks: intra-chunk terms are
dense matmuls (MXU-friendly quadratic-in-chunk work) and inter-chunk terms
are a short scan over chunk states — O(T·chunk) total, the TPU-native way to
run the recurrence. Decode keeps the O(1) recurrent state [H, P, N] plus a
(conv_width-1)-deep conv tail, which is what makes the ``long_500k`` cell
feasible (no KV cache at all).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig, SSMConfig


def _dims(cfg: ModelConfig, s: SSMConfig):
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def mamba2_init(b, cfg: ModelConfig, s: SSMConfig):
    d = cfg.d_model
    d_inner, n_heads, conv_dim = _dims(cfg, s)
    b.dense("in_proj", (d, 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads),
            ("embed", "rnn"))
    b.dense("conv_w", (s.conv_width, conv_dim), (None, "rnn"), scale=s.conv_width ** -0.5)
    b.zeros("conv_b", (conv_dim,), ("rnn",))
    b.zeros("A_log", (n_heads,), (None,))        # A = -exp(A_log)
    b.zeros("dt_bias", (n_heads,), (None,))
    b.zeros("D", (n_heads,), (None,))
    b.zeros("norm_w", (d_inner,), ("rnn",))
    b.dense("out_proj", (d_inner, d), ("rnn", "embed"))
    return b


def _split_proj(z_x_bc_dt, cfg, s):
    d_inner, n_heads, _ = _dims(cfg, s)
    gn = s.n_groups * s.d_state
    z = z_x_bc_dt[..., :d_inner]
    xbc = z_x_bc_dt[..., d_inner:d_inner + d_inner + 2 * gn]
    dt = z_x_bc_dt[..., -n_heads:]
    return z, xbc, dt


def _causal_conv(xbc, w, bias):
    """Depthwise causal conv via shift-adds (width is tiny)."""
    kw = w.shape[0]
    out = xbc * w[kw - 1]
    for i in range(1, kw):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[kw - 1 - i]
    return jax.nn.silu(out + bias)


def _segsum(x):
    """[..., L] -> [..., L, L] lower-triangular segment sums (log-space)."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(xh, dtA, b_mat, c_mat, chunk: int, init_state=None):
    """Chunked SSD. xh [B,T,H,P] (already dt-scaled), dtA [B,T,H] (log decay),
    b_mat/c_mat [B,T,N] (single group). Returns (y [B,T,H,P], final_state
    [B,H,P,N])."""
    bsz, t, h, p = xh.shape
    n = b_mat.shape[-1]
    q = min(chunk, t)
    tp = -(-t // q) * q
    pad = tp - t
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtA = jnp.pad(dtA, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    nc = tp // q
    xc = xh.reshape(bsz, nc, q, h, p)
    ac = dtA.reshape(bsz, nc, q, h).transpose(0, 3, 1, 2)       # [B,H,C,L]
    bc = b_mat.reshape(bsz, nc, q, n)
    cc = c_mat.reshape(bsz, nc, q, n)

    a_cum = jnp.cumsum(ac, axis=-1)                              # [B,H,C,L]
    # 1) intra-chunk (diagonal): L = exp(segsum(A))
    l_mat = jnp.exp(_segsum(ac))                                 # [B,H,C,L,L]
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", cc, bc, l_mat, xc,
                        preferred_element_type=jnp.float32)
    # 2) per-chunk end states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)              # [B,H,C,L]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bc, decay_states, xc,
                        preferred_element_type=jnp.float32)
    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(a_cum[..., -1])                        # [B,H,C]

    def step(s_prev, inp):
        st, dec = inp                                            # [B,H,P,N],[B,H]
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, prev_states = lax.scan(
        step,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # [B,C,H,P,N]
    # 4) state -> output contribution
    state_decay = jnp.exp(a_cum)                                 # [B,H,C,L]
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, prev_states, state_decay,
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(bsz, tp, h, p)[:, :t]
    return y, final


def mamba2_forward(p, x, cfg: ModelConfig, s: SSMConfig):
    """Full-sequence SSD block. x [B,T,d] -> (y, final_state, conv_tail)."""
    dt_ = x.dtype
    d_inner, n_heads, conv_dim = _dims(cfg, s)
    proj = x @ p["in_proj"].astype(dt_)
    z, xbc_raw, dt_raw = _split_proj(proj, cfg, s)
    # last (W-1) pre-conv inputs: the decode-time conv window tail
    w = s.conv_width
    conv_tail = jnp.pad(xbc_raw, ((0, 0), (w - 1, 0), (0, 0)))[:, -(w - 1):]
    xbc = _causal_conv(xbc_raw, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    gn = s.n_groups * s.d_state
    xs = xbc[..., :d_inner]
    b_mat = xbc[..., d_inner:d_inner + gn]
    c_mat = xbc[..., d_inner + gn:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [H]
    dta = dt * a                                                 # [B,T,H]
    xh = xs.reshape(*xs.shape[:2], n_heads, s.headdim)
    xh_dt = (xh.astype(jnp.float32) * dt[..., None])
    y, final = ssd_scan(xh_dt, dta, b_mat.astype(jnp.float32),
                        c_mat.astype(jnp.float32), s.chunk)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(*xs.shape[:2], d_inner).astype(dt_)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"].astype(dt_), final, conv_tail


def mamba2_decode(p, x, state, conv_tail, cfg: ModelConfig, s: SSMConfig):
    """One-token recurrent step. x [B,1,d]; state [B,H,P,N]; conv_tail
    [B,conv_width-1,conv_dim]. Returns (y [B,1,d], state', conv_tail')."""
    dt_ = x.dtype
    d_inner, n_heads, conv_dim = _dims(cfg, s)
    proj = x @ p["in_proj"].astype(dt_)
    z, xbc, dt_raw = _split_proj(proj, cfg, s)                   # [B,1,*]
    # conv over (tail ++ current)
    window = jnp.concatenate([conv_tail, xbc], axis=1)           # [B,W,convdim]
    w = p["conv_w"].astype(dt_)
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + p["conv_b"].astype(dt_)
    xbc1 = jax.nn.silu(conv_out)[:, None]
    new_tail = window[:, 1:]
    gn = s.n_groups * s.d_state
    xs = xbc1[..., :d_inner]
    b_mat = xbc1[..., d_inner:d_inner + gn].astype(jnp.float32)[:, 0]   # [B,N]
    c_mat = xbc1[..., d_inner + gn:].astype(jnp.float32)[:, 0]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))[:, 0]  # [B,H]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                      # [B,H]
    xh = xs.reshape(-1, n_heads, s.headdim).astype(jnp.float32)  # [B,H,P]
    state_new = (state * decay[..., None, None]
                 + jnp.einsum("bhp,bn,bh->bhpn", xh, b_mat, dt))
    y = jnp.einsum("bhpn,bn->bhp", state_new, c_mat)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, 1, d_inner).astype(dt_)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"].astype(dt_), state_new, new_tail
