"""Parameter initialization with logical-axis annotations.

Params are plain nested dicts of jnp arrays. Alongside every params tree we
build a *parallel tree of logical-axis tuples* (one string/None per dim)
which ``parallel/sharding.py`` maps to mesh ``PartitionSpec``s.

Two modes share one code path:
  * concrete — ``ParamBuilder(key)`` samples real arrays (smoke/examples);
  * abstract — ``ParamBuilder(None)`` records ``jax.ShapeDtypeStruct``s, so
    the 314B-param grok config never allocates a byte during dry-run.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


class ParamBuilder:
    """Records (array-or-shape, logical axes) pairs for a params dict."""

    def __init__(self, key: Optional[jax.Array], param_dtype=jnp.float32):
        self._key = key
        self.abstract = key is None
        self.dtype = param_dtype
        self.params: dict = {}
        self.axes: dict = {}

    def _next(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _put(self, name, shape, axes, sampler):
        assert len(shape) == len(axes), (name, shape, axes)
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        else:
            self.params[name] = sampler()
        self.axes[name] = tuple(axes)
        return self

    def dense(self, name: str, shape, axes, scale: float | None = None):
        std = scale if scale is not None else shape[0] ** -0.5

        def sample():
            return jax.random.normal(self._next(), tuple(shape), self.dtype) \
                * jnp.asarray(std, self.dtype)

        return self._put(name, shape, axes, sample)

    def zeros(self, name: str, shape, axes):
        return self._put(name, shape, axes, lambda: jnp.zeros(tuple(shape), self.dtype))

    def ones(self, name: str, shape, axes):
        return self._put(name, shape, axes, lambda: jnp.ones(tuple(shape), self.dtype))

    def child(self, name: str):
        sub = ParamBuilder(None if self.abstract else self._next(), self.dtype)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub

    def stacked_child(self, name: str, n: int, init_one):
        """``init_one(builder)`` fills a per-layer builder; result gains a
        leading "layers" dim (scan axis, never sharded)."""
        proto = ParamBuilder(None, self.dtype)
        init_one(proto)
        if self.abstract:
            params = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
                proto.params,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
        else:
            def one(k):
                b = ParamBuilder(k, self.dtype)
                init_one(b)
                return b.params
            params = jax.vmap(one)(jax.random.split(self._next(), n))
        axes = jax.tree.map(
            lambda a: ("layers",) + tuple(a), proto.axes, is_leaf=_is_axes_leaf)
        self.params[name] = params
        self.axes[name] = axes
        return self

    def build(self):
        return self.params, self.axes


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(e is None or isinstance(e, str) for e in x)


def is_axes_leaf(x):
    return _is_axes_leaf(x)
