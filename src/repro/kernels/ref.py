"""Pure-jnp oracles for the Pallas kernels (validation targets)."""
from __future__ import annotations

import jax.numpy as jnp


def firstfit_ref(nbr_colors: jnp.ndarray, num_colors_bound: int) -> jnp.ndarray:
    """Oracle mex per row: smallest positive color absent from the row.

    nbr_colors: [V, D] int32 (0 = absent/uncolored). Dense one-hot presence
    over [0, C) — O(V*C) memory, fine at test scale.
    """
    v, d = nbr_colors.shape
    c = num_colors_bound
    present = (nbr_colors[:, :, None] == jnp.arange(c)[None, None, :]).any(axis=1)
    present = present.at[:, 0].set(True)  # color 0 always forbidden
    cand = jnp.where(~present, jnp.arange(c)[None, :], jnp.iinfo(jnp.int32).max)
    return cand.min(axis=1).astype(jnp.int32)


def conflict_mask_ref(colors_src, colors_dst, src, dst) -> jnp.ndarray:
    """Oracle per-edge conflict mask (Alg. 2 line 13)."""
    conf = (colors_src == colors_dst) & (colors_src > 0) & (src > dst)
    return conf.astype(jnp.int32)
