"""Model zoo: one composable stack, six families, ten assigned architectures.

Public API (family-dispatched):
    init_params(cfg, key)            -> (params, axes); key=None => abstract
    forward(cfg, params, batch)      -> (logits, aux, caches|None)
    loss_fn(cfg, params, batch)      -> (loss, metrics)
    cache_spec(cfg, batch, max_len)  -> (abstract cache tree, axes tree)
    init_cache(cfg, batch, max_len)  -> zeroed cache tree
    decode_step(cfg, params, caches, tokens) -> (logits, caches')
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import (ModelConfig, ShapeConfig, SHAPES, MoEConfig, MLAConfig,
                     SSMConfig, RGLRUConfig, EncDecConfig, VLMConfig)
from . import transformer, whisper, counting


def init_params(cfg: ModelConfig, key):
    if cfg.family == "encdec":
        return whisper.init_encdec(cfg, key)
    return transformer.init_lm(cfg, key)


def loss_fn(cfg: ModelConfig, params, batch):
    if cfg.family == "encdec":
        logits, aux, _ = whisper.forward(cfg, params, batch["tokens"], batch["frames"])
        nll = transformer.chunked_xent(logits, batch["labels"])
        return nll, {"nll": nll, "aux": jnp.zeros((), jnp.float32)}
    return transformer.loss_fn(cfg, params, batch)


def forward(cfg: ModelConfig, params, batch, caches=None):
    if cfg.family == "encdec":
        return whisper.forward(cfg, params, batch["tokens"], batch["frames"],
                               caches=caches)
    return transformer.forward(cfg, params, batch["tokens"],
                               image_embeds=batch.get("image_embeds"),
                               caches=caches)


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family == "encdec":
        return whisper.cache_spec(cfg, batch, max_len)
    return transformer.cache_spec(cfg, batch, max_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    shapes, _ = cache_spec(cfg, batch, max_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def decode_step(cfg: ModelConfig, params, caches, tokens):
    if cfg.family == "encdec":
        return whisper.decode_step(cfg, params, caches, tokens)
    return transformer.decode_step(cfg, params, caches, tokens)


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "MoEConfig", "MLAConfig",
    "SSMConfig", "RGLRUConfig", "EncDecConfig", "VLMConfig",
    "init_params", "loss_fn", "forward", "cache_spec", "init_cache",
    "decode_step", "counting",
]
