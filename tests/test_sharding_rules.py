"""Logical-axis sharding rule unit tests (no multi-device needed)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (DEFAULT_RULES, Rules, logical_to_spec,
                                     spec_for_array, rules_for_mesh)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_basic_mapping(mesh):
    rules = rules_for_mesh(mesh)
    spec = logical_to_spec(("embed", "mlp"), rules)
    assert spec == P("data", "model")


def test_pod_pruned_on_single_pod(mesh):
    rules = rules_for_mesh(mesh)
    assert rules.resolve("batch") == ("data",) or rules.resolve("batch") == "data"


def test_dedup_repeated_axis():
    rules = Rules({"a": "model", "b": "model"})
    spec = logical_to_spec(("a", "b"), rules)
    assert spec == P("model", None)  # later dim loses the contested axis


def test_divisibility_drop():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        devices = type("D", (), {"shape": (4, 16)})()

    rules = rules_for_mesh(FakeMesh, DEFAULT_RULES)
    # kv_heads = 8 does not divide model=16 -> replicated
    spec = spec_for_array((2, 128, 8, 64), ("batch", None, "kv_heads", None),
                          rules, FakeMesh)
    assert spec[2] is None
    # heads = 32 divides -> sharded
    spec2 = spec_for_array((2, 128, 32, 64), ("batch", None, "heads", None),
                           rules, FakeMesh)
    assert spec2[2] == "model"


def test_override():
    r = DEFAULT_RULES.override(experts=None)
    assert r.resolve("experts") is None
    assert r.resolve("heads") == "model"
