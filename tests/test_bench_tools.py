"""Tools-level tests for the benchmark harness (benchmarks/run.py):
the per-family atomic JSON flush — a crashing family must never lose the
rows already produced by completed families — and the family registry's
CLI surface staying in sync."""
import importlib.util
import json
import os
import sys

import pytest


def _load_run():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "run.py")
    spec = importlib.util.spec_from_file_location("bench_run", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture()
def bench():
    mod = _load_run()
    yield mod
    sys.modules.pop("bench_run", None)


class _Args:
    scale = None
    ell = False
    json = None


def test_json_flushes_per_family(bench, tmp_path, monkeypatch):
    """One crashing family loses only its own rows: the artifact on disk
    holds every completed family's rows, written atomically."""
    out = tmp_path / "bench.json"

    def fam_ok(args, scale):
        bench._row("ok/row", 1.0, "d=1", extra=7)

    def fam_boom(args, scale):
        bench._row("boom/partial", 2.0, "d=2")
        raise RuntimeError("family crashed mid-run")

    monkeypatch.setattr(bench, "FAMILIES", {
        "fam_ok": (fam_ok, 1), "fam_boom": (fam_boom, 1)})
    with pytest.raises(RuntimeError, match="crashed"):
        bench.run_families(["fam_ok", "fam_boom"], _Args(),
                           json_path=str(out))
    payload = json.loads(out.read_text())
    assert payload["families"] == ["fam_ok"]  # completed families only
    names = [r["name"] for r in payload["rows"]]
    assert "ok/row" in names
    assert payload["rows"][0]["extra"] == 7
    assert not os.path.exists(str(out) + ".tmp")  # rename, not partial write


def test_json_flush_is_atomic_rewrite(bench, tmp_path):
    out = tmp_path / "bench.json"

    def fam(n):
        def run(args, scale):
            bench._row(f"f{n}/row", float(n), f"d={n}")
        return run

    bench.FAMILIES = {"a": (fam(1), 1), "b": (fam(2), 1)}
    bench.run_families(["a", "b"], _Args(), json_path=str(out))
    payload = json.loads(out.read_text())
    assert payload["families"] == ["a", "b"]
    assert len(payload["rows"]) == 2
    assert payload["schema"] == 1


def test_stream_compare_registered(bench):
    assert "stream_compare" in bench.FAMILIES
    assert bench.FAMILIES["stream_compare"][1] == 10
    # the module docstring table and the registry can't drift silently
    for fam in bench.FAMILIES:
        assert fam in bench.__doc__


# ------------------------------------------------- bench_gate / bench_trend
def _load_tool(name):
    path = os.path.join(os.path.dirname(__file__), "..", "tools",
                        f"{name}.py")
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _write_bench(path, rows):
    payload = {"schema": 1, "families": ["fam"], "scale_override": None,
               "backend": "cpu", "rows": [
                   dict(name=n, us_per_call=u, derived="") for n, u in rows]}
    path.write_text(json.dumps(payload))


def test_bench_gate_machine_speed_cancels(tmp_path):
    """A uniform 3x slowdown (different machine) must NOT trip the gate."""
    gate = _load_tool("bench_gate")
    base, cur = tmp_path / "base.json", tmp_path / "cur.json"
    rows = [("a/x", 100.0), ("a/y", 200.0), ("b/z", 400.0)]
    _write_bench(base, rows)
    _write_bench(cur, [(n, 3.0 * u) for n, u in rows])
    rc = gate.main([f"{base}:{cur}", "--tolerance", "0.25"])
    assert rc == 0


def test_bench_gate_catches_relative_regression(tmp_path):
    """One family regressing 2x relative to another trips the gate even
    under an overall machine-speed shift."""
    gate = _load_tool("bench_gate")
    b1, c1 = tmp_path / "b1.json", tmp_path / "c1.json"
    b2, c2 = tmp_path / "b2.json", tmp_path / "c2.json"
    _write_bench(b1, [("f1/a", 100.0), ("f1/b", 100.0), ("f1/c", 100.0)])
    _write_bench(c1, [("f1/a", 150.0), ("f1/b", 150.0), ("f1/c", 150.0)])
    _write_bench(b2, [("f2/a", 100.0), ("f2/b", 100.0)])
    _write_bench(c2, [("f2/a", 450.0), ("f2/b", 450.0)])
    rc = gate.main([f"{b1}:{c1}", f"{b2}:{c2}", "--tolerance", "0.25"])
    assert rc == 1


def test_bench_gate_refuses_disjoint_rows(tmp_path):
    gate = _load_tool("bench_gate")
    base, cur = tmp_path / "base.json", tmp_path / "cur.json"
    _write_bench(base, [("old/x", 10.0)])
    _write_bench(cur, [("new/x", 10.0)])
    with pytest.raises(SystemExit, match="no common rows"):
        gate.main([f"{base}:{cur}"])


def test_bench_trend_schemas(tmp_path):
    """extract_rows handles both committed-baseline schemas: run.py rows
    and the roofline_round record."""
    trend = _load_tool("bench_trend")
    rows = trend.extract_rows(
        {"rows": [{"name": "a", "us_per_call": 5.0},
                  {"name": "zero", "us_per_call": 0.0}]})
    assert rows == {"a": 5.0}
    rr = trend.extract_rows(
        {"kind": "roofline_round",
         "rounds": [{"three_pass_us": 30.0, "fused_us": 10.0},
                    {"three_pass_us": 25.0, "fused_us": 12.0}]})
    assert rr == {"roofline_round/three_pass": 25.0,
                  "roofline_round/fused": 10.0}
    assert trend.extract_rows({"unknown": True}) == {}
    assert trend.geomean([10.0, 1000.0]) == pytest.approx(100.0)


# --------------------------------------------------- roofline round mode
def test_roofline_round_mode_small():
    """The measured coloring-round mode (ISSUE 6): fused and 3-pass paths
    bit-identical each round, and the analytic byte accounting shows the
    fused round moving >= 2x fewer bytes AND >= 2x fewer kernel slab
    reads at degree = block_d."""
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "roofline.py")
    spec = importlib.util.spec_from_file_location("bench_roofline", path)
    roofline = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(roofline)
    rep = roofline.round_report(scale=8, degree=128, max_rounds=2)
    assert rep["parity"] is True
    assert rep["bytes"]["bytes_ratio"] >= 2.0
    assert rep["bytes"]["kernel_slab_read_ratio"] >= 2.0
    assert rep["rounds"] and rep["rounds"][0]["conflicts"] > 0
    assert rep["bandwidth"]["peak_gbps"] > 0


def test_committed_roofline_artifact_meets_acceptance():
    """The committed BENCH_roofline_round.json must carry the acceptance
    numbers: parity + >= 2x fewer slab reads for the fused round."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_roofline_round.json")
    with open(path) as f:
        rep = json.load(f)
    assert rep["parity"] is True
    assert rep["bytes"]["kernel_slab_read_ratio"] >= 2.0
    assert rep["bytes"]["bytes_ratio"] >= 2.0
