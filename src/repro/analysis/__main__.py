"""``python -m repro.analysis`` — sweep the full registry against the
committed baseline.

Runs every strategy x engine x model combination through the plan-level
passes (races, envelope leaks, budgets) plus the source-level passes
(retrace AST lint, dead-export scan), dedupes by fingerprint, and compares
against ``repro/analysis/baseline.json``. ``--distributed`` additionally
sweeps the host strategy across wire x partition scheme x engine and runs
the SPMD verifier (collective safety, wire-cost model, halo exactness) on
every traced mesh program.

Exit codes are stable (tools/lint_plans.py and CI key off them):

* exit 0 — every gating finding is allowlisted and no baseline entry is
  stale;
* exit 1 — new violations (fix the code or extend the baseline with a
  reason string), possibly alongside stale entries;
* exit 2 — baseline drift only: no new violations, but stale entries
  match nothing and must be removed (deleted, not ignored).

``--json PATH`` writes a machine-readable report object::

    {"findings": [{code, site, severity, message, context}, ...],
     "wire_cost": [<closed-form cost table per distributed cell>, ...],
     "summary": {errors, warnings, infos, new, stale}}

(``wire_cost`` is populated by ``--distributed``; the ``dist_scale``
benchmark asserts measured bytes-on-wire against the same tables.)

``--write-baseline`` regenerates the entry list from the current run,
preserving reason strings for fingerprints that already have one and
stamping ``TODO: justify`` on new ones — the file is meant to be
hand-annotated before committing, and the loader rejects empty reasons.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import (AnalysisConfig, SWEEP_ENGINES, SWEEP_MODELS, SWEEP_SCHEMES,
               SWEEP_STRATEGIES, SWEEP_WIRES, dedupe, lint_tree,
               load_baseline, save_baseline, split_by_severity,
               sweep_distributed, sweep_registry, compare)


def _csv(text):
    return tuple(s.strip() for s in text.split(",") if s.strip())


def _wire_cost_tables(wires, schemes, engines):
    """One closed-form cost table per distributed sweep cell (the --json
    ``wire_cost`` section)."""
    from ..core.api import ColoringSpec, PlanShape
    from .wirecost import wire_cost_table

    statics = PlanShape(num_vertices=48, padded_edges=512, max_degree=8)
    tables = []
    for wire in wires:
        for scheme in schemes:
            spec = ColoringSpec(strategy="distributed", engine=engines[0],
                                wire=wire, partition=scheme)
            t = wire_cost_table(spec, statics)
            if t is not None:
                t["cell"] = f"wire={wire}/{scheme}"
                tables.append(t)
    return tables


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis sweep over the coloring registry")
    ap.add_argument("--strategies", type=_csv, default=SWEEP_STRATEGIES,
                    help="comma list (default: all registered)")
    ap.add_argument("--engines", type=_csv, default=SWEEP_ENGINES)
    ap.add_argument("--models", type=_csv, default=SWEEP_MODELS)
    ap.add_argument("--distributed", action="store_true",
                    help="also sweep the distributed strategy across "
                         "wire x partition scheme x engine and run the "
                         "SPMD verifier on every traced mesh program")
    ap.add_argument("--wires", type=_csv, default=SWEEP_WIRES,
                    help="comma list for --distributed "
                         "(default: boundary,full,auto)")
    ap.add_argument("--schemes", type=_csv, default=SWEEP_SCHEMES,
                    help="comma list for --distributed (default: 1d,2d)")
    ap.add_argument("--no-source", action="store_true",
                    help="skip the source-level passes (AST lint, dead "
                         "exports); plan sweep only")
    ap.add_argument("--vmem-ceiling", type=int, default=None,
                    help="per-grid-step VMEM budget in bytes "
                         "(default 16 MiB)")
    ap.add_argument("--baseline", default=None,
                    help="allowlist path (default: the committed "
                         "repro/analysis/baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from this run "
                         "(hand-annotate reasons before committing)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write the machine-readable report object "
                         "(findings + wire-cost tables + summary)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print info-grade and allowlisted findings")
    args = ap.parse_args(argv)

    config = AnalysisConfig(vmem_ceiling_bytes=args.vmem_ceiling,
                            baseline_path=args.baseline)
    progress = lambda ctx: print(f"  analyzing {ctx}", file=sys.stderr)  # noqa: E731
    findings = sweep_registry(
        strategies=args.strategies, engines=args.engines, models=args.models,
        config=config, progress=progress)
    wire_cost = []
    if args.distributed:
        findings = dedupe(findings + sweep_distributed(
            wires=args.wires, schemes=args.schemes, engines=args.engines,
            config=config, progress=progress))
        wire_cost = _wire_cost_tables(args.wires, args.schemes, args.engines)
    if not args.no_source:
        findings = dedupe(findings + lint_tree())

    errors, warnings_, infos = split_by_severity(findings)
    print(f"{len(findings)} finding(s): {len(errors)} error, "
          f"{len(warnings_)} warning, {len(infos)} info")

    if args.write_baseline:
        old = {}
        try:
            old = load_baseline(args.baseline)
        except ValueError:
            pass  # regenerating a malformed baseline is the point
        entries = {f.fingerprint: old.get(f.fingerprint, "TODO: justify")
                   for f in errors + warnings_}
        save_baseline(entries, args.baseline)
        print(f"wrote {len(entries)} baseline entr(ies); annotate any "
              "'TODO: justify' reasons before committing")
        return 0

    baseline = load_baseline(args.baseline)
    new, allowed, stale = compare(findings, baseline)

    if args.json_path:
        report = {
            "findings": [{"code": x.code, "site": x.site,
                          "severity": x.severity, "message": x.message,
                          "context": x.context} for x in findings],
            "wire_cost": wire_cost,
            "summary": {"errors": len(errors), "warnings": len(warnings_),
                        "infos": len(infos), "new": len(new),
                        "stale": len(stale)},
        }
        with open(args.json_path, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2)

    if args.verbose:
        for f in infos:
            print(f.format())
        for f in allowed:
            print(f"allowed {f.format()}")
    for f in new:
        print(f"NEW     {f.format()}")
    for fp in stale:
        print(f"STALE   baseline entry {fp} matches nothing — remove it")
    if new:
        print(f"FAIL: {len(new)} new violation(s), {len(stale)} stale "
              "baseline entr(ies)")
        return 1
    if stale:
        print(f"DRIFT: {len(stale)} stale baseline entr(ies) — delete them")
        return 2
    print(f"clean: {len(allowed)} allowlisted, {len(infos)} info")
    return 0


if __name__ == "__main__":
    sys.exit(main())
