"""int8 gradient all-reduce compression (shard_map, stochastic rounding).

A distributed-optimization trick for bandwidth-bound DP syncs at 1000+ node
scale: quantize each gradient leaf to int8 with a per-leaf fp32 scale,
``psum`` the int32-accumulated payload, dequantize. Stochastic rounding
keeps the estimator unbiased. ~4x less collective traffic than fp32 psum
(the scale overhead is negligible).

Use via ``compressed_psum_tree`` inside a shard_map'd explicit-DP step, or
standalone (tests compare against exact psum).
"""
# pending: dist_scale wire-up — exports stay dormant until the distributed
# train step grows a compressed-sync knob (repro.analysis.deadcode exempts
# this module's unreferenced exports via this pragma)
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _quantize(x, key):
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    y = x32 / scale
    lo = jnp.floor(y)
    frac = y - lo
    rnd = jax.random.uniform(key, x.shape)
    q = (lo + (rnd < frac)).astype(jnp.int32)
    q = jnp.clip(q, -127, 127)
    return q.astype(jnp.int8), scale


def compressed_psum(x, axis_name, key):
    """Quantized psum of one tensor across ``axis_name``."""
    q, scale = _quantize(x, key)
    # int8 payload accumulates in int32; scales reduce with max (conservative
    # shared scale keeps dequantization linear)
    scale_max = lax.pmax(scale, axis_name)
    # requantize against the shared scale so the sum is exact in int32
    requant = jnp.clip(
        jnp.round(q.astype(jnp.float32) * (scale / scale_max)),
        -127, 127).astype(jnp.int32)
    total = lax.psum(requant, axis_name)
    return total.astype(jnp.float32) * scale_max


def compressed_psum_tree(tree, axis_name, key):
    leaves, tdef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [compressed_psum(x, axis_name, k) for x, k in zip(leaves, keys)]
    return jax.tree.unflatten(tdef, out)
