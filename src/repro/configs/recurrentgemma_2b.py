"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680,
RG-LRU + local attn in Griffin (rec,rec,attn) pattern, window 2048, lru
width 2560. [arXiv:2402.19427]"""
from ..models.config import ModelConfig, RGLRUConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid", num_layers=26, d_model=2560,
        n_heads=10, n_kv_heads=1, head_dim=256, d_ff=7680, vocab_size=256000,
        local_window=2048, tie_embeddings=True, emb_scale=True,
        rglru=RGLRUConfig(d_rnn=2560, conv_width=4))


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid", num_layers=5, d_model=128,
        n_heads=4, n_kv_heads=1, head_dim=32, d_ff=256, vocab_size=512,
        local_window=32, tie_embeddings=True, emb_scale=True,
        rglru=RGLRUConfig(d_rnn=128, conv_width=4))
