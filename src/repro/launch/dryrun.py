import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production meshes and extract the roofline terms.

MUST be run as a script/module (the XLA_FLAGS line above precedes every jax
import):  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
             --shape train_4k [--multi-pod] [--out results/dryrun]

Per cell this emits a JSON record with:
  * memory_analysis (per-device argument/output/temp/peak bytes),
  * cost_analysis FLOPs + bytes accessed (per-device SPMD program),
  * collective bytes by kind (post-SPMD HLO walk, while-loop trip counts
    folded in — launch/hlo_analysis.py),
  * the three roofline terms vs the TPU v5e-like hardware model and the
    MODEL_FLOPS/HLO_FLOPs usefulness ratio.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.jax_compat import set_mesh
from repro.models import counting
from repro.models.config import SHAPES
from repro import models
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as S
from repro.launch.hlo_analysis import analyze_hlo
from repro.parallel.sharding import (DEFAULT_RULES, activation_rules,
                                     rules_for_mesh)
from repro.train import AdamWConfig, make_train_step
from repro.train.train_step import TrainStepConfig
from repro.train.optimizer import abstract_opt_state

# ---- hardware model (TPU v5e-like; per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

# long_500k runs only for sub-quadratic archs (DESIGN.md §Arch-applicability)
LONG_OK = {"mamba2-130m", "recurrentgemma-2b"}

# per-arch gradient-accumulation defaults sized so train_4k activations fit
# the 16 GB/chip budget (EXPERIMENTS.md §Perf, memory audit)
MICROBATCH_DEFAULTS = {
    "mistral-nemo-12b": 2, "qwen3-4b": 1, "starcoder2-3b": 2, "gemma2-2b": 2,
    "mamba2-130m": 1, "whisper-medium": 1, "recurrentgemma-2b": 2,
    "llama-3.2-vision-11b": 8, "grok-1-314b": 16, "deepseek-v2-lite-16b": 16,
}


def cells(arch=None, shape=None):
    for a in ARCH_IDS + ["rmat-coloring"]:
        if arch and a != arch:
            continue
        if a == "rmat-coloring":
            if shape in (None, "coloring"):
                yield a, "coloring"
            continue
        for s in SHAPES:
            if shape and s != shape:
                continue
            if s == "long_500k" and a not in LONG_OK:
                continue
            yield a, s


def _opt_cfg(cfg):
    # bf16 moments for the giants so optimizer state fits 16 GB/chip
    big = counting.param_count(cfg) > 50e9
    return AdamWConfig(moment_dtype="bfloat16" if big else "float32")


def lower_cell(arch: str, shape_name: str, mesh, rules=DEFAULT_RULES,
               bf16_params: bool = False, microbatches: int = 1):
    """Build + lower one cell; returns (lowered, meta)."""
    if arch == "rmat-coloring":
        return lower_coloring(mesh)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    params_abs, params_axes = models.init_params(cfg, None)
    p_sh = S.tree_shardings(params_abs, params_axes, rules, mesh)

    if shape.kind == "train":
        opt_cfg = _opt_cfg(cfg)
        opt_abs = abstract_opt_state(params_abs, opt_cfg)
        o_sh = S.tree_shardings(
            opt_abs["m"], params_axes, rules, mesh)
        opt_sh = {"m": o_sh, "v": o_sh, "step": S.scalar_sharding(mesh)}
        batch_abs = S.batch_specs(cfg, shape)
        b_sh = S.tree_shardings(batch_abs, S.batch_axes(cfg), rules, mesh)
        step = make_train_step(cfg, opt_cfg,
                               TrainStepConfig(bf16_compute_params=bf16_params,
                                               microbatches=microbatches))

        def fn(params, opt_state, batch):
            with activation_rules(rules):
                return step(params, opt_state, batch)

        with set_mesh(mesh):
            lowered = jax.jit(
                fn,
                in_shardings=(p_sh, opt_sh, b_sh),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, batch_abs)
        return lowered, cfg, shape

    if shape.kind == "prefill":
        batch_abs = S.batch_specs(cfg, shape)
        b_sh = S.tree_shardings(batch_abs, S.batch_axes(cfg), rules, mesh)
        cache_abs, cache_axes = models.cache_spec(
            cfg, shape.global_batch, shape.seq_len)
        c_sh = S.tree_shardings(cache_abs, cache_axes, rules, mesh)

        def fn(params, batch, caches):
            with activation_rules(rules):
                logits, aux, caches = models.forward(cfg, params, batch,
                                                     caches=caches)
                # serving returns last-position logits only
                return logits[:, -1], caches

        with set_mesh(mesh):
            lowered = jax.jit(
                fn, in_shardings=(p_sh, b_sh, c_sh), donate_argnums=(2,),
            ).lower(params_abs, batch_abs, cache_abs)
        return lowered, cfg, shape

    # decode
    cache_abs, cache_axes, tok_abs = S.decode_specs(cfg, shape)
    c_sh = S.tree_shardings(cache_abs, cache_axes, rules, mesh)
    t_sh = S.tree_shardings(
        tok_abs, ("cache_batch",), rules, mesh)

    def fn(params, caches, tokens):
        with activation_rules(rules):
            return models.decode_step(cfg, params, caches, tokens)

    with set_mesh(mesh):
        lowered = jax.jit(
            fn, in_shardings=(p_sh, c_sh, t_sh), donate_argnums=(1,),
        ).lower(params_abs, cache_abs, tok_abs)
    return lowered, cfg, shape


def lower_coloring(mesh):
    """The paper's own workload on the production mesh (scale-24 RMAT)."""
    from repro.configs.rmat_coloring import get_config as get_col
    from repro.core.distance2 import MODELS
    from repro.core.distributed import build_distributed_coloring
    from repro.core.engine import get_backend
    ccfg = get_col()
    if get_backend(ccfg.engine).needs_ell:
        raise ValueError(
            f"dry-run lowers the coloring cell from shapes alone; the "
            f"{ccfg.engine!r} engine needs a real host graph for its ELL "
            "width — use engine='sort' or 'bitmap' here (ELL engines run "
            "via color_distributed)")
    if ccfg.model not in MODELS:
        raise ValueError(f"unknown coloring model {ccfg.model!r}")
    D = int(np.prod(mesh.devices.shape))
    v = 1 << ccfg.dryrun_scale
    e2 = 2 * ccfg.edge_factor * v
    if ccfg.model != "d1":
        # d2/pd2 color the squared constraint graph: |E(G2)| is bounded by
        # the wedge count ~ avg_degree x |directed edges| (distance2.py) —
        # the slab widens accordingly, everything else is shape-identical
        e2 *= 2 * ccfg.edge_factor
    vl = -(-v // D)
    el = int(e2 / D * 1.35)  # slab padding headroom for R-MAT skew
    fcv = fce = 0
    if ccfg.frontier != "off":
        # per-shard frontier slabs on the same static envelope: frontier
        # rounds + the compacted halo wire lower here too. Shapes-only
        # caveat: with no host graph there is no max-degree term, so on
        # skewed graphs the runtime edge slab can be wider than this
        # lowering's (the vertex slab and program structure are identical)
        from repro.core.frontier import frontier_capacities
        fcv, fce = frontier_capacities(vl, el,
                                       capacity=ccfg.frontier_capacity)
    # shapes-only halo slab: no host graph to classify boundary from, so
    # lower the worst case (every local vertex boundary, Bl = Vl); the
    # packed-entry width takes color_bound for the same reason (no provable
    # Delta+1 without a graph — matches the config's color_bound caveat)
    wire = "full" if ccfg.wire == "full" else "boundary"
    fn = build_distributed_coloring(mesh, vl, el, ccfg.local_concurrency,
                                    ccfg.max_rounds, engine=ccfg.engine,
                                    max_colors=ccfg.color_bound,
                                    frontier_cap_v=fcv, frontier_cap_e=fce,
                                    wire=wire, wire_colors=ccfg.color_bound)
    lsrc = jax.ShapeDtypeStruct((D, el), jnp.int32)
    ldst = jax.ShapeDtypeStruct((D, el), jnp.int32)
    bnd = jax.ShapeDtypeStruct((D, vl), jnp.int32)
    with set_mesh(mesh):
        lowered = fn.lower(lsrc, ldst, bnd)
    return lowered, ccfg, None


def analyse(lowered, cfg, shape, mesh, arch, shape_name, compile_s):
    compiled = lowered.compile()
    n_dev = int(np.prod(mesh.devices.shape))
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    st = analyze_hlo(hlo)

    # cost_analysis counts while bodies once (verified) -> use the HLO walk,
    # which folds trip counts; keep cost_analysis numbers for reference.
    flops_dev = st.dot_flops
    bytes_dev = st.boundary_bytes
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "devices": n_dev,
        "compile_seconds": compile_s,
        "per_device": {
            "flops": flops_dev,
            "bytes_accessed": bytes_dev,
            "collective_bytes": st.collective_bytes,
            "collective_by_kind": st.collective_bytes_by_kind,
            "collective_counts": st.collective_counts,
            "while_trip_counts": st.while_trip_counts,
            "cost_analysis_flops_once": float(cost.get("flops", 0.0)),
            "cost_analysis_bytes_once": float(cost.get("bytes accessed", 0.0)),
        },
        "memory_analysis": {},
    }
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        try:
            rec["memory_analysis"][attr] = int(getattr(mem, attr))
        except Exception:
            pass

    # roofline terms (per chip; chips divide out of the global form)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = st.collective_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    rec["roofline"] = {
        **terms,
        "dominant": dominant,
        "bound_s": max(terms.values()),
    }
    if shape is not None:
        mf = counting.model_flops(cfg, shape)
        rec["model_flops_total"] = mf
        rec["model_flops_per_device"] = mf / n_dev
        rec["useful_flops_ratio"] = (mf / n_dev) / flops_dev if flops_dev else 0.0
        # roofline fraction: ideal model-FLOPs time / achieved bound
        ideal = mf / n_dev / PEAK_FLOPS
        rec["roofline_fraction"] = ideal / max(terms.values()) if max(terms.values()) else 0.0
    return rec


def run_cell(arch, shape_name, multi_pod, out_dir, rules=None,
             tag="baseline", bf16_params=False, microbatches=1):
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for_mesh(mesh, rules or DEFAULT_RULES)
    t0 = time.time()
    lowered, cfg, shape = lower_cell(arch, shape_name, mesh, rules,
                                     bf16_params=bf16_params,
                                     microbatches=microbatches)
    t_lower = time.time() - t0
    t0 = time.time()
    rec = analyse(lowered, cfg, shape, mesh, arch, shape_name,
                  compile_s=None)
    rec["compile_seconds"] = time.time() - t0
    rec["lower_seconds"] = t_lower
    rec["tag"] = tag
    os.makedirs(out_dir, exist_ok=True)
    mp = "multipod" if multi_pod else "pod"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mp}__{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] {arch} x {shape_name} x {mp}: "
          f"compile={rec['compile_seconds']:.1f}s "
          f"flops/dev={rec['per_device']['flops']:.3e} "
          f"coll/dev={rec['per_device']['collective_bytes']:.3e}B "
          f"dominant={rec['roofline']['dominant']} "
          f"frac={rec.get('roofline_fraction', 0):.3f}")
    # memory_analysis headline: prove it fits
    ma = rec["memory_analysis"]
    print(f"         memory/device: args={ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
          f"temp={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
          f"out={ma.get('output_size_in_bytes', 0)/2**30:.2f}GiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--bf16-params", action="store_true",
                    help="mixed precision: bf16 compute params (H-A1)")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="gradient-accumulation microbatches for train cells "
                         "(0 = per-arch MICROBATCH_DEFAULTS)")
    args = ap.parse_args()

    failures = []
    for arch, shape_name in cells(args.arch, args.shape):
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        mb = args.microbatches or MICROBATCH_DEFAULTS.get(arch, 1)
        for mp in meshes:
            try:
                run_cell(arch, shape_name, mp, args.out, tag=args.tag,
                         bf16_params=args.bf16_params,
                         microbatches=mb)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape_name, mp, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("dry-run complete: all cells lowered + compiled.")


if __name__ == "__main__":
    main()
