"""Race classifier: every scatter/store in a traced coloring program,
classified against the paper's benign-speculation model.

The speculative algorithm (Çatalyürek et al. arXiv:1205.3809, Alg. 1-2) is
*deliberately* racy: concurrent first-fit writes may collide, and
correctness rests on every collision being (a) resolved by the later
conflict-detection pass, or (b) idempotent/commutative so the collision
cannot be observed at all. Rokos et al. (arXiv:1505.04086) document how an
"optimistic" coloring silently degrades the moment a race stops being
benign — so this pass finds every scatter op in the jaxpr and proves it
into one of the benign classes, or reports it:

=========  ========  =====================================================
code       severity  class
=========  ========  =====================================================
RACE101    info      commutative-idempotent reduction (scatter-min/max/...)
RACE102    info      static-index store (slice assignment; no data overlap)
RACE103    info      idempotent constant store (the bitmap scatter-or)
RACE104    info      single-site store (one update row)
RACE300    warning   speculative last-writer-wins store — benign ONLY via
                     the conflict-detected-later argument; allowlisted per
                     site with the argument spelled out
RACE301    warning   ``unique_indices=True`` on data-driven indices — UB if
                     the distinctness claim is ever violated
RACE201    error     float scatter-accumulate: accumulation-order
                     nondeterminism
RACE202    error     integer scatter-accumulate: non-idempotent overlap
=========  ========  =====================================================

The proof obligations the analyzer CAN discharge, it does (static-index,
constant-fill, single-row — a small abstract interpretation over the
jaxpr, :mod:`repro.analysis.jaxpr_walk`); what it cannot, it demands a
baseline entry for, with a human-written reason string.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .findings import Finding
from .jaxpr_walk import (is_constant_fill, is_static, site_of, static_vars,
                         walk_eqns)

_COMMUTATIVE = frozenset({"scatter-min", "scatter-max", "scatter-and",
                          "scatter-or", "scatter-xor"})
_ACCUMULATING = frozenset({"scatter-add", "scatter-mul", "scatter-sub"})


def _n_update_rows(indices_var) -> int:
    """Number of scattered index rows; <= 1 means the store cannot
    self-collide. Scatter indices have layout [..., index_depth]."""
    try:
        shape = indices_var.aval.shape
    except Exception:
        return 2  # unknown: assume it can collide
    if not shape:
        return 1
    n = 1
    for d in shape[:-1]:
        n *= int(d)
    return int(n)


def classify_scatters(closed_jaxpr, context: str = "") -> List[Finding]:
    """Classify every scatter in a ``ClosedJaxpr`` (recursing through
    while/cond/scan/pjit/pallas_call bodies) into the table above."""
    findings: List[Finding] = []
    static_cache: dict = {}

    def visit(eqn, enclosing):
        name = eqn.primitive.name
        if not name.startswith("scatter"):
            return
        site = site_of(eqn)
        operand, indices, updates = eqn.invars[0], eqn.invars[1], eqn.invars[2]
        try:
            dtype = np.dtype(updates.aval.dtype)
        except Exception:
            dtype = np.dtype(np.int32)

        if name in _COMMUTATIVE:
            findings.append(Finding(
                "RACE101", site,
                f"{name} ({dtype}): order-independent reduction",
                context))
            return
        if name in _ACCUMULATING:
            if np.issubdtype(dtype, np.inexact):
                findings.append(Finding(
                    "RACE201", site,
                    f"{name} on {dtype}: overlapping accumulation order is "
                    "nondeterministic — results vary run to run",
                    context))
            else:
                findings.append(Finding(
                    "RACE202", site,
                    f"{name} on {dtype}: overlapping accumulation is "
                    "non-idempotent — a speculative replay double-counts",
                    context))
            return

        # plain scatter (set): prove a benign class or demand an argument
        key = id(enclosing)
        if key not in static_cache:
            static_cache[key] = static_vars(enclosing)
        static = static_cache[key]
        if is_static(indices, static):
            findings.append(Finding(
                "RACE102", site,
                "store indices derive from constants/iota only "
                "(slice assignment): overlap is impossible", context))
            return
        if _n_update_rows(indices) <= 1:
            findings.append(Finding(
                "RACE104", site,
                "single update row: the store cannot self-collide",
                context))
            return
        if is_constant_fill(updates, enclosing):
            findings.append(Finding(
                "RACE103", site,
                f"idempotent constant store ({dtype}): colliding writes "
                "all write the same value (scatter-or idiom)", context))
            return
        if bool(eqn.params.get("unique_indices", False)):
            findings.append(Finding(
                "RACE301", site,
                "unique_indices=True asserted on data-driven indices "
                f"({dtype}): XLA behavior is undefined if duplicates ever "
                "appear — allowlist with the distinctness argument",
                context))
            return
        findings.append(Finding(
            "RACE300", site,
            f"overlapping data-driven store ({dtype}): last writer wins, "
            "nondeterministically — benign only if a conflict pass "
            "detects and repairs collisions (paper Alg. 2 phase 2); "
            "allowlist with that argument", context))

    walk_eqns(closed_jaxpr.jaxpr, visit)
    return findings
