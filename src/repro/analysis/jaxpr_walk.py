"""Shared jaxpr traversal + provenance analysis for the static analyzer.

Everything here is pure structure-walking over ``jax.make_jaxpr`` output —
no execution, no compilation. Three facilities:

* :func:`walk_eqns` — depth-first over every equation including the
  sub-jaxprs of ``while``/``cond``/``scan``/``pjit``/``pallas_call``
  (params holding Jaxpr, ClosedJaxpr, or tuples of either);
* :func:`static_vars` — per-jaxpr dataflow: the set of variables derivable
  from literals/constants alone (primitives are pure, so an equation whose
  inputs are all static produces static outputs; ``iota`` has no inputs and
  is static by construction). A variable fed by the jaxpr's *inputs* — real
  data, or a loop carrier inside a ``while`` body — is never static. This
  is what lets the race classifier tell a slice-assignment scatter from a
  data-driven one;
* :func:`site_of` — ``<package-relative file>:<function>`` provenance of an
  equation from its source info (line numbers dropped: fingerprints must
  survive unrelated edits).
"""
from __future__ import annotations

import os
from typing import Callable, Iterator, List, Set

import numpy as np

from jax._src import core as jax_core
from jax._src import source_info_util

Literal = jax_core.Literal


def _sub_jaxprs(eqn) -> Iterator:
    for v in eqn.params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for x in items:
            # ClosedJaxpr first: it forwards .eqns, but only the raw
            # Jaxpr carries .constvars for the provenance analysis
            if hasattr(x, "jaxpr") and hasattr(getattr(x, "jaxpr"),
                                               "eqns"):  # ClosedJaxpr
                yield x.jaxpr
            elif hasattr(x, "eqns"):                     # raw Jaxpr
                yield x


def walk_eqns(jaxpr, visit: Callable) -> None:
    """Depth-first visit of every eqn; ``visit(eqn, enclosing_jaxpr)``."""
    for eqn in jaxpr.eqns:
        visit(eqn, jaxpr)
        for sub in _sub_jaxprs(eqn):
            walk_eqns(sub, visit)


def static_vars(jaxpr) -> Set:
    """Variables of ``jaxpr`` (one level, not sub-jaxprs) that depend on
    literals/constvars only — see module docstring."""
    static = set(jaxpr.constvars)
    for eqn in jaxpr.eqns:
        if all(isinstance(v, Literal) or v in static for v in eqn.invars):
            static.update(eqn.outvars)
    return static


def is_static(var, static: Set) -> bool:
    return isinstance(var, Literal) or var in static


def producer_map(jaxpr) -> dict:
    """outvar -> producing eqn (one jaxpr level)."""
    prod = {}
    for eqn in jaxpr.eqns:
        for o in eqn.outvars:
            prod[o] = eqn
    return prod


_FILL_PRESERVING = frozenset({
    "broadcast_in_dim", "convert_element_type", "reshape", "copy",
    "squeeze", "expand_dims",
})


def is_constant_fill(var, jaxpr, _prod=None, _depth=0) -> bool:
    """True when ``var`` is provably a constant-filled array (every element
    equal): a literal, or a fill-preserving chain over one. The idempotence
    test for overlapping stores — colliding writes of the same constant
    commute."""
    if isinstance(var, Literal):
        val = np.asarray(var.val)
        return val.size <= 1 or bool((val == val.flat[0]).all())
    if _depth > 8:
        return False
    if _prod is None:
        _prod = producer_map(jaxpr)
    eqn = _prod.get(var)
    if eqn is None or eqn.primitive.name not in _FILL_PRESERVING:
        return False
    data_ins = [v for v in eqn.invars]
    return bool(data_ins) and all(
        is_constant_fill(v, jaxpr, _prod, _depth + 1) for v in data_ins)


def site_of(eqn, fallback: str = "unknown:unknown") -> str:
    """Stable ``file:function`` provenance of an equation."""
    try:
        frame = source_info_util.user_frame(eqn.source_info)
    except Exception:
        frame = None
    if frame is None:
        return fallback
    return f"{rel_source_path(frame.file_name)}:{frame.function_name}"


def rel_source_path(path: str) -> str:
    """Package-relative source path: '.../src/repro/core/engine.py' ->
    'core/engine.py'; files outside the package keep their basename."""
    norm = path.replace(os.sep, "/")
    marker = "/repro/"
    if marker in norm:
        return norm.rsplit(marker, 1)[1]
    return norm.rsplit("/", 1)[-1]


def aval_bytes(aval) -> int:
    try:
        size = int(np.prod(aval.shape)) if aval.shape else 1
        return size * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0


def collect_consts(closed_jaxpr) -> List[np.ndarray]:
    """Concrete constants captured by the trace (closure-captured arrays)."""
    out = []
    for c in getattr(closed_jaxpr, "consts", ()):
        try:
            out.append(np.asarray(c))
        except Exception:
            continue
    return out
